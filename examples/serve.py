"""Serving example: batched prefill + greedy decode with a persistent KV
cache — the same prefill/decode steps the inference dry-run cells lower.

  PYTHONPATH=src python examples/serve.py --arch qwen2-7b
(uses the reduced smoke config on CPU; on a TPU slice drop --smoke logic
and point --arch at the full config.)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import init_params
from repro.training import greedy_generate, make_decode_step, make_prefill_step

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-7b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--gen-len", type=int, default=16)
args = ap.parse_args()

cfg = get_arch(args.arch, smoke=True)
params = init_params(jax.random.PRNGKey(0), cfg)
max_seq = args.prompt_len + args.gen_len

prompt = jax.random.randint(jax.random.PRNGKey(1),
                            (args.batch, args.prompt_len), 0, cfg.vocab_size)

prefill = jax.jit(make_prefill_step(cfg, max_seq=max_seq))
decode = jax.jit(make_decode_step(cfg))

t0 = time.time()
state, logits = prefill(params, prompt)
jax.block_until_ready(logits)
print(f"prefill: batch={args.batch} len={args.prompt_len} "
      f"({time.time()-t0:.2f}s incl. compile)")

tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
out = [tok]
t0 = time.time()
for i in range(args.gen_len - 1):
    state, logits = decode(params, state, tok)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out.append(tok)
jax.block_until_ready(tok)
dt = time.time() - t0
gen = jnp.concatenate(out, 1)
print(f"decode: {args.gen_len-1} steps in {dt:.2f}s "
      f"({args.batch*(args.gen_len-1)/dt:.1f} tok/s)")
print("generated ids[0]:", list(map(int, gen[0])))

# one-call variant
gen2 = greedy_generate(cfg, params, prompt, n_steps=args.gen_len,
                       max_seq=max_seq)
assert (gen2 == gen).all(), "generate mismatch"
print("greedy_generate matches step-by-step decode")
