"""End-to-end pretraining driver: a paper-scale LLaMA (60M/130M) trained for
a few hundred steps with SCALE, with checkpointing + auto-resume.

  PYTHONPATH=src python examples/pretrain.py --arch llama-60m --steps 300
  # kill it at any point, re-run with the same command: it resumes.

This is the same production path the multi-pod dry-run lowers — on a TPU
slice the identical code shards over the (data, model) mesh.
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--optimizer", default="scale")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_pretrain")
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--optimizer", args.optimizer, "--lr", "1e-3",
        "--dtype", "float32",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--resume", "auto", "--log-every", "10",
    ])
