"""Figure 1 in miniature: perplexity vs optimizer memory across methods.

Trains the same proxy LLaMA with every optimizer and prints a Pareto table:
SCALE should sit at the bottom-left (lowest memory at Adam-class ppl).

  PYTHONPATH=src python examples/compare_optimizers.py --steps 150
"""
import argparse

import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.pretrain_proxy import pretrain, proxy_cfg, _sched
from repro.core import make_optimizer, memory_report
from repro.models import param_shapes

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()

METHODS = [("scale", {}, 1e-2), ("adam", {}, 3e-3), ("stable_spam", {}, 3e-3),
           ("muon", {}, 3e-3), ("swan", {}, 3e-3),
           ("galore", {"rank": 16}, 3e-3), ("fira", {"rank": 16}, 3e-3),
           ("apollo", {"rank": 16}, 3e-3), ("apollo_mini", {}, 3e-3),
           ("sgd", {}, 0.1)]

shapes = param_shapes(proxy_cfg())
rows = []
for name, kw, lr in METHODS:
    ppl = pretrain(make_optimizer(name, _sched(lr, args.steps), **kw),
                   args.steps)
    mem = memory_report(shapes, "adam" if name == "stable_spam" else
                        name.replace("scale_fused", "scale"),
                        rank=kw.get("rank", 256)).gb()[2] * 1e3
    rows.append((name, ppl, mem))

rows.sort(key=lambda r: r[2])
print(f"{'method':14s} {'eval_ppl':>9s} {'mem_MB':>8s}")
for name, ppl, mem in rows:
    print(f"{name:14s} {ppl:9.2f} {mem:8.2f}")

best_ppl = min(r[1] for r in rows)
scale_row = next(r for r in rows if r[0] == "scale")
print(f"\nSCALE: ppl within {scale_row[1]/best_ppl - 1:.1%} of best, "
      f"memory rank #{[r[0] for r in rows].index('scale') + 1} "
      f"(1 = smallest after SGD)")
