"""Fine-tuning proxy (paper Appendix I): take a pretrained checkpoint and
fine-tune with SCALE vs Adam on a shifted data distribution.

  PYTHONPATH=src python examples/finetune.py
"""
import dataclasses

import jax

from repro.core import linear_warmup_cosine, make_optimizer
from repro.data import make_dataset
from repro.models import init_params
from repro.training import init_state, make_eval_step, make_train_step
from repro.models import ModelConfig


def proxy_cfg():
    return ModelConfig(name="llama-proxy", family="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=344,
                       vocab_size=512, dtype="float32", attn_kv_block=64,
                       attn_q_block=64, loss_chunk=64)

PRETRAIN_STEPS, FT_STEPS = 80, 40
cfg = proxy_cfg()

# --- pretrain once (seed-0 distribution) ---
tx0 = make_optimizer("scale", linear_warmup_cosine(1e-2, PRETRAIN_STEPS))
state = init_state(init_params(jax.random.PRNGKey(0), cfg), tx0)
step0 = jax.jit(make_train_step(cfg, tx0, clip_norm=1.0))
ds_pre = make_dataset(cfg, seq_len=64, global_batch=16, seed=0)
for i in range(PRETRAIN_STEPS):
    state, _ = step0(state, ds_pre.host_batch_at(i))
pretrained = state.params
ev = jax.jit(make_eval_step(cfg))

# --- fine-tune on a different bigram map (seed-7 "domain") ---
ds_ft = make_dataset(cfg, seq_len=64, global_batch=16, seed=7)
base = float(ev(pretrained, ds_ft.host_batch_at(9_999))["perplexity"])
print(f"zero-shot ppl on the new domain: {base:.2f}")
for name, lr in (("scale", 3e-3), ("adam", 1e-3)):
    tx = make_optimizer(name, linear_warmup_cosine(lr, FT_STEPS))
    st = init_state(pretrained, tx)
    stepf = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
    for i in range(FT_STEPS):
        st, _ = stepf(st, ds_ft.host_batch_at(i))
    ppl = float(ev(st.params, ds_ft.host_batch_at(9_999))["perplexity"])
    print(f"fine-tuned with {name:6s}: ppl {ppl:.2f}  (improvement {base/ppl:.2f}x)")
