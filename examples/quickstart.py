"""Quickstart: pretrain a tiny LLaMA with SCALE and inspect what makes it
memory-efficient.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import linear_warmup_cosine, make_optimizer, memory_report
from repro.data import make_dataset
from repro.models import ModelConfig, init_params, param_shapes
from repro.training import init_state, make_eval_step, make_train_step

STEPS = 60

cfg = ModelConfig(name="quickstart-llama", family="dense", n_layers=4,
                  d_model=128, n_heads=4, n_kv_heads=4, d_ff=344,
                  vocab_size=512, dtype="float32",
                  attn_kv_block=64, attn_q_block=64, loss_chunk=64)

# --- the paper's optimizer: column-norm everywhere, momentum on the head ---
tx = make_optimizer("scale", linear_warmup_cosine(3e-3, STEPS), beta=0.9)

params = init_params(jax.random.PRNGKey(0), cfg)
state = init_state(params, tx)
step = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
ds = make_dataset(cfg, seq_len=64, global_batch=16)

for i in range(STEPS):
    state, metrics = step(state, ds.host_batch_at(i))
    if (i + 1) % 10 == 0:
        print(f"step {i+1:3d}  loss {float(metrics['loss']):.4f}")

evaluate = jax.jit(make_eval_step(cfg))
print(f"eval ppl: {float(evaluate(state.params, ds.host_batch_at(9999))['perplexity']):.2f}")

# --- why it's memory-efficient: the only stateful matrix is the LM head ---
mu = state.opt_state.mu
print("\noptimizer state buffers:")
print(f"  lm_head momentum: {mu['lm_head']['w'].shape}")
print(f"  hidden matrices:  {mu['segments']['seg0_dense']['attn']['wq'].shape} (stateless)")

shapes = param_shapes(cfg)
for method in ("sgd", "scale", "muon", "adam"):
    w, s, t = memory_report(shapes, method).gb()
    print(f"  {method:6s} weights={w*1e3:7.2f}MB state={s*1e3:7.2f}MB total={t*1e3:7.2f}MB")
