"""Paper Table 1: wall-time of each gradient normalization.

The paper measures CUDA on an A40; here the same ordering must hold on CPU:
sign < col/row << NS << exact SVD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (colnorm, ns_orthogonalize, rownorm, signnorm,
                        svd_orthogonalize)

from .common import time_call

NORMS = [
    ("singular-value", svd_orthogonalize),
    ("singular-value-ns", ns_orthogonalize),
    ("column-wise", colnorm),
    ("row-wise", rownorm),
    ("sign", signnorm),
]


def run(quick: bool = True):
    dims = (256, 512) if quick else (256, 512, 1024, 2048)
    rows = []
    for d in dims:
        g = jax.random.normal(jax.random.PRNGKey(0), (d, d))
        for name, fn in NORMS:
            if name == "singular-value" and d > 512 and quick:
                continue
            jfn = jax.jit(fn)
            us = time_call(jfn, g, iters=3 if "singular" in name else 7)
            rows.append((f"table1/{name}/d{d}", round(us, 1), ""))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
