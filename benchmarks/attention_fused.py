"""Fused vs scan-jnp flash attention: HBM-byte accounting, peak
score-activation bytes, kernel parity, and backend-appropriate timing.

Accounting model (one full attention forward+backward over a causal
(B, S, S) problem; be = element size of q/k/v, f32 intermediates 4 bytes;
tile pairs above the causal diagonal are skipped by both paths). The jnp
scan (``models.layers.flash_attention``) first **repeats GQA kv to the
full H heads** (one (B, S, H, hd) write each for k and v), then per tile
pair reads the q/k/v blocks and round-trips its f32 carries through HBM
block slices: the (B, b, H, hd) output accumulator plus (B, b, H) max/sum
rows on the forward, and the three f32 dQ/dK/dV accumulators on the
backward. The fused path (:mod:`repro.kernels.attention`) pays one
layout transpose per operand, reads kv **un-repeated** (1/G of the scan's
kv bytes) once per live q tile, and keeps every carry in VMEM scratch —
its only f32 HBM traffic is the final lse row.

The memory figure of merit is the peak score activation: the scan's
einsum materializes the (B, H, b, b) f32 score tile across *all* batch
and head entries at once, while the kernels hold one (bq, bk) f32 VMEM
tile regardless of B, H, S (see ``attn/peak_score_bytes_*``).

Timing follows the convention of :mod:`benchmarks.xent_fused`: off-TPU
the compiled-kernel path would time the Pallas *interpreter*, so the
wall-clock section times the jnp scan under compiled XLA (fused-off), and
the fused kernels are timed only on TPU (``--tiny`` also times the
interpret oracle at toy shapes so the harness itself cannot rot). Parity
runs the real kernels on every backend.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

# paper-scale attention shapes (bf16): a 60M-ish MHA model and a 1B-ish
# GQA model — the GQA ratio is the point (the scan pays G-times the kv
# traffic; cf. the head-dominance framing the SCALE/APOLLO papers share)
SHAPES = {
    "60M": dict(B=4, S=4096, H=8, K=8, hd=64),
    "1B-gqa": dict(B=4, S=4096, H=32, K=8, hd=128),
}


def _tiles(S, hd, be):
    from repro.kernels.attention.attention import _pick_tiles
    return _pick_tiles(S, S, hd, hd, None, el_bytes=be)


def scan_bytes(B, S, H, K, hd, block=1024, be=2):
    """(total_bytes, peak_score_bytes) for the jnp scan path (causal).

    The dominant term is the (B, H, b, b) f32 score tile the einsum
    materializes across all batch/head entries at once — far past
    register capacity, so it lives in HBM between the two matmuls.
    Counted best-case for XLA (one write+read on the forward with the
    whole mask/exp chain fused; two such round-trips on the backward for
    the recomputed p and ds), mirroring the xent benchmark's generosity.
    """
    from repro.models.layers import _pick_block
    b = _pick_block(S, S, block)
    nq = S // b
    npairs = nq * (nq + 1) // 2
    blk = B * b * H * hd * be          # one q/k/v/do block
    f32_blk = B * b * H * hd * 4       # one f32 accumulator block slice
    score = B * H * b * b * 4          # one materialized f32 score tile
    rep = 2 * B * S * H * hd * be if K != H else 0  # materialized kv repeat
    fwd = npairs * (3 * blk + 2 * f32_blk + 2 * score)  # qkv + acc + p
    bwd = npairs * (4 * blk + 3 * 2 * f32_blk + 4 * score)  # + p, ds
    out = 2 * B * S * H * hd * be                   # out write + bwd read
    return rep + fwd + bwd + out, score


def fused_bytes(B, S, H, K, hd, be=2):
    """(total_bytes, peak_score_bytes) for the fused kernel path (causal).

    kv blocks are revisited per live q tile but never repeated (K heads,
    not H); q/out/do blocks stream once per kernel; the layout transposes
    (one read+write per operand per kernel) are counted honestly.
    """
    bq, bk = _tiles(S, hd, be)
    nq, nk = math.ceil(S / bq), math.ceil(S / bk)
    live = sum(min(nk, math.ceil((i + 1) * bq / bk)) for i in range(nq))
    q_sz = B * S * H * hd * be
    kv_sz = B * S * K * hd * be                     # un-repeated!
    kblk = B * H * bk * hd * be                     # kv block per q head
    # layout transposes, one read+write per operand per kernel: forward
    # moves q/k/v in and out back (2q + 2kv), dQ adds dout in and dq out
    # (3q + 2kv), dK/dV adds dk/dv out (2q + 4kv)
    transpose = 2 * (7 * q_sz + 8 * kv_sz)
    fwd = q_sz + live * kblk + q_sz                 # q in, kv stream, out
    dq = 2 * q_sz + live * kblk + q_sz              # q+do in, kv, dq out
    dkv = nk * 2 * q_sz + 2 * kv_sz + 2 * kv_sz     # q/do per kv tile
    lse = B * H * S * 4 * 3
    return transpose + fwd + dq + dkv + lse, bq * bk * 4


def _accounting_rows(shapes):
    rows = []
    peaks = {}
    for name, s in shapes.items():
        sb, speak = scan_bytes(**s)
        fb, fpeak = fused_bytes(**s)
        peaks[name] = fpeak
        rows += [
            (f"attn/{name}/jnp_scan_hbm_bytes", None,
             f"{sb / 1e9:.2f} GB (peak score block {speak / 1e6:.0f} MB, "
             f"f32 carries round-trip HBM, kv repeated "
             f"x{s['H'] // s['K']})"),
            (f"attn/{name}/fused_hbm_bytes", None,
             f"{fb / 1e9:.2f} GB (peak score tile {fpeak / 1e6:.2f} MB in "
             f"VMEM, carries never leave VMEM, kv un-repeated)"),
            (f"attn/{name}/hbm_ratio", None,
             f"{sb / fb:.2f}x fewer bytes fused"),
        ]
        assert fb < sb, (name, fb, sb)  # the PR's acceptance bar
    if len(peaks) > 1:
        vals = sorted(set(peaks.values()))
        rows.append(("attn/peak_score_bytes_fused", None,
                     f"{' vs '.join(f'{v / 1e6:.2f} MB' for v in vals)} "
                     f"across {', '.join(peaks)} — one (bq, bk) VMEM tile, "
                     f"independent of B, H and S (the scan's einsum "
                     f"materializes the tile across all B*H at once)"))
    return rows


def _parity_rows(tiny: bool):
    """Real kernels (interpret oracle off-TPU) vs the jnp scan reference:
    causal GQA fwd + dQ/dK/dV, and the kv_len decode bound."""
    from repro.kernels import dispatch
    from repro.models.layers import chunked_q_attention, flash_attention

    B, S, H, K, hd = (1, 32, 4, 2, 8) if tiny else (2, 128, 8, 2, 32)
    scale = hd ** -0.5
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    do = jax.random.normal(ks[3], (B, S, H, hd))
    # explicit mode: a user-exported REPRO_FUSED=off must not silently
    # turn this into a reference-vs-reference comparison
    mode = "compiled" if jax.devices()[0].platform == "tpu" else "interpret"
    assert dispatch.attn_route(q.shape, k.shape, True, mode)[0] == "kernel"

    def f_fused(q, k, v):
        return jnp.sum(dispatch.flash_attention(
            q, k, v, scale=scale, causal=True, mode=mode) * do)

    def f_ref(q, k, v):
        kf, vf = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
        return jnp.sum(flash_attention(q, kf, vf, 128, scale, True) * do)

    v1, g1 = jax.value_and_grad(f_fused, argnums=(0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    errs = {"out": abs(float(v1) - float(v2)) / max(abs(float(v2)), 1e-9)}
    for name, a, b in zip(("dQ", "dK", "dV"), g1, g2):
        errs[name] = float(jnp.max(jnp.abs(a - b)))
    assert errs["out"] < 1e-5 and max(errs[n] for n in ("dQ", "dK", "dV")) \
        < 1e-4, errs

    # decode: S=1 against the cache with a kv_len bound
    qd = jax.random.normal(ks[0], (B, 1, H, hd))
    fill = jnp.asarray(S // 3)
    od = dispatch.flash_attention(qd, k, v, scale=scale, causal=False,
                                  kv_len=fill, mode=mode)
    rd = chunked_q_attention(qd, k, v, 1, scale, kv_len=fill)
    errs["decode"] = float(jnp.max(jnp.abs(od - rd)))
    assert errs["decode"] < 1e-5, errs
    return [(f"attn/parity_{n}_err", None, f"{e:.2e}")
            for n, e in errs.items()]


def _timing_rows(tiny: bool):
    """Wall time of attention loss+grad; see the module docstring for what
    is compared on which backend."""
    from repro.kernels import dispatch
    from repro.models.layers import flash_attention

    from .common import repro_fused, time_call

    on_tpu = jax.devices()[0].platform == "tpu"
    B, S, H, K, hd = (1, 32, 4, 2, 8) if tiny else (2, 512, 8, 2, 64)
    scale = hd ** -0.5
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))

    def scan_loss(q, k, v):
        kf, vf = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
        return jnp.sum(flash_attention(q, kf, vf, 128, scale, True) ** 2)

    def fused_loss(q, k, v):
        return jnp.sum(dispatch.flash_attention(
            q, k, v, scale=scale, causal=True) ** 2)

    rows = [("attn/timing_backend", None, jax.devices()[0].platform)]
    with repro_fused("off"):  # scan path, compiled XLA
        g_scan = jax.jit(jax.grad(scan_loss, argnums=(0, 1, 2)))
        us_scan = time_call(g_scan, q, k, v)
    rows.append(("attn/step_jnp_scan", round(us_scan, 1),
                 f"grad of blockwise scan, B={B} S={S} H={H} K={K} "
                 f"hd={hd}"))
    if on_tpu or tiny:
        g_fused = jax.jit(jax.grad(fused_loss, argnums=(0, 1, 2)))
        us_fused = time_call(g_fused, q, k, v)
        label = "compiled kernels" if on_tpu else \
            "interpret oracle (correctness harness, not a perf number)"
        rows.append(("attn/step_fused", round(us_fused, 1), label))
    else:
        rows.append(("attn/step_fused", None,
                     "skipped off-TPU (interpret oracle would time the "
                     "Pallas interpreter; run --tiny for the harness "
                     "smoke, or on TPU for real numbers)"))
    return rows


def run(quick: bool = False):
    """``quick`` (the CLI's ``--tiny``) swaps the paper-scale shape sweep
    for toy shapes and times the interpret oracle — the CI smoke mode."""
    tiny = quick
    shapes = ({"tiny": dict(B=1, S=64, H=4, K=2, hd=8)} if tiny else SHAPES)
    rows = [("attn/mode", None,
             f"backend={jax.devices()[0].platform} tiny={tiny} be=2 "
             f"(bf16 q/k/v)")]
    rows += _accounting_rows(shapes)
    rows += _parity_rows(tiny)
    rows += _timing_rows(tiny)
    return rows


if __name__ == "__main__":
    import sys

    from .common import emit, json_arg
    emit(run(quick="--tiny" in sys.argv), json_path=json_arg(sys.argv))
