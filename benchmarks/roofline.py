"""Roofline report: aggregates results/dryrun/*.json into the §Roofline
table (one row per arch x shape x mesh): three terms, bottleneck, useful-
flop ratio, and what would move the dominant term."""
from __future__ import annotations

import glob
import json
import os

SUGGESTIONS = {
    "compute_s": "raise arithmetic efficiency: larger microbatch per chip / "
                 "reduce remat recompute",
    "memory_s": "cut HBM traffic: fuse optimizer update, bf16 accumulators, "
                "larger attention tiles",
    "collective_s": "reshard: fewer TP all-reduces (2D->1D), overlap "
                    "collectives with compute, FSDP gather instead of "
                    "activation reduce",
}


def load(out_dir: str = "results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = True, out_dir: str = "results/dryrun"):
    rows = []
    for r in load(out_dir):
        roof = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}"
        dominant = roof["bottleneck"]
        derived = (f"compute={roof['compute_s']:.3e}s "
                   f"memory={roof['memory_s']:.3e}s "
                   f"collective={roof['collective_s']:.3e}s "
                   f"bottleneck={dominant.replace('_s','')} "
                   f"useful={roof['useful_flop_ratio']:.2f} "
                   f"mfu_bound={roof['mfu_at_roofline']:.3f}")
        rows.append((name, None, derived))
    if not rows:
        rows.append(("roofline/none", None,
                     "run repro.launch.dryrun first (results/dryrun empty)"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
