"""Packed vs padded pretraining batches: pad waste and effective tok/s.

Two layouts of the *same* documents (``data.pipeline``):

  * **packed** — first-fit binned into (B, S) rows with segment ids;
    attention and loss stay within document boundaries (the MaskSpec
    segment clause), pad shrinks to the first-fit remainder.
  * **padded** — one document per row (``unpack_to_rows``), the layout a
    loader without packing support feeds: every row pays ``S - len`` pad
    positions of attention + loss work for nothing.

Reported per layout: **pad-waste %** (1 - real tokens / total positions,
averaged over a few pipeline steps) and the fwd+bwd **effective tok/s**
(weighted tokens — the honest numerator — over the median step time of a
jitted ``value_and_grad(loss_fn)``). The padded layout runs more rows for
the same documents, so its step is both slower *and* earns the same
effective tokens — the ratio is the throughput the packing path recovers.

Timing follows the other harnesses (:mod:`benchmarks.attention_fused`):
off-TPU the fused kernels would time the Pallas interpreter, so steps are
timed under compiled XLA with ``REPRO_FUSED=off``; on TPU the env setting
is untouched. ``--tiny`` shrinks to smoke shapes (CI bench-smoke);
the default is the paper's 60M model at S=1024.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, fused_off_unless_tpu, json_arg, time_call
from repro.configs import get_arch
from repro.data import make_dataset
from repro.data.pipeline import unpack_to_rows
from repro.models import init_params, loss_fn


def _pad_waste(ds, steps):
    """Mean pad fraction of the packed batches and their padded unpacking."""
    packed_waste, padded_waste = [], []
    for step in range(steps):
        batch = ds.global_batch_at(step)
        w = np.asarray(batch["segment_ids"] > 0, np.float64)
        packed_waste.append(1.0 - w.mean())
        rows = unpack_to_rows(batch)
        ru = np.asarray(rows["segment_ids"] > 0, np.float64)
        padded_waste.append(1.0 - ru.mean())
    return float(np.mean(packed_waste)), float(np.mean(padded_waste))


def run(tiny: bool, json_path=None):
    cfg = get_arch("llama-60m", smoke=tiny)
    B, S = (4, 64) if tiny else (8, 1024)
    if cfg.attn_kv_block > S:
        cfg.attn_kv_block = cfg.attn_q_block = max(16, S // 4)
    cfg.loss_chunk = min(cfg.loss_chunk, S)
    ds = make_dataset(cfg, seq_len=S, global_batch=B, seed=0,
                      pack_documents=True)
    pack_w, pad_w = _pad_waste(ds, steps=4)

    params = init_params(jax.random.PRNGKey(0), cfg)
    step = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b)[0], has_aux=False))

    batch = ds.global_batch_at(0)
    rows = unpack_to_rows(batch)
    eff_tokens = float(jnp.sum(batch["loss_weights"]))  # same in both

    with fused_off_unless_tpu():
        us_pack = time_call(step, params, batch, warmup=1, iters=3)
        us_pad = time_call(step, params, rows, warmup=1, iters=3)

    tps_pack = eff_tokens / (us_pack / 1e6)
    tps_pad = eff_tokens / (us_pad / 1e6)
    n_rows = int(rows["tokens"].shape[0])
    emit([
        ("pack/pad_waste_pct", None, f"{100 * pack_w:.2f}"),
        ("pad/pad_waste_pct", None, f"{100 * pad_w:.2f}"),
        ("pack/rows_per_step", None, f"{B}"),
        ("pad/rows_per_step", None, f"{n_rows}"),
        ("pack/step", us_pack, f"eff_tok_s={tps_pack:.0f}"),
        ("pad/step", us_pad, f"eff_tok_s={tps_pad:.0f}"),
        ("pack_vs_pad/speedup", None, f"{tps_pack / tps_pad:.2f}x"),
    ], json_path)
    # sanity the harness itself: packing must actually reduce pad waste,
    # and both steps must produce finite losses
    assert pack_w < pad_w, (pack_w, pad_w)
    loss_p, _ = step(params, batch)
    loss_u, _ = step(params, rows)
    assert bool(jnp.isfinite(loss_p)) and bool(jnp.isfinite(loss_u))
    return tps_pack, tps_pad


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    run(tiny="--tiny" in argv, json_path=json_arg(argv))


if __name__ == "__main__":
    main()
