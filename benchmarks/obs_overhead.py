"""Overhead of the in-jit stats collector (`repro.obs.stats`).

The telemetry plane's contract is "always-on at low cadence": the per-
layer-group statistics must be cheap enough to leave enabled on every
production run. This harness times the *same* jitted train step three
ways — stats off, stats at ``every_k`` (the amortized production shape),
and stats every step (the worst case) — and reports mean step time plus
the relative overhead of each. The cadenced overhead is the number the CI
``obs-smoke`` job asserts stays under 10% at the tiny scale.

Timing: mean wall time over the run (not median — with ``every_k`` only
every k-th step pays the collector, and the median would report an
off-cadence step, i.e. ~0 by construction), first post-compile step
excluded. Off-TPU the step runs under compiled XLA (``REPRO_FUSED=off``,
like every other harness) so the comparison is real math, not the Pallas
interpreter.

JSON (``--json BENCH_obs.json``): ``{"schema": "obs_overhead/v1", "rows":
[{variant, every_k, mean_step_us, overhead_pct}, ...]}``.
"""
from __future__ import annotations

import json
import sys
import time

import jax

from benchmarks.common import fused_off_unless_tpu
from repro.core import make_optimizer
from repro.data import make_dataset
from repro.models import ModelConfig, init_params
from repro.obs import StatsPolicy
from repro.training import init_state, make_train_step

SCHEMA = "obs_overhead/v1"


def bench_cfg(tiny: bool) -> ModelConfig:
    if tiny:
        return ModelConfig(name="obs-tiny", family="dense", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                           vocab_size=256, dtype="float32",
                           attn_kv_block=16, attn_q_block=16, loss_chunk=16)
    return ModelConfig(name="obs-base", family="dense", n_layers=4,
                       d_model=256, n_heads=8, n_kv_heads=4, d_ff=512,
                       vocab_size=4096, dtype="float32",
                       attn_kv_block=64, attn_q_block=64, loss_chunk=64)


def _mean_step_us(cfg, ds, stats, steps: int) -> float:
    tx = make_optimizer("scale", 1e-3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(params, tx)
    fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0, stats=stats))
    # compile + one settle step outside the clock
    for i in range(2):
        state, m = fn(state, ds.host_batch_at(i))
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(2, 2 + steps):
        state, m = fn(state, ds.host_batch_at(i))
    jax.block_until_ready(m["loss"])
    return 1e6 * (time.perf_counter() - t0) / steps


def run(tiny: bool = False, every_k: int = 4, steps: int = 32,
        json_path=None):
    cfg = bench_cfg(tiny)
    batch, seq = (8, 64) if tiny else (8, 256)
    ds = make_dataset(cfg, seq_len=seq, global_batch=batch, seed=0)
    with fused_off_unless_tpu():
        base = _mean_step_us(cfg, ds, None, steps)
        cadenced = _mean_step_us(cfg, ds, StatsPolicy(every_k=every_k),
                                 steps)
        every = _mean_step_us(cfg, ds, StatsPolicy(every_k=1), steps)
    rows = [
        {"variant": "no_stats", "every_k": 0, "mean_step_us": base,
         "overhead_pct": 0.0},
        {"variant": "stats_cadenced", "every_k": every_k,
         "mean_step_us": cadenced,
         "overhead_pct": 100.0 * (cadenced - base) / base},
        {"variant": "stats_every_step", "every_k": 1, "mean_step_us": every,
         "overhead_pct": 100.0 * (every - base) / base},
    ]
    for r in rows:
        print(f"{r['variant']},{r['every_k']},{r['mean_step_us']:.1f},"
              f"{r['overhead_pct']:+.2f}%")
    doc = {"schema": SCHEMA, "model": cfg.name, "batch": batch, "seq": seq,
           "steps_timed": steps, "rows": rows}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {json_path}")
    return doc


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    from benchmarks.common import json_arg
    run(tiny="--tiny" in argv, json_path=json_arg(argv))


if __name__ == "__main__":
    main()
