"""Fused vs chunked-jnp LM-head cross-entropy: HBM-byte accounting, peak
logit-activation bytes, kernel parity, and backend-appropriate timing.

Accounting model (one full loss+grad; be = element size of h/w, f32
intermediates 4 bytes). The jnp chunked path
(``models.model.lm_loss`` scan under full remat) per chunk of ``B*chunk``
tokens: the forward reads the h chunk and w and materializes + reads the
(B*chunk, V) f32 logit block (logsumexp assumed fused into one
write+read — best case for XLA); the backward recomputes the logits,
materializes dlogits (write+read), writes the dH chunk, and reads+writes
the f32 (D, V) dW accumulator the scan carries across every chunk. The
fused path (:mod:`repro.kernels.xent`): forward reads h once and w once
per token tile; dH the same plus one dH write; dW reads w once and h once
per vocab tile plus one dW write — logits and dlogits never leave VMEM.

The memory figure of merit is the peak logit activation: the jnp path
holds a (B*chunk, V) f32 block in HBM — O(S*V) as chunk approaches S —
while the fused path's is one (bn, bv) f32 VMEM tile, the same few MiB at
every head size (independent of V and S; see
``xent/peak_logit_bytes_*``).

Timing follows the convention of :mod:`benchmarks.fused_update`: off-TPU
the compiled-kernel path would time the Pallas *interpreter*, so the
wall-clock section compares the two jnp code paths (chunked scan vs full
logits) under compiled XLA, and the fused kernels are timed only on TPU
(``--tiny`` also times the interpret oracle at toy shapes so the harness
itself cannot rot). Parity runs the real kernels on every backend.
"""
from __future__ import annotations

import math
import os

import jax
import jax.numpy as jnp
import numpy as np

# paper-scale head shapes (bf16 h/w): LLaMA-60M-ish and a 1B model with a
# 128k tokenizer — the V sweep is the point (head dominance, cf. APOLLO)
HEADS = {
    "60M": dict(B=4, S=4096, D=512, V=32768),
    "1B": dict(B=4, S=4096, D=2048, V=131072),
}
CHUNK = 2048  # cfg.loss_chunk default


def _tiles(N, D, V, be):
    from repro.kernels.xent.xent import _pick_blocks
    fwd = _pick_blocks(N, D, V, el_bytes=be)
    dh = _pick_blocks(N, D, V, el_bytes=be, row_acc=True)
    return fwd, dh


def jnp_chunk_bytes(B, S, D, V, chunk, be=2):
    """(total_bytes, peak_logit_bytes) for the chunked-scan jnp path."""
    chunk = min(chunk, S)
    nch = math.ceil(S / chunk)
    c = B * chunk
    logit = c * V * 4
    fwd = nch * (c * D * be + D * V * be + 2 * logit)
    bwd = nch * (c * D * be + D * V * be + 2 * logit   # remat logits
                 + 2 * logit                           # dlogits
                 + c * D * be                          # dH chunk
                 + 2 * D * V * 4)                      # f32 dW accum r+w
    return fwd + bwd, logit


def fused_bytes(B, S, D, V, be=2):
    """(total_bytes, peak_logit_bytes) for the fused kernel path."""
    N = B * S
    (bn_f, bv_f), (bn_h, _) = _tiles(N, D, V, be)
    fwd = N * D * be + math.ceil(N / bn_f) * D * V * be
    dh = 2 * N * D * be + math.ceil(N / bn_h) * D * V * be
    dw = math.ceil(V / bv_f) * N * D * be + 2 * D * V * be
    # loss/lse/labels vectors are noise (N * 4 each)
    return fwd + dh + dw, max(bn_f * bv_f, bn_h * bv_f) * 4


def _accounting_rows(heads, chunk):
    rows = []
    peaks = {}
    for name, s in heads.items():
        jb, jpeak = jnp_chunk_bytes(**s, chunk=chunk)
        fb, fpeak = fused_bytes(**s)
        peaks[name] = fpeak
        rows += [
            (f"xent/{name}/jnp_chunk_hbm_bytes", None,
             f"{jb / 1e9:.2f} GB (peak logit block {jpeak / 1e6:.0f} MB "
             f"in HBM)"),
            (f"xent/{name}/fused_hbm_bytes", None,
             f"{fb / 1e9:.2f} GB (peak logit tile {fpeak / 1e6:.2f} MB "
             f"in VMEM)"),
            (f"xent/{name}/hbm_ratio", None,
             f"{jb / fb:.2f}x fewer bytes fused"),
        ]
        assert fb < jb, (name, fb, jb)  # the PR's acceptance bar
    if len(peaks) > 1:
        vals = sorted(set(peaks.values()))
        rows.append(("xent/peak_logit_bytes_fused", None,
                     f"{' vs '.join(f'{v / 1e6:.2f} MB' for v in vals)} "
                     f"across {', '.join(peaks)} — O(bn*bv) VMEM tile, "
                     f"set by the D-dependent tile budget and independent "
                     f"of V and S (jnp peak is O(chunk*V) in HBM)"))
    return rows


def _parity_rows(B=2, S=64, D=64, V=512, VS=500, tied: bool = False):
    """Real kernels (interpret oracle off-TPU) vs the full-logit jnp ref.

    ``tied``: exercise the transposed-w variants — w lives in the (V, D)
    embedding layout, dW must come back in that layout, and the oracle
    contracts ``w.T``.
    """
    from repro.kernels import dispatch
    from repro.kernels.xent import ref as xref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32)
    w = jax.random.normal(ks[1], (V, D) if tied else (D, V), jnp.float32)
    lab = jax.random.randint(ks[2], (B, S), -1, VS)
    # explicit mode: a user-exported REPRO_FUSED=off must not silently
    # turn this into a reference-vs-reference comparison
    mode = "compiled" if jax.devices()[0].platform == "tpu" else "interpret"
    assert dispatch.xent_route(h.shape, w.shape, mode,
                               transposed=tied)[0] == "kernel"

    def f_fused(h, w):
        return jnp.sum(dispatch.xent_loss(h, w, lab, vocab_size=VS,
                                          mode=mode, transposed=tied))

    def f_ref(h, w):
        return jnp.sum(xref.losses(h, w.T if tied else w, lab, VS))

    (v1, (dh1, dw1)) = jax.value_and_grad(f_fused, argnums=(0, 1))(h, w)
    (v2, (dh2, dw2)) = jax.value_and_grad(f_ref, argnums=(0, 1))(h, w)
    assert dw1.shape == w.shape
    errs = {
        "loss": abs(float(v1) - float(v2)) / max(abs(float(v2)), 1e-9),
        "dH": float(jnp.max(jnp.abs(dh1 - dh2))),
        "dW": float(jnp.max(jnp.abs(dw1 - dw2))),
    }
    assert errs["loss"] < 1e-5 and errs["dH"] < 1e-4 and errs["dW"] < 1e-4, \
        errs
    tag = "tied_parity" if tied else "parity"
    return [(f"xent/{tag}_{k}_err", None, f"{e:.2e}")
            for k, e in errs.items()]


def _timing_rows(tiny: bool):
    """Wall time of loss+grad; see the module docstring for what is
    compared on which backend."""
    from repro.kernels import dispatch
    from repro.kernels.xent import ref as xref
    from repro.models import ModelConfig, lm_loss

    from .common import time_call

    on_tpu = jax.devices()[0].platform == "tpu"
    B, S, D, V = (2, 64, 32, 512) if tiny else (4, 512, 256, 4096)
    cfg = ModelConfig(d_model=D, vocab_size=V, loss_chunk=max(S // 4, 1),
                      dtype="float32")
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    h = jax.random.normal(ks[0], (B, S, D))
    w = jax.random.normal(ks[1], (D, cfg.padded_vocab))
    lab = jax.random.randint(ks[2], (B, S), -1, V)

    def scan_loss(h, w):
        return lm_loss({"lm_head": {"w": w}}, cfg, h, lab)[0]

    def full_loss(h, w):
        return jnp.mean(xref.losses(h, w, lab, V))

    def fused_loss(h, w):
        losses = dispatch.xent_loss(h, w, lab, vocab_size=V)
        return jnp.sum(losses) / jnp.maximum(
            jnp.sum((lab >= 0).astype(jnp.float32)), 1.0)

    rows = [("xent/timing_backend", None,
             f"{jax.devices()[0].platform} "
             f"REPRO_FUSED={os.environ.get('REPRO_FUSED', 'auto')}")]
    from .common import repro_fused
    with repro_fused("off"):  # scan path, compiled XLA
        g_scan = jax.jit(jax.grad(scan_loss, argnums=(0, 1)))
        us_scan = time_call(g_scan, h, w)
    g_full = jax.jit(jax.grad(full_loss, argnums=(0, 1)))
    us_full = time_call(g_full, h, w)
    rows += [
        ("xent/step_jnp_chunk_scan", round(us_scan, 1),
         f"grad of chunked scan, B={B} S={S} D={D} V={V}"),
        ("xent/step_jnp_full_logits", round(us_full, 1),
         "grad of full-logit reference (unbounded activation memory)"),
    ]
    if on_tpu or tiny:
        g_fused = jax.jit(jax.grad(fused_loss, argnums=(0, 1)))
        us_fused = time_call(g_fused, h, w)
        label = "compiled kernels" if on_tpu else \
            "interpret oracle (correctness harness, not a perf number)"
        rows.append(("xent/step_fused", round(us_fused, 1), label))
    else:
        rows.append(("xent/step_fused", None,
                     "skipped off-TPU (interpret oracle would time the "
                     "Pallas interpreter; run --tiny for the harness "
                     "smoke, or on TPU for real numbers)"))
    return rows


def _tied_rows():
    """Tied-head (transposed-w) kernel smoke: parity + end-to-end lm_loss.

    Keeps the transposed kernels exercised by ``bench-smoke`` (CI passes
    ``--tied``): the tied lm_loss route must stay on the kernels and match
    the chunked scan over ``tok_embed.w.T``.
    """
    from repro.models import ModelConfig, init_params, lm_loss

    from .common import repro_fused

    rows = _parity_rows(tied=True)
    cfg = ModelConfig(d_model=32, vocab_size=500, loss_chunk=16,
                      dtype="float32", tie_embeddings=True)
    params = init_params(jax.random.PRNGKey(2), cfg)
    h = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    lab = jax.random.randint(jax.random.PRNGKey(4), (2, 64), -1,
                             cfg.vocab_size)
    # pin the mode and assert the route like _parity_rows: an exported
    # REPRO_FUSED=off (or a cfg tweak off the coverage matrix) must fail
    # loudly, not silently compare the scan reference with itself
    from repro.kernels import dispatch
    mode = "compiled" if jax.devices()[0].platform == "tpu" else "interpret"
    assert dispatch.xent_route(
        tuple(h.shape), (cfg.padded_vocab, cfg.d_model), mode,
        transposed=True)[0] == "kernel"
    with repro_fused(mode):
        l_f = float(lm_loss(params, cfg, h, lab)[0])
    with repro_fused("off"):
        l_r = float(lm_loss(params, cfg, h, lab)[0])
    err = abs(l_f - l_r) / max(abs(l_r), 1e-9)
    assert err < 1e-5, (l_f, l_r)
    rows.append(("xent/tied_lm_loss_vs_scan_err", None, f"{err:.2e}"))
    return rows


def run(quick: bool = False, tied: bool = False):
    """``quick`` (the CLI's ``--tiny``) swaps the paper-scale shape sweep
    for toy shapes and times the interpret oracle — the CI smoke mode.
    ``tied`` adds the transposed-w (tied-embedding head) kernel smoke."""
    tiny = quick
    heads = ({"tiny": dict(B=2, S=64, D=32, V=512)} if tiny else HEADS)
    rows = [("xent/mode", None,
             f"backend={jax.devices()[0].platform} tiny={tiny} "
             f"chunk={CHUNK} be=2 (bf16 h/w)")]
    rows += _accounting_rows(heads, CHUNK)
    rows += _parity_rows()
    if tied:
        rows += _tied_rows()
    rows += _timing_rows(tiny)
    return rows


if __name__ == "__main__":
    import sys

    from .common import emit, json_arg
    emit(run(quick="--tiny" in sys.argv, tied="--tied" in sys.argv),
         json_path=json_arg(sys.argv))
