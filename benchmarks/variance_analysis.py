"""Paper Fig. 4 + Fig. 10 proxies.

Fig. 4: per-layer-group gradient variance (small-batch grads vs a large-
batch estimate of the true gradient) — the LM head should dominate, and
last-layer momentum should shrink it.

Fig. 10: LM-head gradient column norms vs token frequency — frequent (low-
id, Zipf) tokens get much larger column norms, the imbalance column-wise
normalization fixes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.labels import layer_group
from repro.data import make_dataset
from repro.models import init_params, loss_fn
from .pretrain_proxy import proxy_cfg


def layer_variances(n_small: int = 8, small_batch: int = 4,
                    large_batch: int = 64, seq: int = 64):
    cfg = proxy_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=seq, global_batch=large_batch)
    grad_fn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))

    big = ds.global_batch_at(0)
    g_true = grad_fn(params, big)

    from repro.core.labels import path_str
    sums, counts = {}, {}
    for i in range(n_small):
        sl = jax.tree_util.tree_map(
            lambda x: x[i * small_batch:(i + 1) * small_batch], big)
        g = grad_fn(params, sl)
        for (kp, gl), tl in zip(jax.tree_util.tree_flatten_with_path(g)[0],
                                jax.tree_util.tree_leaves(g_true)):
            grp = layer_group(path_str(kp))
            d = jnp.mean((gl.astype(jnp.float32) - tl.astype(jnp.float32)) ** 2)
            sums[grp] = sums.get(grp, 0.0) + float(d)
            counts[grp] = counts.get(grp, 0) + 1
    return {k: sums[k] / counts[k] for k in sums}


def head_column_norms(seq: int = 64, batch: int = 32):
    cfg = proxy_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=seq, global_batch=batch)
    grad_fn = jax.jit(jax.grad(lambda p, b: loss_fn(p, cfg, b)[0]))
    g = grad_fn(params, ds.global_batch_at(0))
    gh = np.asarray(g["lm_head"]["w"], np.float32)  # (D, V)
    norms = np.linalg.norm(gh, axis=0)
    # Zipf ids: low token-id == frequent
    head = norms[:32].mean()
    tail = norms[256:512].mean()
    return head, tail


def run(quick: bool = True):
    rows = []
    var = layer_variances(n_small=4 if quick else 8)
    for grp, v in sorted(var.items()):
        rows.append((f"fig4/variance/{grp}", None, f"var={v:.3e}"))
    rows.append(("fig4/lm_head_dominates", None,
                 f"{var['lm_head'] > var['hidden']}"))
    head, tail = head_column_norms()
    rows.append(("fig10/colnorm_frequent_tokens", None,
                 f"head32={head:.2e} tail256={tail:.2e} "
                 f"ratio={head/max(tail,1e-12):.1f}x"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
