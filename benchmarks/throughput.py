"""Paper Table 7 (Appendix D): optimizer-step throughput.

Times one full optimizer update (given fixed gradients) for each method on
a llama-130m-shaped parameter set — isolating the optimizer cost exactly as
the paper's tokens/sec comparison does (fwd/bwd is identical across
methods). Expect: sign/col/row ~ Adam-class cheap; NS-based (Muon/SWAN)
markedly slower; GaLore/Fira pay periodic SVDs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import make_optimizer
from repro.models import init_params

from .common import fused_off_unless_tpu, time_call

METHODS = [("scale", {}), ("scale_fused", {}), ("adam", {}),
           ("stable_spam", {}), ("muon", {}), ("swan", {}),
           ("galore", {"rank": 64}), ("fira", {"rank": 64}),
           ("apollo", {"rank": 64}), ("apollo_mini", {}), ("sgd", {})]


def run(quick: bool = True):
    arch = "llama-60m" if quick else "llama-130m"
    cfg = get_arch(arch)
    cfg.dtype = "float32"
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree_util.tree_map(
        lambda p: 0.01 * jnp.ones_like(p), params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    rows = []
    # off-TPU, scale_fused would time the Pallas interpreter (see common)
    with fused_off_unless_tpu():
        for name, kw in METHODS:
            tx = make_optimizer(name, 1e-3, **kw)
            state = tx.init(params)
            step = jax.jit(lambda g, s: tx.update(g, s, params))
            us = time_call(step, grads, state, iters=5)
            rows.append((f"table7/{arch}/{name}", round(us, 1),
                         f"params={n/1e6:.0f}M"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
