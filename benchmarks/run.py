"""Benchmark entrypoint: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``--full`` runs the long versions
(Table-scale step counts); default is a quick pass suitable for CI.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list: norms,memory,pretrain,optimizers,"
                         "throughput,variance,roofline,fused,xent")
    args = ap.parse_args()
    quick = not args.full

    from . import (fused_update, memory_table, norm_timing, optimizer_bench,
                   pretrain_proxy, roofline, throughput, variance_analysis,
                   xent_fused)
    sections = {
        "norms": norm_timing,
        "memory": memory_table,
        "pretrain": pretrain_proxy,
        "optimizers": optimizer_bench,
        "throughput": throughput,
        "variance": variance_analysis,
        "roofline": roofline,
        "fused": fused_update,
        "xent": xent_fused,
    }
    only = set(args.only.split(",")) if args.only else set(sections)

    print("name,us_per_call,derived")
    for name, mod in sections.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            rows = mod.run(quick=quick)
        except Exception as e:  # a failing section must not hide the rest
            rows = [(f"{name}/ERROR", None, repr(e))]
        for r in rows:
            print(f"{r[0]},{r[1] if r[1] is not None else ''},{r[2]}")
        print(f"# section {name} took {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
