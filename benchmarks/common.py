"""Shared benchmark helpers."""
from __future__ import annotations

import contextlib
import os
import time

import jax


@contextlib.contextmanager
def repro_fused(mode: str):
    """Pin REPRO_FUSED to ``mode`` for the enclosed block, restoring the
    prior value (or unset state) afterwards."""
    prev = os.environ.get("REPRO_FUSED")
    os.environ["REPRO_FUSED"] = mode
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED", None)
        else:
            os.environ["REPRO_FUSED"] = prev


@contextlib.contextmanager
def fused_off_unless_tpu():
    """Pin REPRO_FUSED=off for the enclosed block on non-TPU backends.

    Off-TPU the fused dispatch runs the Pallas *interpreter* — an exactness
    oracle, orders of magnitude slower than compiled XLA. Timing it would
    benchmark the interpreter, not the optimizer, so benchmarks compare the
    code paths under compiled XLA instead. On TPU the env var is left
    untouched (the user's setting, if any, is reported by the caller).
    """
    if jax.devices()[0].platform == "tpu":
        yield
        return
    with repro_fused("off"):
        yield


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted call with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows, json_path=None):
    """Print rows as CSV; optionally also write them as a JSON artifact
    (list of {name, us, derived} — what the CI bench-smoke job uploads)."""
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump([{"name": n, "us": us, "derived": d}
                       for n, us, d in rows], f, indent=2)
        print(f"# wrote {json_path}")


def json_arg(argv):
    """Pull the '--json PATH' flag out of a benchmark's argv (or None)."""
    if "--json" in argv:
        i = argv.index("--json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
            raise SystemExit("--json requires a path argument")
        return argv[i + 1]
    return None
