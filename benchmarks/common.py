"""Shared benchmark helpers."""
from __future__ import annotations

import contextlib
import os
import time

import jax


@contextlib.contextmanager
def fused_off_unless_tpu():
    """Pin REPRO_FUSED=off for the enclosed block on non-TPU backends.

    Off-TPU the fused dispatch runs the Pallas *interpreter* — an exactness
    oracle, orders of magnitude slower than compiled XLA. Timing it would
    benchmark the interpreter, not the optimizer, so benchmarks compare the
    code paths under compiled XLA instead. On TPU the env var is left
    untouched (the user's setting, if any, is reported by the caller).
    """
    if jax.devices()[0].platform == "tpu":
        yield
        return
    prev = os.environ.get("REPRO_FUSED")
    os.environ["REPRO_FUSED"] = "off"
    try:
        yield
    finally:
        if prev is None:
            os.environ.pop("REPRO_FUSED", None)
        else:
            os.environ["REPRO_FUSED"] = prev


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted call with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
