"""Shared benchmark helpers."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted call with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows):
    for name, us, derived in rows:
        print(f"{name},{us if us is not None else ''},{derived}")
