"""Back-compat shim: the Table-4 / Appendix-B memory rows moved into
``benchmarks/optimizer_bench.py`` (the merged head-to-head harness)."""
from __future__ import annotations

from .optimizer_bench import (ACCOUNTING, METHODS, PAPER, memory_rows,
                              tied_rows)


def run(quick: bool = True):
    return memory_rows(quick=quick)


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
