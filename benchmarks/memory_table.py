"""Paper Table 4 + Appendix B: optimizer memory for LLaMA 1B/7B, ours vs the
paper's published numbers, the assigned-architecture zoo, and the
tied-embedding rows at 60M (the regime where the head is the largest single
matrix, so tying shrinks the table the most)."""
from __future__ import annotations

import dataclasses

from repro.configs import ARCH_IDS, LLAMA_PAPER, get_arch
from repro.core import memory_report
from repro.core.labels import LabelRules
from repro.models import param_shapes

PAPER = {  # (model, method) -> GB from Appendix B
    ("llama-7b", "sgd"): 13.476, ("llama-7b", "adam"): 40.428,
    ("llama-7b", "muon"): 26.952, ("llama-7b", "swan"): 14.524,
    ("llama-7b", "apollo"): 16.144, ("llama-7b", "apollo_mini"): 14.531,
    ("llama-7b", "scale"): 13.738,
    ("llama-1b", "sgd"): 2.678, ("llama-1b", "adam"): 8.034,
    ("llama-1b", "muon"): 5.356, ("llama-1b", "swan"): 3.202,
    ("llama-1b", "apollo_mini"): 3.20, ("llama-1b", "scale"): 2.809,
}

METHODS = ("sgd", "adam", "muon", "swan", "galore", "fira", "apollo",
           "apollo_mini", "scale")


def tied_rows(model: str = "llama-60m"):
    """weights/state/total for scale + adam with tying off vs on.

    The tied shapes tree has no ``lm_head`` leaf (counted once), and
    ``LabelRules.tied()`` keeps SCALE's momentum on the tied matrix, so
    tying saves the head's weight bytes while the optimizer state is
    unchanged (the momentum moves, it does not disappear).
    """
    rows = []
    for tied in (False, True):
        cfg = dataclasses.replace(get_arch(model), tie_embeddings=tied)
        shapes = param_shapes(cfg)
        rules = LabelRules.tied() if tied else None
        for m in ("scale", "adam", "sgd"):
            w, s, t = memory_report(shapes, m, rules=rules).gb()
            rows.append((f"tied/{model}/{'tied' if tied else 'untied'}/{m}",
                         None, f"weights={w:.3f}G state={s:.3f}G "
                               f"total={t:.3f}G"))
    return rows


def run(quick: bool = True):
    rows = []
    for model in ("llama-1b", "llama-7b"):
        shapes = param_shapes(get_arch(model))
        for m in METHODS:
            ours = memory_report(shapes, m).gb()[2]
            ref = PAPER.get((model, m))
            derived = (f"ours={ours:.3f}G paper={ref:.3f}G "
                       f"diff={100*(ours-ref)/ref:+.1f}%" if ref
                       else f"ours={ours:.3f}G")
            rows.append((f"table4/{model}/{m}", None, derived))
    rows += tied_rows()
    if not quick:
        for arch in ARCH_IDS:
            shapes = param_shapes(get_arch(arch))
            adam = memory_report(shapes, "adam").gb()[2]
            scale = memory_report(shapes, "scale").gb()[2]
            rows.append((f"memory_zoo/{arch}", None,
                         f"scale={scale:.1f}G adam={adam:.1f}G "
                         f"ratio={scale/adam:.2f}"))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run(quick=False))
