"""Head-to-head optimizer bench: the whole registry zoo on one harness.

Merges the former ``memory_table.py`` (paper Table 4 / Appendix B analytic
memory) and ``pretrain_proxy.py`` (CPU-scale perplexity proxies for Tables
2/3/5/8/11/13) and adds the head-to-head sweep: every ``OPTIMIZER_REGISTRY``
entry trains the same proxy LLaMA and reports, per optimizer,

  * ``final_loss`` / ``eval_ppl`` — last-step training loss and the averaged
    eval perplexity (the paper's ordering claim, not absolute C4 numbers);
  * ``state_bytes`` — *measured* optimizer-state footprint on the proxy
    params (``jax.eval_shape`` over ``tx.init``, summed over leaves);
  * ``llama1b_gb`` — the analytic Appendix-B footprint at LLaMA-1B scale
    (bf16 protocol; this is where the paper's Adam > GaLore > APOLLO >
    SCALE ordering is asserted — the proxy model is too small for it);
  * ``step_time_us`` — median jitted train-step wall time, with
    ``fused_off_unless_tpu`` so off-TPU numbers benchmark compiled XLA,
    not the Pallas interpreter;
  * ``hbm_passes`` — analytic full-matrix HBM passes per step under the
    ``benchmarks/fused_update.py`` convention (fused where the composition
    lowers to the Pallas kernels: stateless 4 vs 6, momentum 6 vs 9 per
    matrix; compositions with Adam-style state count as momentum rows).

``--tiny --json PATH`` is the CI bench-smoke entry (10 steps, seq 32,
batch 8) and what generates the committed ``BENCH_optimizers.json``.
The old module entry points survive as delegating shims.
"""
from __future__ import annotations

import dataclasses
import json

import jax

from repro.configs import ARCH_IDS, LLAMA_PAPER, get_arch
from repro.core import (OPTIMIZER_REGISTRY, linear_warmup_cosine,
                        make_optimizer, memory_report)
from repro.core.labels import LabelRules, label_tree
from repro.core.scale import scale as make_scale
from repro.data import make_dataset
from repro.models import ModelConfig, init_params, param_shapes
from repro.training import init_state, make_eval_step, make_train_step

from .common import emit, fused_off_unless_tpu, time_call

# --------------------------------------------------------------------------
# Analytic memory (paper Table 4 / Appendix B) — formerly memory_table.py
# --------------------------------------------------------------------------

PAPER = {  # (model, method) -> GB from Appendix B
    ("llama-7b", "sgd"): 13.476, ("llama-7b", "adam"): 40.428,
    ("llama-7b", "muon"): 26.952, ("llama-7b", "swan"): 14.524,
    ("llama-7b", "apollo"): 16.144, ("llama-7b", "apollo_mini"): 14.531,
    ("llama-7b", "scale"): 13.738,
    ("llama-1b", "sgd"): 2.678, ("llama-1b", "adam"): 8.034,
    ("llama-1b", "muon"): 5.356, ("llama-1b", "swan"): 3.202,
    ("llama-1b", "apollo_mini"): 3.20, ("llama-1b", "scale"): 2.809,
}

METHODS = ("sgd", "adam", "muon", "swan", "galore", "fira", "apollo",
           "apollo_mini", "scale")

# registry name -> Appendix-B accounting method (vector Adam moments of the
# sgd_*norm ablations are negligible, so they bill as plain sgd)
ACCOUNTING = {"scale_fused": "scale", "sgd_momentum": "sgd_momentum",
              "sgd_colnorm": "sgd", "sgd_rownorm": "sgd",
              "sgd_signnorm": "sgd", "sgd_nsnorm": "sgd",
              "sgd_svdnorm": "sgd"}


def tied_rows(model: str = "llama-60m"):
    """weights/state/total for scale + adam with tying off vs on.

    The tied shapes tree has no ``lm_head`` leaf (counted once), and
    ``LabelRules.tied()`` keeps SCALE's momentum on the tied matrix, so
    tying saves the head's weight bytes while the optimizer state is
    unchanged (the momentum moves, it does not disappear).
    """
    rows = []
    for tied in (False, True):
        cfg = dataclasses.replace(get_arch(model), tie_embeddings=tied)
        shapes = param_shapes(cfg)
        rules = LabelRules.tied() if tied else None
        for m in ("scale", "adam", "sgd"):
            w, s, t = memory_report(shapes, m, rules=rules).gb()
            rows.append((f"tied/{model}/{'tied' if tied else 'untied'}/{m}",
                         None, f"weights={w:.3f}G state={s:.3f}G "
                               f"total={t:.3f}G"))
    return rows


def memory_rows(quick: bool = True):
    rows = []
    for model in ("llama-1b", "llama-7b"):
        shapes = param_shapes(get_arch(model))
        for m in METHODS:
            ours = memory_report(shapes, m).gb()[2]
            ref = PAPER.get((model, m))
            derived = (f"ours={ours:.3f}G paper={ref:.3f}G "
                       f"diff={100*(ours-ref)/ref:+.1f}%" if ref
                       else f"ours={ours:.3f}G")
            rows.append((f"table4/{model}/{m}", None, derived))
    rows += tied_rows()
    if not quick:
        for arch in ARCH_IDS:
            shapes = param_shapes(get_arch(arch))
            adam = memory_report(shapes, "adam").gb()[2]
            scale = memory_report(shapes, "scale").gb()[2]
            rows.append((f"memory_zoo/{arch}", None,
                         f"scale={scale:.1f}G adam={adam:.1f}G "
                         f"ratio={scale/adam:.2f}"))
    return rows


# --------------------------------------------------------------------------
# Pretraining proxy (Tables 2/3/5/8/11/13) — formerly pretrain_proxy.py
# --------------------------------------------------------------------------

def proxy_cfg():
    return ModelConfig(name="llama-proxy", family="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=344,
                       vocab_size=512, dtype="float32", attn_kv_block=64,
                       attn_q_block=64, loss_chunk=64)


def _train(tx, steps: int, seed: int = 0, seq: int = 64, batch: int = 16):
    """Train the proxy model; returns (state, step_fn, ds, final_loss)."""
    cfg = proxy_cfg()
    state = init_state(init_params(jax.random.PRNGKey(seed), cfg), tx)
    step_fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
    ds = make_dataset(cfg, seq_len=seq, global_batch=batch, seed=seed)
    loss = float("nan")
    for i in range(steps):
        state, metrics = step_fn(state, ds.host_batch_at(i))
    loss = float(metrics["loss"])
    return state, step_fn, ds, loss


def _eval_ppl(state, ds) -> float:
    ev = jax.jit(make_eval_step(proxy_cfg()))
    ppl = 0.0
    for j in range(4):
        ppl += float(ev(state.params,
                        ds.host_batch_at(100_000 + j))["perplexity"])
    return ppl / 4


def pretrain(tx, steps: int, seed: int = 0, seq: int = 64, batch: int = 16):
    state, _, ds, _ = _train(tx, steps, seed=seed, seq=seq, batch=batch)
    return _eval_ppl(state, ds)


# per-method peak lr, mirroring the paper's per-optimizer sweeps (App. C).
# Normalized-SGD updates have per-column magnitude == lr, so their optimum
# sits ~3x higher than Adam's on this proxy.
LRS = {"sgd": 1e-1, "adam": 3e-3, "stable_spam": 3e-3, "muon": 3e-3,
       "swan": 3e-3, "galore": 3e-3, "fira": 3e-3, "apollo": 3e-3,
       "apollo_mini": 3e-3, "scale": 1e-2, "sgd_colnorm": 1e-2,
       "sgd_rownorm": 1e-2, "sgd_signnorm": 3e-3, "sgd_nsnorm": 1e-2,
       "sgd_svdnorm": 1e-2, "scale_fused": 1e-2, "sgd_momentum": 1e-1,
       "adamw": 3e-3}

# proxy-scale kwargs: galore-family rank 256 would swamp the 128-d proxy
# matrices (rank >= min dim = plain Adam), so the proxy sweeps use rank 16
PROXY_KW = {"galore": {"rank": 16}, "fira": {"rank": 16},
            "apollo": {"rank": 16}}


def _sched(lr, steps):
    return linear_warmup_cosine(lr, steps)


def table2(steps):
    out = []
    for name in ("sgd_colnorm", "sgd_rownorm", "sgd_signnorm", "sgd_nsnorm",
                 "adam"):
        out.append((f"table2/{name}",
                    pretrain(make_optimizer(name, _sched(LRS[name], steps)),
                             steps)))
    return out


def table3(steps):
    rows = []
    rows.append(("table3/colnorm+mmt-last(SCALE)",
                 pretrain(make_optimizer("scale", _sched(1e-2, steps)), steps)))
    rows.append(("table3/nsnorm+mmt-last",
                 pretrain(make_scale(_sched(3e-3, steps), norm_rest="ns",
                                     norm_last="ns"), steps)))
    return rows


def table5(steps):
    rows = []
    opts = [("scale", {}), ("adam", {}), ("stable_spam", {}), ("muon", {}),
            ("sgd", {}), ("galore", {"rank": 16}), ("fira", {"rank": 16}),
            ("apollo", {"rank": 16}), ("apollo_mini", {}), ("swan", {})]
    for name, kw in opts:
        rows.append((f"table5/{name}",
                     pretrain(make_optimizer(name, _sched(LRS[name], steps),
                                             **kw), steps)))
    return rows


def table8(steps):
    return [
        ("table8/mmt-none",
         pretrain(make_scale(_sched(1e-2, steps), momentum_on=()), steps)),
        ("table8/mmt-last(SCALE)",
         pretrain(make_scale(_sched(1e-2, steps), momentum_on=("last",)), steps)),
        ("table8/mmt-first+last",
         pretrain(make_scale(_sched(1e-2, steps),
                             momentum_on=("first", "last")), steps)),
    ]


def table13(steps):
    s = _sched(1e-2, steps)
    return [
        ("table13/all-col(SCALE)", pretrain(make_scale(s), steps)),
        ("table13/col-last,row-rest",
         pretrain(make_scale(s, norm_last="col", norm_rest="row"), steps)),
        ("table13/row-first,col-rest",
         pretrain(make_scale(s, norm_first="row", norm_rest="col"), steps)),
        ("table13/norm-larger-dim",
         pretrain(make_scale(s, norm_last="larger", norm_rest="larger"), steps)),
        ("table13/row-last,col-rest",
         pretrain(make_scale(s, norm_last="row", norm_rest="col"), steps)),
    ]


def table11(steps):
    """Overtraining regime (paper Table 11): 1x / 2x / 4x token budgets."""
    rows = []
    for mult in (1, 2, 4):
        n = steps * mult
        for name in ("scale", "adam"):
            rows.append((f"table11/{name}/chinchilla_{mult}x",
                         pretrain(make_optimizer(name, _sched(LRS[name], n)), n)))
    return rows


def proxy_rows(quick: bool = True):
    steps = 60 if quick else 300
    rows = []
    tables = [table2, table3, table5, table8, table13] if not quick else \
        [table2, table5]
    for t in tables:
        for name, ppl in t(steps):
            rows.append((name, None, f"eval_ppl={ppl:.2f}"))
    return rows


# --------------------------------------------------------------------------
# Head-to-head registry sweep
# --------------------------------------------------------------------------

def _state_bytes(tx, params) -> int:
    """Measured optimizer-state bytes via eval_shape (no allocation)."""
    st = jax.eval_shape(tx.init, params)
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(st))


def _hbm_passes(name: str, params) -> int:
    """Analytic full-matrix HBM passes per step (fused_update convention:
    fused stateless 4 / momentum 6; unfused 6 / 9 per non-vector matrix),
    with 'fused' meaning the composition lowers to the Pallas kernels on
    TPU. Adam-style state counts as a momentum row."""
    labels = label_tree(params, LabelRules())
    total = 0
    for lab in jax.tree_util.tree_leaves(labels):
        if lab == "vector":
            continue
        if name in ("scale", "scale_fused"):
            stateful, fused = lab == "last", True
        elif name in ("sgd_colnorm", "sgd_rownorm"):
            stateful, fused = False, True
        elif name in ("sgd", "sgd_signnorm", "sgd_nsnorm", "sgd_svdnorm"):
            stateful, fused = False, False
        elif name == "swan":
            stateful, fused = lab in ("first", "last"), False
        else:  # momentum or Adam state on every non-vector group
            stateful, fused = True, False
        total += (6 if stateful else 4) if fused else (9 if stateful else 6)
    return total


def head_to_head(steps: int = 60, seq: int = 64, batch: int = 16,
                 time_iters: int = 3):
    """One record per registry optimizer; see the module docstring."""
    shapes_1b = param_shapes(get_arch("llama-1b"))
    records = []
    with fused_off_unless_tpu():
        for name, spec in OPTIMIZER_REGISTRY.items():
            kw = dict(PROXY_KW.get(name, {}))
            tx = make_optimizer(name, _sched(LRS.get(name, 3e-3), steps),
                                **kw)
            state, step_fn, ds, loss = _train(tx, steps, seq=seq,
                                              batch=batch)
            ppl = _eval_ppl(state, ds)
            us = time_call(step_fn, state, ds.host_batch_at(0),
                           warmup=1, iters=time_iters)
            method = ACCOUNTING.get(name, name)
            records.append({
                "optimizer": name,
                "fused": spec.fused,
                "final_loss": round(loss, 4),
                "eval_ppl": round(ppl, 3),
                "state_bytes": _state_bytes(tx, state.params),
                "llama1b_gb": round(
                    memory_report(shapes_1b, method).gb()[2], 3),
                "step_time_us": round(us, 1),
                "hbm_passes": _hbm_passes(name, state.params),
            })
    return records


def head_to_head_rows(records):
    return [(f"optimizers/{r['optimizer']}", r["step_time_us"],
             f"loss={r['final_loss']} ppl={r['eval_ppl']} "
             f"state={r['state_bytes']}B llama1b={r['llama1b_gb']}G "
             f"hbm={r['hbm_passes']} fused={r['fused']}")
            for r in records]


def run(quick: bool = True):
    """benchmarks.run section: the head-to-head sweep (quick = tiny)."""
    steps, seq, batch = (10, 32, 8) if quick else (60, 64, 16)
    return head_to_head_rows(head_to_head(steps, seq=seq, batch=batch))


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="10 steps, seq 32, batch 8 (CI bench-smoke)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--json", default=None,
                    help="write BENCH_optimizers.json-style artifact here")
    ap.add_argument("--table", default="",
                    help="also run proxy tables: comma list of 2,3,5,8,11,13")
    ap.add_argument("--memory", action="store_true",
                    help="also emit the analytic Table-4 memory rows")
    a = ap.parse_args(argv)

    steps, seq, batch = (10, 32, 8) if a.tiny else (a.steps, 64, 16)
    records = head_to_head(steps, seq=seq, batch=batch)
    rows = head_to_head_rows(records)
    if a.memory:
        rows += memory_rows(quick=not a.tiny)
    if a.table:
        fns = {"2": table2, "3": table3, "5": table5, "8": table8,
               "11": table11, "13": table13}
        for t in a.table.split(","):
            rows += [(n, None, f"eval_ppl={p:.2f}")
                     for n, p in fns[t](steps)]
    emit(rows)
    if a.json:
        doc = {"schema": "optimizer_bench/v1",
               "config": {"steps": steps, "seq": seq, "batch": batch,
                          "backend": jax.devices()[0].platform},
               "rows": records}
        with open(a.json, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print(f"# wrote {a.json}")


if __name__ == "__main__":
    main()
