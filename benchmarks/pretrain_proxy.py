"""Back-compat shim: the pretraining proxies moved into
``benchmarks/optimizer_bench.py`` (the merged head-to-head harness).

Keeps the public surface (``pretrain``, ``proxy_cfg``, ``_sched``, ``LRS``,
``table*``, ``run``) that ``examples/compare_optimizers.py`` and
``benchmarks/variance_analysis.py`` import, and forwards the CLI — including
the ``--tiny`` / ``--json`` bench-smoke flags — to the merged harness.
"""
from __future__ import annotations

from .optimizer_bench import (LRS, PROXY_KW, _sched, pretrain, proxy_cfg,
                              proxy_rows, table2, table3, table5, table8,
                              table11, table13)


def run(quick: bool = True):
    return proxy_rows(quick=quick)


if __name__ == "__main__":
    import sys

    from .common import emit
    argv = sys.argv[1:]
    if "--tiny" in argv or "--json" in argv:
        # bench-smoke path: defer to the merged head-to-head harness
        from .optimizer_bench import main
        main(argv)
        sys.exit(0)
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="all")
    ap.add_argument("--steps", type=int, default=300)
    a = ap.parse_args(argv)
    fns = {"2": table2, "3": table3, "5": table5, "8": table8, "11": table11,
           "13": table13}
    todo = fns.values() if a.table == "all" else [fns[a.table]]
    rows = []
    for t in todo:
        rows += [(n, None, f"eval_ppl={p:.2f}") for n, p in t(a.steps)]
    emit(rows)
