"""CPU-scale pretraining proxies for the paper's perplexity tables.

Table 2 (normalization ablations), Table 3 (norm + last-layer momentum),
Table 5 (main comparison), Table 8 (first+last momentum), Table 13 (mixed
normalization schemes). A scaled-down LLaMA trains on the synthetic C4 proxy
(Zipf marginal + learnable bigram) for a few hundred steps; we report eval
perplexity. The claim validated is the *ordering* the paper reports, not the
absolute C4 numbers (no C4 offline).
"""
from __future__ import annotations

import jax

from repro.core import linear_warmup_cosine, make_optimizer
from repro.core.scale import scale as make_scale
from repro.data import make_dataset
from repro.models import ModelConfig, init_params
from repro.training import init_state, make_eval_step, make_train_step


def proxy_cfg():
    return ModelConfig(name="llama-proxy", family="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=344,
                       vocab_size=512, dtype="float32", attn_kv_block=64,
                       attn_q_block=64, loss_chunk=64)


def pretrain(tx, steps: int, seed: int = 0, seq: int = 64, batch: int = 16):
    cfg = proxy_cfg()
    state = init_state(init_params(jax.random.PRNGKey(seed), cfg), tx)
    step_fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
    ds = make_dataset(cfg, seq_len=seq, global_batch=batch, seed=seed)
    for i in range(steps):
        state, _ = step_fn(state, ds.host_batch_at(i))
    ev = jax.jit(make_eval_step(cfg))
    ppl = 0.0
    for j in range(4):
        ppl += float(ev(state.params, ds.host_batch_at(100_000 + j))["perplexity"])
    return ppl / 4


# per-method peak lr, mirroring the paper's per-optimizer sweeps (App. C).
# Normalized-SGD updates have per-column magnitude == lr, so their optimum
# sits ~3x higher than Adam's on this proxy.
LRS = {"sgd": 1e-1, "adam": 3e-3, "stable_spam": 3e-3, "muon": 3e-3,
       "swan": 3e-3, "galore": 3e-3, "fira": 3e-3, "apollo": 3e-3,
       "apollo_mini": 3e-3, "scale": 1e-2, "sgd_colnorm": 1e-2,
       "sgd_rownorm": 1e-2, "sgd_signnorm": 3e-3, "sgd_nsnorm": 1e-2}


def _sched(lr, steps):
    return linear_warmup_cosine(lr, steps)


def table2(steps):
    out = []
    for name in ("sgd_colnorm", "sgd_rownorm", "sgd_signnorm", "sgd_nsnorm",
                 "adam"):
        out.append((f"table2/{name}",
                    pretrain(make_optimizer(name, _sched(LRS[name], steps)),
                             steps)))
    return out


def table3(steps):
    rows = []
    rows.append(("table3/colnorm+mmt-last(SCALE)",
                 pretrain(make_optimizer("scale", _sched(1e-2, steps)), steps)))
    rows.append(("table3/nsnorm+mmt-last",
                 pretrain(make_scale(_sched(3e-3, steps), norm_rest="ns",
                                     norm_last="ns"), steps)))
    return rows


def table5(steps):
    rows = []
    opts = [("scale", {}), ("adam", {}), ("stable_spam", {}), ("muon", {}),
            ("sgd", {}), ("galore", {"rank": 16}), ("fira", {"rank": 16}),
            ("apollo", {"rank": 16}), ("apollo_mini", {}), ("swan", {})]
    for name, kw in opts:
        rows.append((f"table5/{name}",
                     pretrain(make_optimizer(name, _sched(LRS[name], steps),
                                             **kw), steps)))
    return rows


def table8(steps):
    return [
        ("table8/mmt-none",
         pretrain(make_scale(_sched(1e-2, steps), momentum_on=()), steps)),
        ("table8/mmt-last(SCALE)",
         pretrain(make_scale(_sched(1e-2, steps), momentum_on=("last",)), steps)),
        ("table8/mmt-first+last",
         pretrain(make_scale(_sched(1e-2, steps),
                             momentum_on=("first", "last")), steps)),
    ]


def table13(steps):
    s = _sched(1e-2, steps)
    return [
        ("table13/all-col(SCALE)", pretrain(make_scale(s), steps)),
        ("table13/col-last,row-rest",
         pretrain(make_scale(s, norm_last="col", norm_rest="row"), steps)),
        ("table13/row-first,col-rest",
         pretrain(make_scale(s, norm_first="row", norm_rest="col"), steps)),
        ("table13/norm-larger-dim",
         pretrain(make_scale(s, norm_last="larger", norm_rest="larger"), steps)),
        ("table13/row-last,col-rest",
         pretrain(make_scale(s, norm_last="row", norm_rest="col"), steps)),
    ]


def table11(steps):
    """Overtraining regime (paper Table 11): 1x / 2x / 4x token budgets."""
    rows = []
    for mult in (1, 2, 4):
        n = steps * mult
        for name in ("scale", "adam"):
            rows.append((f"table11/{name}/chinchilla_{mult}x",
                         pretrain(make_optimizer(name, _sched(LRS[name], n)), n)))
    return rows


def run(quick: bool = True):
    steps = 60 if quick else 300
    rows = []
    tables = [table2, table3, table5, table8, table13] if not quick else \
        [table2, table5]
    for t in tables:
        for name, ppl in t(steps):
            rows.append((name, None, f"eval_ppl={ppl:.2f}"))
    return rows


if __name__ == "__main__":
    import argparse
    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="all")
    ap.add_argument("--steps", type=int, default=300)
    a = ap.parse_args()
    fns = {"2": table2, "3": table3, "5": table5, "8": table8, "11": table11,
           "13": table13}
    todo = fns.values() if a.table == "all" else [fns[a.table]]
    rows = []
    for t in todo:
        rows += [(n, None, f"eval_ppl={p:.2f}") for n, p in t(a.steps)]
    emit(rows)
