"""Fused vs. unfused SCALE step: wall time and HBM-pass accounting.

The SCALE update is bandwidth-bound, so the figure of merit is how many
times each matrix parameter (and its gradient) streams through HBM per
step. One pass = one full-matrix read or write (per-slice norm vectors are
noise); the convention matches :mod:`repro.kernels.dispatch`:

  stateless matrix:
      unfused: g r (sumsq); g r, gn w; theta r, gn r, theta w   = 6
      fused:   g r (sumsq); theta r, g r, theta w               = 4
      (apply stage = exactly 3: theta read, grad read, theta write)
  momentum matrix:
      unfused: m r, g r, m' w; m' r (sumsq); m' r, d w;
               theta r, d r, theta w                            = 9
      fused:   m r, g r, m' w (EMA+sumsq); theta r, m' r,
               theta w                                          = 6

On TPU the fused path runs compiled kernels; on CPU, where the Pallas
interpreter would dominate wall time, the timing section compares the two
*code paths* with ``REPRO_FUSED=off`` so both run XLA-compiled jnp — i.e.
it measures the update-tree materialization + second apply pass that
``update_params`` removes, which is exactly the structural difference that
persists on every backend. Pass counts are reported alongside as derived
values.

``--sharded`` runs the mesh variant: params/grads are sharded over a
``("data", "model")`` host mesh (row-sharded where divisible), the fused
step gets the sharding tree + a folded clip factor, and the accounting is
**per shard** — each device streams only its 1/N of every matrix, the
norm reductions psum one per-slice vector over ICI, the clip factor rides
inside the kernels (no grad rescale pass), and theta is written through
``input_output_aliases`` (no fresh allocation). Run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see a real
multi-shard mesh on CPU.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import apply_updates, make_optimizer
from repro.launch.mesh import make_host_mesh

from .common import fused_off_unless_tpu, time_call

# a LLaMA-60M-ish parameter census at benchmark scale: ragged head,
# stacked scan layers, odd MLP dims — everything the dispatch must cover
def _params(vocab=4099, d=256, layers=4, d_ff=683):
    k = jax.random.split(jax.random.PRNGKey(0), 5)
    return {
        "tok_embed": {"w": jax.random.normal(k[0], (vocab, d))},
        "layers": {
            "wqkv": jax.random.normal(k[1], (layers, d, 3 * d)),
            "w_up": jax.random.normal(k[2], (layers, d, d_ff)),
            "w_down": jax.random.normal(k[3], (layers, d_ff, d)),
        },
        "norm": {"s": jnp.ones((d,))},
        "lm_head": {"w": jax.random.normal(k[4], (d, vocab))},
    }


def hbm_passes(params, fused: bool, rules=None) -> int:
    """Analytic full-matrix HBM passes per step (matrix params only)."""
    from repro.core.labels import LabelRules, label_tree

    labels = label_tree(params, rules or LabelRules())
    total = 0
    for lab in jax.tree_util.tree_leaves(labels):
        if lab == "vector":
            continue
        momentum = lab == "last"  # the only momentum_on group by default
        if fused:
            total += 6 if momentum else 4
        else:
            total += 9 if momentum else 6
    return total


def run(quick: bool = True):
    params = _params() if quick else _params(vocab=32003, d=512, layers=8)
    grads = jax.tree_util.tree_map(
        lambda p: 0.1 * jnp.ones_like(p) + 0.01 * p, params)
    rows = []
    with fused_off_unless_tpu():
        # disclose what was actually measured: backend plus the effective
        # REPRO_FUSED mode (a user-exported 'off' on TPU — the miscompile
        # escape hatch — means the 'fused' row ran the jnp write path)
        rows.append(("fused/mode", None,
                     f"backend={jax.devices()[0].platform} "
                     f"REPRO_FUSED={os.environ.get('REPRO_FUSED', 'auto')}"))
        tx_ref = make_optimizer("scale", 1e-2)
        tx_fused = make_optimizer("scale", 1e-2, impl="fused")

        @jax.jit
        def step_unfused(p, g, s):
            upd, s = tx_ref.update(g, s, p)
            return apply_updates(p, upd), s

        @jax.jit
        def step_fused(p, g, s):
            return tx_fused.update_params(g, s, p)

        s0 = tx_ref.init(params)
        us_unfused = time_call(step_unfused, params, grads, s0, iters=7)
        us_fused = time_call(step_fused, params, grads,
                             tx_fused.init(params), iters=7)
    p_unfused = hbm_passes(params, fused=False)
    p_fused = hbm_passes(params, fused=True)
    rows.append(("fused/step_unfused", round(us_unfused, 1),
                 f"hbm_passes={p_unfused}"))
    rows.append(("fused/step_fused", round(us_fused, 1),
                 f"hbm_passes={p_fused}"))
    rows.append(("fused/speedup", None,
                 f"{us_unfused / max(us_fused, 1e-9):.2f}x"))
    # per-matrix accounting; the apply stage meets the <=3-pass bound
    # (theta read, grad read, theta write) and the norm reduction adds
    # one grad read on top (see module docstring)
    rows.append(("fused/passes_per_stateless_matrix", None,
                 "4 (apply stage 3: theta r, grad r, theta w)"))
    rows.append(("fused/passes_per_momentum_matrix", None, "6"))
    return rows


def _row_shardings(params, mesh):
    """Row-shard matrix leaves over the mesh's "data" axis where divisible
    (the FSDP layout the default rules table produces for weights)."""
    data = mesh.shape["data"]

    def leaf(p):
        if p.ndim == 2 and p.shape[0] % data == 0:
            spec = P("data", None)
        elif p.ndim == 3 and p.shape[1] % data == 0:
            spec = P(None, "data", None)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(leaf, params)


def run_sharded(quick: bool = True):
    """Sharded fused step: per-shard HBM-pass accounting + parity check."""
    import numpy as np

    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=n_dev)
    params = _params() if quick else _params(vocab=32003, d=512, layers=8)
    grads = jax.tree_util.tree_map(
        lambda p: 0.1 * jnp.ones_like(p) + 0.01 * p, params)
    shardings = _row_shardings(params, mesh)
    params_s = jax.device_put(params, shardings)
    grads_s = jax.device_put(grads, shardings)
    clip = jnp.asarray(0.5, jnp.float32)  # pretend clip factor to fold

    rows = [("fused_sharded/mesh", None,
             f"devices={n_dev} data={mesh.shape['data']} "
             f"model={mesh.shape['model']} "
             f"REPRO_FUSED={os.environ.get('REPRO_FUSED', 'auto')}")]

    # correctness: sharded fused step == single-device jnp reference with
    # clip-then-update (runs the real kernels — interpret mode off-TPU)
    tx_fused = make_optimizer("scale", 1e-2, impl="fused")
    tx_ref = make_optimizer("scale", 1e-2)
    s0 = tx_ref.init(params)
    p_ref, _ = tx_ref.update_params(
        jax.tree_util.tree_map(lambda g: g * clip, grads), s0, params)
    p_sh, _ = tx_fused.update_params(grads_s, tx_fused.init(params_s),
                                     params_s, shardings=shardings,
                                     grad_scale=clip)
    err = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                    - b.astype(jnp.float32))))
              for a, b in zip(jax.tree_util.tree_leaves(p_sh),
                              jax.tree_util.tree_leaves(p_ref)))
    assert np.isfinite(err) and err < 1e-4, err
    rows.append(("fused_sharded/parity_max_abs_err", None, f"{err:.2e}"))

    # timing: structural comparison under compiled XLA (see module docstring)
    with fused_off_unless_tpu():
        @jax.jit
        def step_sharded(p, g, s):
            return tx_fused.update_params(g, s, p, shardings=shardings,
                                          grad_scale=clip)

        @jax.jit
        def step_clip_pass(p, g, s):
            g = jax.tree_util.tree_map(lambda x: x * clip, g)
            upd, s = tx_ref.update(g, s, p)
            return apply_updates(p, upd), s

        us_fused = time_call(step_sharded, params_s, grads_s,
                             tx_fused.init(params_s), iters=7)
        us_unfused = time_call(step_clip_pass, params_s, grads_s,
                               tx_ref.init(params_s), iters=7)

    # per-shard accounting: every pass streams only the local 1/data shard
    # of the matrix; the psum moves a per-slice vector (noise)
    p_fused = hbm_passes(params, fused=True)
    p_unfused = hbm_passes(params, fused=False)
    frac = f"1/{mesh.shape['data']}"
    rows += [
        ("fused_sharded/step_clip_then_unfused", round(us_unfused, 1),
         f"hbm_passes={p_unfused}+2 (clip adds grad r + grad w)"),
        ("fused_sharded/step_fused", round(us_fused, 1),
         f"hbm_passes={p_fused} (clip folded: 0 extra passes)"),
        ("fused_sharded/speedup", None,
         f"{us_unfused / max(us_fused, 1e-9):.2f}x"),
        ("fused_sharded/passes_per_stateless_matrix_per_shard", None,
         f"4 over the local {frac} shard "
         "(apply stage 3: theta r, grad r, theta w)"),
        ("fused_sharded/passes_per_momentum_matrix_per_shard", None,
         f"6 over the local {frac} shard"),
        ("fused_sharded/clip", None,
         "folded into the kernels' gradient read (grad_scale) — "
         "no separate rescale pass"),
        ("fused_sharded/theta_alloc", None,
         "in-place via input_output_aliases (+ donate_argnums on the "
         "train step) — no fresh theta buffer"),
        ("fused_sharded/norm_reduction_comms", None,
         "lax.psum of the per-slice sumsq vector over the reduce-dim mesh "
         "axes (~1/256 of a matrix per step)"),
    ]
    return rows


if __name__ == "__main__":
    import sys

    from .common import emit, json_arg
    if "--sharded" in sys.argv:
        # quick census by default: the parity check runs the real kernels,
        # which off-TPU means the Pallas interpreter (--full on TPU)
        emit(run_sharded(quick="--full" not in sys.argv),
             json_path=json_arg(sys.argv))
    else:
        emit(run(quick="--quick" in sys.argv), json_path=json_arg(sys.argv))
