"""End-to-end behaviour tests: the full story the paper tells, at CPU scale.

1. Plain SGD stalls; SCALE converges (Fig. 2).
2. SCALE matches Adam's perplexity ballpark with a fraction of the
   optimizer-state memory (Table 5 + Appendix B).
3. Training is fault-tolerant: kill + auto-resume is bitwise identical.
"""
import jax
import numpy as np

from repro.core import make_optimizer, memory_report
from repro.data import make_dataset
from repro.models import init_params, param_shapes
from repro.training import init_state, make_eval_step, make_train_step


def pretrain(cfg, opt, steps=40, lr=3e-3, seed=0):
    tx = make_optimizer(opt, lr)
    state = init_state(init_params(jax.random.PRNGKey(seed), cfg), tx)
    step_fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
    ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=seed)
    for i in range(steps):
        state, m = step_fn(state, ds.host_batch_at(i))
    ev = jax.jit(make_eval_step(cfg))
    out = ev(state.params, ds.host_batch_at(10_000))
    return float(out["perplexity"]), state


def test_paper_story_end_to_end(tiny):
    # per-method lr tuning, as in the paper's sweeps (App. C): SCALE's
    # per-column update magnitude is exactly lr, so its optimum sits higher
    ppl_scale, state = pretrain(tiny, "scale", lr=1e-2)
    ppl_sgd, _ = pretrain(tiny, "sgd", lr=1e-1)
    ppl_adam, _ = pretrain(tiny, "adam", lr=3e-3)

    # Fig. 2: plain SGD is far off; SCALE is Adam-class
    assert ppl_scale < 0.6 * ppl_sgd
    assert ppl_scale < 2.0 * ppl_adam

    # Appendix B at this scale: SCALE state is tiny vs Adam's 2x params
    shapes = param_shapes(tiny)
    assert memory_report(shapes, "scale").state_bytes < \
        0.35 * memory_report(shapes, "adam").state_bytes

    # the momentum buffer exists only for the head
    assert state.opt_state.mu["lm_head"]["w"].size > 0
    assert state.opt_state.mu["segments"]["seg0_dense"]["attn"]["wq"].size == 0


def test_memory_efficient_baselines_run(tiny):
    """Every paper baseline trains the same tiny model without NaNs."""
    for opt in ("galore", "fira", "apollo", "apollo_mini", "muon",
                "stable_spam", "swan"):
        ppl, _ = pretrain(tiny, opt, steps=8, lr=1e-3)
        assert np.isfinite(ppl), opt
