"""Launch-layer units that don't need 512 devices: sharding rules, HLO cost
parser, roofline math, input specs."""
import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch import hlo_analysis as HA
from repro.launch import hlo_cost as HC
from repro.models.sharding import Rules


class FakeMesh:
    """Stands in for a (data=16, model=16) mesh in rule resolution."""
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_rules_divisibility_guard():
    r = Rules()
    mesh = FakeMesh()
    # 8 kv heads cannot shard over 16-way model axis -> replicated
    spec = r.spec(("act_heads",), mesh, (8,))
    assert spec == P(None)
    spec = r.spec(("act_heads",), mesh, (64,))
    assert spec == P("model")
    # multi-axis batch rule with missing 'pod' axis silently drops it
    spec = r.spec(("act_batch",), mesh, (256,))
    assert spec == P("data")


def test_rules_overrides():
    r = Rules(overrides=(("act_seq", ("data",)),))
    spec = r.spec(("act_seq",), FakeMesh(), (4096,))
    assert spec == P("data")


SAMPLE_HLO = """
HloModule test

%inner (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %c = f32[64,64]{1,0} constant({...})
  %dot.1 = f32[128,64]{1,0} dot(%p0, %c), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %ar = f32[128,64]{1,0} all-reduce(%dot.1), replica_groups={{0,1,2,3}}, to_apply=%add
}

%body (p: (s32[], f32[128,64])) -> (s32[], f32[128,64]) {
  %p = (s32[], f32[128,64]{1,0}) parameter(0)
  %g = f32[128,64]{1,0} get-tuple-element(%p), index=1
  %call.1 = f32[128,64]{1,0} call(%g), to_apply=%inner
  ROOT %t = (s32[], f32[128,64]{1,0}) tuple(%g, %call.1)
}

ENTRY %main (a: f32[128,64]) -> f32[128,64] {
  %a = f32[128,64]{1,0} parameter(0)
  %w = (s32[], f32[128,64]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_cost_while_multiplier():
    cost = HC.analyze(SAMPLE_HLO)
    # dot: 2*128*64*64 flops, x10 trips
    assert cost.flops == 10 * 2 * 128 * 64 * 64
    # all-reduce: 2*bytes*(g-1)/g with g=4, x10
    out_bytes = 128 * 64 * 4
    assert abs(cost.coll_bytes["all-reduce"] - 10 * 2 * out_bytes * 3 / 4) < 1
    assert cost.coll_counts["all-reduce"] == 10


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes_accessed": 819e9 / 2,
            "transcendentals": 0}
    coll = HA.CollectiveStats({"all-reduce": 50e9 * 2}, {"all-reduce": 1})
    roof = HA.roofline(cost, coll, model_flops=197e12 * 256 * 0.5,
                       n_chips=256)
    assert abs(roof["compute_s"] - 1.0) < 1e-9
    assert abs(roof["memory_s"] - 0.5) < 1e-9
    assert abs(roof["collective_s"] - 2.0) < 1e-9
    assert roof["bottleneck"] == "collective_s"
    assert abs(roof["useful_flop_ratio"] - 0.5) < 1e-9


def test_model_flops_kinds():
    from repro.configs import get_arch
    cfg = get_arch("qwen2-7b")
    t = HA.model_flops_for(cfg, "train", 4096, 256)
    p = HA.model_flops_for(cfg, "prefill", 4096, 256)
    d = HA.model_flops_for(cfg, "decode", 4096, 256)
    assert abs(t / p - 3.0) < 1e-6      # 6ND vs 2ND
    assert d < p / 1000                 # one token per sequence


def test_collective_stats_regex_group_formats():
    txt = ('%ag = bf16[1024]{0} all-gather(%x), replica_groups=[8,2]<=[16]\n'
           '%ar = f32[256,4]{1,0} all-reduce(%y), replica_groups={{0,1}}\n')
    st = HA.collective_stats(txt)
    assert st.bytes_by_kind["all-gather"] == 1024 * 2
    assert st.bytes_by_kind["all-reduce"] == 2 * 256 * 4 * 4 // 2
