"""Resilience chaos matrix: in-jit anomaly guard, hardened checkpoint
recovery, and the REPRO_FAULTS fault-injection harness end to end.

The invariants under test (ISSUE 8 acceptance):
  * NaN/Inf grads injected at step k -> the run completes and its params +
    optimizer state are **bitwise** equal to a clean run with step k's
    batch dropped (the guard's element-select passthrough);
  * a corrupted-latest checkpoint costs one checkpoint interval, not the
    run (restore_latest degrades to the newest verifiable committed step);
  * a simulated kill mid-commit never leaves a COMMITTED step that fails
    verification;
  * a forced kernel-dispatch failure degrades to the jnp reference path,
    logged once.
"""
import json
import os
import signal
import subprocess
import sys
import warnings
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint as ckpt
from repro.core import make_optimizer
from repro.data import make_dataset
from repro.kernels import dispatch
from repro.models import init_params
from repro.training import (GuardPolicy, SimulatedKill, faults,
                            guard_step, guard_verdict, init_guard_state,
                            init_state, make_train_step, parse_faults,
                            resolve_plan)


@pytest.fixture(autouse=True)
def _clean_fault_state():
    """Every case starts and ends with rewound fault counters and fallback
    tallies (REPRO_FAULTS itself is scoped per-test via monkeypatch)."""
    faults.reset()
    dispatch.reset_fallbacks()
    yield
    faults.reset()
    dispatch.reset_fallbacks()


# --------------------------------------------------------------------------
# REPRO_FAULTS grammar
# --------------------------------------------------------------------------

def test_parse_faults_grammar_roundtrip():
    p = parse_faults("nan_grad@3;inf_grad@5; io_error@save:2 ;"
                     "kill@commit:1;dispatch_fail@norm_update")
    assert p.grad_fault_steps("nan") == (3,)
    assert p.grad_fault_steps("inf") == (5,)
    assert p.any_grad_faults
    assert p.io_errors == (("save", 2),)
    assert p.kills == (("commit", 1),)
    assert p.dispatch_ops == ("norm_update",)


@pytest.mark.parametrize("bad", [
    "nan_grad",                # no @arg
    "nan_grad@x",              # non-integer step
    "nan_grad@-1",             # negative step
    "nan_grad@3:4",            # grad faults take exactly one arg
    "io_error@tmp:1",          # unknown site
    "kill@save",               # missing occurrence count
    "dispatch_fail@",          # empty op
    "frobnicate@1",            # unknown kind
])
def test_parse_faults_rejects_bad_clauses(bad):
    with pytest.raises(ValueError, match="REPRO_FAULTS"):
        parse_faults(bad)


def test_resolve_plan_none_when_unset(monkeypatch):
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    assert resolve_plan() is None
    monkeypatch.setenv(faults.ENV_VAR, "  ")
    assert resolve_plan() is None
    monkeypatch.setenv(faults.ENV_VAR, "nan_grad@7")
    assert resolve_plan().grad_fault_steps("nan") == (7,)


# --------------------------------------------------------------------------
# Guard unit behavior (pure scalar arithmetic, no training loop)
# --------------------------------------------------------------------------

def test_guard_verdict_finite_checks():
    policy = GuardPolicy()
    gs = init_guard_state()
    ok = guard_verdict(policy, gs, jnp.float32(1.0), jnp.float32(2.0))
    assert bool(ok)
    for loss, gnorm in [(jnp.nan, 1.0), (1.0, jnp.nan),
                        (jnp.inf, 1.0), (1.0, jnp.inf)]:
        assert not bool(guard_verdict(policy, gs, jnp.float32(loss),
                                      jnp.float32(gnorm)))


def test_guard_spike_detection_arms_after_warmup():
    policy = GuardPolicy(spike_factor=2.0, spike_warmup=2, ema_beta=0.5)
    gs = init_guard_state()
    # before any accepted step the spike check is unarmed: a huge finite
    # loss passes (a fresh run's first losses are legitimately huge)
    assert bool(guard_verdict(policy, gs, jnp.float32(100.0),
                              jnp.float32(1.0)))
    for _ in range(3):
        ok = guard_verdict(policy, gs, jnp.float32(1.0), jnp.float32(1.0))
        gs, rb = guard_step(policy, gs, ok, jnp.float32(1.0))
        assert not bool(rb)
    # debiased EMA of three accepted 1.0 losses is 1.0
    np.testing.assert_allclose(float(gs.loss_ema) / (1 - 0.5 ** 3), 1.0)
    assert not bool(guard_verdict(policy, gs, jnp.float32(5.0),
                                  jnp.float32(1.0)))  # 5 > 2*1: spike
    assert bool(guard_verdict(policy, gs, jnp.float32(1.5),
                              jnp.float32(1.0)))      # 1.5 <= 2*1: calm


def test_guard_streak_and_rollback_flag():
    policy = GuardPolicy(max_bad_steps=2)
    gs = init_guard_state()
    bad, good = jnp.zeros((), bool), jnp.ones((), bool)
    gs, rb = guard_step(policy, gs, bad, jnp.float32(jnp.nan))
    assert (int(gs.consecutive_bad), int(gs.skipped), bool(rb)) == (1, 1, False)
    gs, rb = guard_step(policy, gs, bad, jnp.float32(jnp.nan))
    assert (int(gs.consecutive_bad), int(gs.skipped), bool(rb)) == (2, 2, True)
    gs, rb = guard_step(policy, gs, good, jnp.float32(1.0))
    assert (int(gs.consecutive_bad), int(gs.skipped), bool(rb)) == (0, 2, False)
    # the bad loss never poisons the EMA (only the accepted 1.0 entered)
    np.testing.assert_allclose(float(gs.loss_ema), 0.01, rtol=1e-5)


def test_guard_requires_guard_carrying_state(tiny):
    tx = make_optimizer("scale", 1e-3)
    params = init_params(jax.random.PRNGKey(0), tiny)
    state = init_state(params, tx)  # guard=False: no GuardState leaves
    step_fn = make_train_step(tiny, tx, guard=GuardPolicy())
    ds = make_dataset(tiny, seq_len=32, global_batch=8, seed=0)
    with pytest.raises(ValueError, match="guard-carrying"):
        step_fn(state, ds.host_batch_at(0))


# --------------------------------------------------------------------------
# The acceptance invariant: injected grad fault at step k == clean run
# minus that step, bitwise
# --------------------------------------------------------------------------

def _guarded_run(cfg, batch_ids, plan=None):
    tx = make_optimizer("scale", 3e-3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    state = init_state(params, tx, guard=True)
    step_fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0,
                                      guard=GuardPolicy(), faults=plan))
    ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=0)
    metrics = {}
    for i in batch_ids:
        state, metrics = step_fn(state, ds.host_batch_at(i))
    return state, metrics


@pytest.mark.parametrize("kind", ["nan_grad", "inf_grad"])
def test_injected_grad_fault_skips_step_bitwise(tiny, kind):
    """Faulted 8-step run == clean run that never saw step 3's batch,
    bitwise on params AND optimizer state (the element-select passthrough
    leaves the old buffers untouched; the candidate NaN update and the
    discarded loss never leak into anything)."""
    faulted, fm = _guarded_run(tiny, range(8),
                               plan=parse_faults(f"{kind}@3"))
    clean, _ = _guarded_run(tiny, [0, 1, 2, 4, 5, 6, 7])
    assert int(fm["skipped"]) == 1
    assert not bool(fm["rollback"])
    for name, tree_f, tree_c in [("params", faulted.params, clean.params),
                                 ("opt_state", faulted.opt_state,
                                  clean.opt_state)]:
        for a, b in zip(jax.tree_util.tree_leaves(tree_f),
                        jax.tree_util.tree_leaves(tree_c)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)
    # the faulted run still advanced its step counter through the skip
    assert int(faulted.step) == 8 and int(clean.step) == 7


def test_faulted_build_is_bitwise_inert_off_the_fault_step(tiny):
    """A train step built WITH a fault plan matches the clean build bitwise
    on every non-fault step (the traced select is inert when step != k)."""
    faulted, _ = _guarded_run(tiny, range(3), plan=parse_faults("nan_grad@9"))
    clean, _ = _guarded_run(tiny, range(3))
    for a, b in zip(jax.tree_util.tree_leaves(faulted.params),
                    jax.tree_util.tree_leaves(clean.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_guarded_state_checkpoint_roundtrip(tiny, tmp_path):
    """TrainState.guard leaves survive save/restore_latest exactly."""
    state, _ = _guarded_run(tiny, range(2))
    ckpt.save(str(tmp_path), 2, state)
    restored, step = ckpt.restore_latest(str(tmp_path), state)
    assert step == 2
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Hardened checkpoint recovery
# --------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": {"w": jax.random.normal(k, (8, 16)),
                  "b": jnp.arange(5, dtype=jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def _corrupt_shard(step_dir):
    (shard,) = (os.path.join(step_dir, n) for n in os.listdir(step_dir)
                if n.startswith("shard_00000"))
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")


def _assert_committed_steps_verifiable(directory, like):
    """The atomicity invariant: every step dir carrying a COMMITTED marker
    must pass full verification — a kill at any injected point may lose a
    checkpoint but never corrupt a committed one."""
    for name in os.listdir(directory):
        full = os.path.join(directory, name)
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(full, "COMMITTED")):
            ckpt.restore(directory, int(name[5:]), like)


def test_restore_latest_degrades_past_corrupt_shard(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    path2 = ckpt.save(str(tmp_path), 2, tree)
    _corrupt_shard(path2)
    with pytest.warns(UserWarning, match="falling back"):
        got, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 1
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_none_when_all_corrupt(tmp_path):
    tree = _tree()
    _corrupt_shard(ckpt.save(str(tmp_path), 1, tree))
    with pytest.warns(UserWarning, match="falling back"):
        assert ckpt.restore_latest(str(tmp_path), tree) is None


def test_leaf_checksum_mismatch_names_the_leaf(tmp_path):
    """Per-leaf crc32s catch (and name) a corruption the shard-level crc
    cannot localize; here the manifest entry is tampered so the shard crc
    still passes and only the leaf check can object."""
    tree = _tree()
    path = ckpt.save(str(tmp_path), 4, tree)
    man_path = os.path.join(path, "manifest.00000.json")
    with open(man_path) as f:
        man = json.load(f)
    assert man["leaf_checksums"]  # the new field is present
    man["leaf_checksums"]["a/w"] += 1
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ckpt.CorruptCheckpointError, match="a/w"):
        ckpt.restore(str(tmp_path), 4, tree)
    # and restore_latest degrades across it like any other corruption
    with pytest.warns(UserWarning, match="falling back"):
        assert ckpt.restore_latest(str(tmp_path), tree) is None


def test_leaf_checksums_match_shard_contents(tmp_path):
    tree = _tree()
    path = ckpt.save(str(tmp_path), 1, tree)
    with open(os.path.join(path, "manifest.00000.json")) as f:
        man = json.load(f)
    assert man["leaf_checksums"]["a/b"] == zlib.crc32(
        np.asarray(tree["a"]["b"]).tobytes())


def test_io_errors_absorbed_by_retry(tmp_path, monkeypatch):
    tree = _tree()
    monkeypatch.setenv(faults.ENV_VAR, "io_error@save:2")
    with pytest.warns(UserWarning, match="retry"):
        ckpt.save(str(tmp_path), 1, tree, io_retries=3, io_backoff=0.01)
    monkeypatch.delenv(faults.ENV_VAR)
    got = ckpt.restore(str(tmp_path), 1, tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_io_errors_beyond_retry_budget_raise(tmp_path, monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "io_error@save:9")
    with pytest.warns(UserWarning, match="retry"):
        with pytest.raises(OSError, match="injected IO error"):
            ckpt.save(str(tmp_path), 1, _tree(), io_retries=2,
                      io_backoff=0.01)
    assert ckpt.latest_step(str(tmp_path)) is None


def test_commit_io_error_absorbed_by_retry(tmp_path, monkeypatch):
    tree = _tree()
    monkeypatch.setenv(faults.ENV_VAR, "io_error@commit:1")
    with pytest.warns(UserWarning, match="retry"):
        ckpt.save(str(tmp_path), 1, tree, io_retries=2, io_backoff=0.01)
    monkeypatch.delenv(faults.ENV_VAR)
    assert ckpt.latest_step(str(tmp_path)) == 1
    _assert_committed_steps_verifiable(str(tmp_path), tree)


def test_mid_commit_kill_never_yields_committed_step(tmp_path, monkeypatch):
    """Kill after the merged manifest but before the COMMITTED marker: the
    step is lost (never committed), the tree never half-committed, and the
    next save of the same step recovers fully."""
    tree = _tree()
    monkeypatch.setenv(faults.ENV_VAR, "kill@commit:1")
    with pytest.raises(SimulatedKill):
        ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) is None
    _assert_committed_steps_verifiable(str(tmp_path), tree)
    # retries must not have been able to absorb the kill
    monkeypatch.delenv(faults.ENV_VAR)
    faults.reset()
    ckpt.save(str(tmp_path), 5, tree)
    assert ckpt.latest_step(str(tmp_path)) == 5
    _assert_committed_steps_verifiable(str(tmp_path), tree)


def test_mid_save_kill_leaves_unvouched_shard_only(tmp_path, monkeypatch):
    """Kill between the shard write and this host's manifest: the tmp dir
    holds a shard no manifest vouches for; nothing is committed and a
    clean re-save overwrites the debris."""
    tree = _tree()
    monkeypatch.setenv(faults.ENV_VAR, "kill@save:1")
    with pytest.raises(SimulatedKill):
        ckpt.save(str(tmp_path), 3, tree)
    tmp_dir = str(tmp_path / "step_0000000003.tmp")
    assert os.path.isdir(tmp_dir)
    assert any(n.startswith("shard_") for n in os.listdir(tmp_dir))
    assert not any(n.startswith("manifest.") for n in os.listdir(tmp_dir))
    assert ckpt.latest_step(str(tmp_path)) is None
    monkeypatch.delenv(faults.ENV_VAR)
    faults.reset()
    ckpt.save(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    _assert_committed_steps_verifiable(str(tmp_path), tree)


def test_async_save_raises_from_wait_and_done(tmp_path, monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "io_error@save:9")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # worker retry warnings
        handle = ckpt.save_async(str(tmp_path), 1, _tree(), io_retries=0,
                                 io_backoff=0.0)
        with pytest.raises(OSError, match="injected IO error"):
            handle.wait()
    # the error keeps surfacing: done must raise too, never report a clean
    # True for a save that failed
    with pytest.raises(OSError, match="injected IO error"):
        handle.done


# --------------------------------------------------------------------------
# Forced kernel-dispatch failure -> reference-path degradation
# --------------------------------------------------------------------------

def test_dispatch_fault_degrades_to_reference(monkeypatch):
    g = jnp.asarray(np.random.RandomState(0).randn(32, 16), jnp.float32)
    th = jnp.asarray(np.random.RandomState(1).randn(32, 16), jnp.float32)
    monkeypatch.setenv("REPRO_FUSED", "off")
    ref = dispatch.norm_update(th, g, 0.01, "col")
    monkeypatch.setenv("REPRO_FUSED", "interpret")  # force the kernel route
    monkeypatch.setenv(faults.ENV_VAR, "dispatch_fail@norm_update")
    with pytest.warns(UserWarning, match="degrading to the jnp reference"):
        out = dispatch.norm_update(th, g, 0.01, "col")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert dispatch.fallback_counts() == {"norm_update": 1}
    # the warning fires once per (op, failure class); the count keeps going
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out2 = dispatch.norm_update(th, g, 0.01, "col")
    assert not any("degrading" in str(x.message) for x in w)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    assert dispatch.fallback_counts() == {"norm_update": 2}


def test_dispatch_fault_wildcard_hits_every_op(monkeypatch):
    g = jnp.asarray(np.random.RandomState(0).randn(32, 16), jnp.float32)
    monkeypatch.setenv("REPRO_FUSED", "off")
    ref = dispatch.normalize(g, "col")
    monkeypatch.setenv("REPRO_FUSED", "interpret")
    monkeypatch.setenv(faults.ENV_VAR, "dispatch_fail@*")
    with pytest.warns(UserWarning, match="degrading"):
        out = dispatch.normalize(g, "col")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert dispatch.fallback_counts().get("normalize") == 1


def test_guarded_train_step_survives_dispatch_fault(tiny, monkeypatch):
    """The full stack degrades gracefully: a train step whose optimizer
    kernels are forced to fail still trains (reference path), finite."""
    monkeypatch.setenv("REPRO_FUSED", "interpret")
    monkeypatch.setenv(faults.ENV_VAR, "dispatch_fail@*")
    with pytest.warns(UserWarning, match="degrading"):
        state, metrics = _guarded_run(tiny, range(2))
    assert np.isfinite(float(metrics["loss"]))
    assert int(metrics["skipped"]) == 0
    assert sum(dispatch.fallback_counts().values()) >= 1


# --------------------------------------------------------------------------
# Driver-level recovery (launch/train.py)
# --------------------------------------------------------------------------

def test_cli_skips_injected_nan_and_completes(tmp_path, monkeypatch, capsys):
    from repro.launch.train import main
    monkeypatch.setenv(faults.ENV_VAR, "nan_grad@2")
    loss = main(["--arch", "qwen2-7b", "--smoke", "--steps", "6",
                 "--batch", "4", "--seq", "32", "--log-every", "1",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "6"])
    out = capsys.readouterr().out
    assert np.isfinite(loss)
    assert "skipped 1" in out
    assert ckpt.latest_step(str(tmp_path)) == 6


def test_cli_rollback_without_checkpoint_cuts_lr_and_continues(
        monkeypatch, capsys):
    from repro.launch.train import main
    monkeypatch.setenv(faults.ENV_VAR, "nan_grad@2;nan_grad@3")
    loss = main(["--arch", "qwen2-7b", "--smoke", "--steps", "6",
                 "--batch", "4", "--seq", "32", "--log-every", "1",
                 "--max-bad-steps", "2"])
    out = capsys.readouterr().out
    assert np.isfinite(loss)
    assert "rollback #1" in out and "peak lr x0.5" in out


def test_cli_bounded_rollbacks_abort(tmp_path, monkeypatch):
    """Deterministic faults replay identically after a rollback restore —
    the driver must abort after --max-rollbacks instead of looping."""
    from repro.launch.train import main
    # checkpoints land at steps 2 and 4 (before the first fault), so every
    # rollback restores to step 4 and replays straight into the same two
    # injected faults: rollback #2 must abort, not loop
    monkeypatch.setenv(faults.ENV_VAR, "nan_grad@4;nan_grad@5")
    with pytest.raises(RuntimeError, match="giving up after 1 rollbacks"):
        main(["--arch", "qwen2-7b", "--smoke", "--steps", "8",
              "--batch", "4", "--seq", "32", "--log-every", "1",
              "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
              "--max-bad-steps", "2", "--max-rollbacks", "1"])


def _cli_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    env.pop("REPRO_FAULTS", None)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


def test_sigterm_writes_final_checkpoint_and_exits_cleanly(tmp_path):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-7b",
         "--smoke", "--steps", "100000", "--batch", "2", "--seq", "32",
         "--log-every", "1", "--ckpt-dir", str(tmp_path), "--ckpt-every",
         "100000", "--resume", "auto"],
        env=_cli_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    lines = []
    try:
        for line in proc.stdout:           # wait for the first real step
            lines.append(line)
            if line.startswith("step "):
                break
        else:
            pytest.fail("driver exited before its first step:\n"
                        + "".join(lines))
        proc.send_signal(signal.SIGTERM)
        lines.extend(proc.stdout)
        assert proc.wait(timeout=300) == 0, "".join(lines)
    finally:
        proc.kill()
    out = "".join(lines)
    assert "exiting cleanly" in out, out
    assert ckpt.latest_step(str(tmp_path)) is not None


def test_guard_skips_nan_step_under_forced_8_devices():
    """The guard's select passthrough under a real 8-way sharded mesh (the
    tier1-multidevice configuration): the skipped step leaves the sharded
    params finite and training continues."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_FAULTS"] = "nan_grad@1"
import jax, jax.numpy as jnp, numpy as np
from repro.core import make_optimizer
from repro.data import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig, init_params, param_logical_axes
from repro.models.sharding import Rules, tree_shardings
from repro.training import (GuardPolicy, init_state, make_train_step,
                            resolve_plan)

assert len(jax.devices()) == 8
cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  dtype="float32", attn_kv_block=16, attn_q_block=16,
                  loss_chunk=16)
mesh = make_host_mesh(data=8)
rules = Rules(cfg.rule_overrides)
tx = make_optimizer("scale", 1e-3)
params = init_params(jax.random.PRNGKey(0), cfg)
params = jax.device_put(params, tree_shardings(param_logical_axes(cfg),
                                               mesh, rules, params))
state = init_state(params, tx, guard=True)
step_fn = make_train_step(cfg, tx, clip_norm=1.0, rules=rules, mesh=mesh,
                          donate=True, guard=GuardPolicy(),
                          faults=resolve_plan())
ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=0)
m = {}
for i in range(3):
    state, m = step_fn(state, ds.host_batch_at(i))
assert int(m["skipped"]) == 1, m
assert np.isfinite(float(m["loss"])), m
for leaf in jax.tree_util.tree_leaves(state.params):
    assert np.isfinite(np.asarray(leaf)).all()
print("OK")
"""
    res = subprocess.run([sys.executable, "-c", script], env=_cli_env(),
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
