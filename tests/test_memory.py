"""Memory accounting must reproduce the paper's Appendix B / Table 4."""
import pytest

from repro.core import memory_report


def llama_shapes(d, ff, L, V):
    shapes = {"tok_embed": {"w": (V, d)}, "lm_head": {"w": (d, V)}}
    for i in range(L):
        shapes[f"layer_{i}"] = {
            "q": (d, d), "k": (d, d), "v": (d, d), "o": (d, d),
            "gate": (d, ff), "up": (d, ff), "down": (ff, d),
            "ln1": (d,), "ln2": (d,)}
    return shapes


SHAPES_7B = llama_shapes(4096, 11008, 32, 32000)
SHAPES_1B = llama_shapes(2048, 5461, 24, 32000)

# paper Appendix B (GB, decimal)
PAPER_7B = {"sgd": 13.476, "adam": 40.428, "muon": 26.952, "swan": 14.524,
            "apollo_mini": 14.531, "scale": 13.738}
PAPER_1B = {"sgd": 2.678, "adam": 8.034, "muon": 5.356, "swan": 3.202,
            "scale": 2.809}


@pytest.mark.parametrize("method,want", sorted(PAPER_7B.items()))
def test_7b_memory_matches_paper(method, want):
    total = memory_report(SHAPES_7B, method).gb()[2]
    assert abs(total - want) / want < 0.005, (method, total, want)


@pytest.mark.parametrize("method,want", sorted(PAPER_1B.items()))
def test_1b_memory_matches_paper(method, want):
    total = memory_report(SHAPES_1B, method).gb()[2]
    assert abs(total - want) / want < 0.005, (method, total, want)


def test_apollo_rank256_close_to_paper():
    # projector-shape convention differs slightly from the paper (DESIGN.md);
    # assert within 5%
    total = memory_report(SHAPES_7B, "apollo", rank=256).gb()[2]
    assert abs(total - 16.144) / 16.144 < 0.05


def test_method_ordering_1b():
    """Figure 1's memory ordering: scale < swan/apollo_mini < muon < adam."""
    t = {m: memory_report(SHAPES_1B, m).gb()[2]
         for m in ("scale", "swan", "muon", "adam", "sgd")}
    assert t["sgd"] < t["scale"] < t["swan"] < t["muon"] < t["adam"]


def test_scale_overhead_is_tiny():
    """Paper: SCALE adds ~2% over SGD at 7B, ~5% at 1B."""
    sgd7 = memory_report(SHAPES_7B, "sgd").gb()[2]
    scale7 = memory_report(SHAPES_7B, "scale").gb()[2]
    assert (scale7 - sgd7) / sgd7 < 0.03


def test_arch_zoo_memory_reports():
    """SCALE's relative saving vs Adam on every assigned architecture."""
    from repro.configs import ARCH_IDS, get_arch
    from repro.models import param_shapes
    for arch in ARCH_IDS:
        shapes = param_shapes(get_arch(arch))
        adam = memory_report(shapes, "adam").total_bytes
        scale = memory_report(shapes, "scale").total_bytes
        sgd = memory_report(shapes, "sgd").total_bytes
        assert sgd <= scale < 0.45 * adam, arch  # scale uses <45% of adam


def test_momentum_dtype_bf16_memory_accounting():
    """memory_report(momentum_dtype=...): bf16 first moments halve the
    eligible portion at f32 storage bytes, and are a no-op under the
    paper's 2-byte protocol (the pinned Table-4 numbers cannot move)."""
    from repro.core import memory_report, momentum_eligible_elements
    from repro.models import param_shapes
    from repro.configs import get_arch

    shapes = param_shapes(get_arch("llama-60m"))
    for method in ("adam", "muon", "scale"):
        base = memory_report(shapes, method, dtype_bytes=4)
        bf16 = memory_report(shapes, method, dtype_bytes=4,
                             momentum_dtype="bfloat16")
        mu = momentum_eligible_elements(shapes, method)
        assert mu > 0
        assert base.state_bytes - bf16.state_bytes == 2 * mu
        # paper protocol (2 bytes/elem) is unchanged by the knob
        assert memory_report(shapes, method,
                             momentum_dtype="bfloat16").state_bytes == \
            memory_report(shapes, method).state_bytes
    # sgd has no momentum-eligible state
    assert momentum_eligible_elements(shapes, "sgd") == 0
    import pytest as _pytest
    with _pytest.raises(ValueError, match="momentum_dtype"):
        memory_report(shapes, "adam", momentum_dtype="fp8")
