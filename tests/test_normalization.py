"""Unit + property tests for the gradient normalizations (paper eq. 6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import colnorm, ns_orthogonalize, rownorm, signnorm, normalize

DIMS = st.integers(2, 24)


@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**16))
@settings(max_examples=40, deadline=None)
def test_colnorm_unit_columns(m, n, seed):
    g = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (m, n)))
    g = g + np.sign(g) * 0.1  # keep columns away from zero
    out = np.asarray(colnorm(jnp.asarray(g)))
    norms = np.linalg.norm(out, axis=0)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**16),
       scale=st.floats(0.01, 100.0))
@settings(max_examples=40, deadline=None)
def test_colnorm_scale_invariant(m, n, seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) + 0.1
    a = np.asarray(colnorm(g))
    b = np.asarray(colnorm(g * scale))
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_rownorm_unit_rows():
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 16)) + 0.1
    out = np.asarray(rownorm(g))
    np.testing.assert_allclose(np.linalg.norm(out, axis=1), 1.0, atol=1e-5)


def test_signnorm():
    g = jnp.asarray([[1.5, -2.0], [0.0, 3.0]])
    np.testing.assert_array_equal(np.asarray(signnorm(g)),
                                  [[1.0, -1.0], [0.0, 1.0]])


@pytest.mark.parametrize("shape", [(16, 16), (8, 32), (32, 8)])
def test_ns_singular_values_near_one(shape):
    """Muon's quintic NS drives singular values into ~[0.7, 1.2] in 5 steps
    (it deliberately trades exactness for speed vs true UV^T)."""
    g = jax.random.normal(jax.random.PRNGKey(1), shape)
    ns = np.asarray(ns_orthogonalize(g)).astype(np.float64)
    sv_in = np.linalg.svd(np.asarray(g), compute_uv=False)
    sv_out = np.linalg.svd(ns, compute_uv=False)
    assert sv_in.max() / sv_in.min() > 2.0      # input was ill-conditioned
    assert sv_out.min() > 0.3 and sv_out.max() < 1.6


def test_ns_orthogonal_rows():
    g = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    o = np.asarray(ns_orthogonalize(g)).astype(np.float64)
    gram = o @ o.T
    np.testing.assert_allclose(gram, np.eye(8), atol=0.25)


def test_stacked_colnorm():
    """Stacked (E, d_in, d_out) params normalize per slice per column."""
    g = jax.random.normal(jax.random.PRNGKey(3), (3, 8, 16)) + 0.1
    out = np.asarray(colnorm(g))
    norms = np.linalg.norm(out, axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_colnorm_vs_rownorm_transpose_duality():
    g = jax.random.normal(jax.random.PRNGKey(4), (8, 16)) + 0.1
    np.testing.assert_allclose(np.asarray(colnorm(g)),
                               np.asarray(rownorm(g.T)).T, atol=1e-6)


def test_normalize_dispatch():
    g = jax.random.normal(jax.random.PRNGKey(5), (8, 8))
    for kind in ("col", "row", "sign", "ns", "svd", "none"):
        assert normalize(g, kind).shape == g.shape
    with pytest.raises(ValueError):
        normalize(g, "nope")
