"""Checkpointing: roundtrip fidelity, auto-resume, retention, corruption."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint as ckpt


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": {"w": jax.random.normal(k, (8, 16)),
                  "b": jnp.arange(5, dtype=jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip_exact(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 10, tree)
    got = ckpt.restore(str(tmp_path), 10, tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_and_retention(tmp_path):
    tree = make_tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4, 5]  # keep=3
    got, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 5


def test_uncommitted_checkpoints_skipped(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crash mid-save: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_0000000002")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checksum_corruption_detected(tmp_path):
    tree = make_tree()
    path = ckpt.save(str(tmp_path), 3, tree)
    # shard extension depends on whether the optional zstd dep is installed
    (shard,) = (os.path.join(path, n) for n in os.listdir(path)
                if n.startswith("shard_00000"))
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 3, tree)


def test_shape_mismatch_rejected(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 1, tree)
    wrong = {"a": {"w": jnp.zeros((4, 4)), "b": tree["a"]["b"]},
             "step": tree["step"]}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_restore_latest_none_when_empty(tmp_path):
    assert ckpt.restore_latest(str(tmp_path), make_tree()) is None


def test_stale_tmp_shard_does_not_poison_save(tmp_path):
    """A crashed save's leftover tmp shard must not survive into the commit.

    Restore resolves the shard via the manifest, and save clears the tmp
    dir, so a stale shard with a different compression extension can
    neither be committed nor picked over the real one.
    """
    tree = make_tree()
    tmp_dir = tmp_path / "step_0000000004.tmp"
    os.makedirs(tmp_dir)
    with open(tmp_dir / "shard_00000.mpk.zst", "wb") as f:
        f.write(b"garbage from a crashed zstd save")
    with open(tmp_dir / "manifest.json", "w") as f:
        f.write('{"checksums": {"shard_00000.mpk.zst": 123}}')
    ckpt.save(str(tmp_path), 4, tree)
    got = ckpt.restore(str(tmp_path), 4, tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_overlaps_and_commits(tmp_path):
    import jax.numpy as jnp
    tree = make_tree()
    h = ckpt.save_async(str(tmp_path), 42, tree)
    # mutate the source immediately (training continues / donates buffers)
    tree2 = jax.tree_util.tree_map(lambda x: x * 0, tree)
    path = h.wait(timeout=30)
    assert h.done and path.endswith("step_0000000042")
    got = ckpt.restore(str(tmp_path), 42, make_tree())
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(make_tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multi_host_barrier_last_host_commits(tmp_path):
    """Barrier: an early host leaves the step uncommitted; the last host to
    arrive observes completeness and commits for everyone, with each host's
    own ``manifest.<host>.json`` intact (no cross-host manifest writes)."""
    tree = make_tree()
    ckpt.save(str(tmp_path), 7, tree, host_id=0, n_hosts=2)
    # host 0 alone must NOT commit (the old best-effort merge did, racing
    # host 1's manifest write)
    assert ckpt.latest_step(str(tmp_path)) is None
    assert os.path.exists(tmp_path / "step_0000000007.tmp"
                          / "manifest.00000.json")
    ckpt.save(str(tmp_path), 7, tree, host_id=1, n_hosts=2)
    assert ckpt.latest_step(str(tmp_path)) == 7
    step_dir = tmp_path / "step_0000000007"
    for h in (0, 1):
        assert os.path.exists(step_dir / f"manifest.{h:05d}.json")
        got = ckpt.restore(str(tmp_path), 7, tree, host_id=h)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the merged manifest (legacy readers) carries both hosts' checksums
    import json
    with open(step_dir / "manifest.json") as f:
        merged = json.load(f)
    assert {n[:11] for n in merged["checksums"]} == {"shard_00000",
                                                     "shard_00001"}


def test_multi_host_concurrent_saves_commit_exactly_once(tmp_path):
    """Both hosts save concurrently with a barrier timeout: every shard and
    every per-host manifest survives, regardless of which host commits."""
    import threading
    tree = make_tree()
    errs = []

    def worker(h):
        try:
            ckpt.save(str(tmp_path), 3, tree, host_id=h, n_hosts=2,
                      barrier_timeout=30.0)
        except BaseException as e:  # pragma: no cover - surfaced below
            errs.append(e)

    ts = [threading.Thread(target=worker, args=(h,)) for h in (0, 1)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(60)
    assert not errs, errs
    assert ckpt.latest_step(str(tmp_path)) == 3
    for h in (0, 1):
        got = ckpt.restore(str(tmp_path), 3, tree, host_id=h)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resave_of_committed_step_keeps_other_hosts_shards(tmp_path):
    """A host re-saving an already committed step must adopt, not destroy,
    the other hosts' committed shards + manifests (the rename replaces the
    whole step dir)."""
    tree = make_tree()
    ckpt.save(str(tmp_path), 5, tree, host_id=0, n_hosts=2)
    ckpt.save(str(tmp_path), 5, tree, host_id=1, n_hosts=2)  # commits
    assert ckpt.latest_step(str(tmp_path)) == 5
    # host 0 re-saves the committed step (e.g. resumed after a crash)
    ckpt.save(str(tmp_path), 5, tree, host_id=0, n_hosts=2)
    step_dir = tmp_path / "step_0000000005"
    shards = sorted(n for n in os.listdir(step_dir) if n.startswith("shard_"))
    assert [s[:11] for s in shards] == ["shard_00000", "shard_00001"]
    for host in (0, 1):
        got = ckpt.restore(str(tmp_path), 5, tree, host_id=host)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_committed_shard_wins_over_stale_tmp_debris(tmp_path):
    """A crashed re-save's tmp shard must not shadow the committed one.

    Host 1 commits step N (both hosts saved), then a re-save crashes after
    writing a garbage shard into the new tmp dir but before writing host
    1's tmp manifest. Host 0's later save adopts host 1's committed shard
    (overwriting the unvouched tmp debris), so host 1's restore still
    checksums clean.
    """
    tree = make_tree()
    ckpt.save(str(tmp_path), 9, tree, host_id=0, n_hosts=2)
    ckpt.save(str(tmp_path), 9, tree, host_id=1, n_hosts=2)  # commits
    (shard_name,) = (n for n in os.listdir(tmp_path / "step_0000000009")
                     if n.startswith("shard_00001"))
    tmp_dir = tmp_path / "step_0000000009.tmp"
    os.makedirs(tmp_dir)
    with open(tmp_dir / shard_name, "wb") as f:
        f.write(b"garbage from a crashed re-save")  # no tmp manifest
    ckpt.save(str(tmp_path), 9, tree, host_id=0, n_hosts=2)
    for host in (0, 1):
        got = ckpt.restore(str(tmp_path), 9, tree, host_id=host)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_legacy_merged_manifest_still_restores(tmp_path):
    """Checkpoints written by the old single-merged-manifest format (no
    per-host manifests) must keep restoring."""
    tree = make_tree()
    path = ckpt.save(str(tmp_path), 2, tree)
    for n in list(os.listdir(path)):
        if n.startswith("manifest.") and n != "manifest.json":
            os.remove(os.path.join(path, n))
    got = ckpt.restore(str(tmp_path), 2, tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _model_trees():
    """(untied, tied) param trees of the same tiny model."""
    untied = {"tok_embed": {"w": jnp.ones((8, 4))},
              "final_norm": {"s": jnp.ones((4,))},
              "lm_head": {"w": jnp.ones((4, 8))}}
    tied = {"tok_embed": {"w": jnp.ones((8, 4))},
            "final_norm": {"s": jnp.ones((4,))}}
    return untied, tied


def test_restore_tied_model_from_untied_checkpoint_names_lm_head(tmp_path):
    untied, tied = _model_trees()
    ckpt.save(str(tmp_path), 1, untied)
    with pytest.raises(ValueError, match="lm_head.*untied"):
        ckpt.restore(str(tmp_path), 1, tied)


def test_restore_untied_model_from_tied_checkpoint_names_lm_head(tmp_path):
    untied, tied = _model_trees()
    ckpt.save(str(tmp_path), 1, tied)
    with pytest.raises(ValueError, match="lm_head.*tie_embeddings"):
        ckpt.restore(str(tmp_path), 1, untied)
