"""Checkpointing: roundtrip fidelity, auto-resume, retention, corruption."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.checkpoint as ckpt


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": {"w": jax.random.normal(k, (8, 16)),
                  "b": jnp.arange(5, dtype=jnp.int32)},
            "step": jnp.asarray(7, jnp.int32)}


def test_roundtrip_exact(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 10, tree)
    got = ckpt.restore(str(tmp_path), 10, tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_latest_and_retention(tmp_path):
    tree = make_tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(n[5:]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4, 5]  # keep=3
    got, step = ckpt.restore_latest(str(tmp_path), tree)
    assert step == 5


def test_uncommitted_checkpoints_skipped(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 1, tree)
    # simulate a crash mid-save: step dir without COMMITTED marker
    os.makedirs(tmp_path / "step_0000000002")
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_checksum_corruption_detected(tmp_path):
    tree = make_tree()
    path = ckpt.save(str(tmp_path), 3, tree)
    shard = os.path.join(path, "shard_00000.mpk.zst")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 3, tree)


def test_shape_mismatch_rejected(tmp_path):
    tree = make_tree()
    ckpt.save(str(tmp_path), 1, tree)
    wrong = {"a": {"w": jnp.zeros((4, 4)), "b": tree["a"]["b"]},
             "step": tree["step"]}
    with pytest.raises(ValueError):
        ckpt.restore(str(tmp_path), 1, wrong)


def test_restore_latest_none_when_empty(tmp_path):
    assert ckpt.restore_latest(str(tmp_path), make_tree()) is None


def test_async_save_overlaps_and_commits(tmp_path):
    import jax.numpy as jnp
    tree = make_tree()
    h = ckpt.save_async(str(tmp_path), 42, tree)
    # mutate the source immediately (training continues / donates buffers)
    tree2 = jax.tree_util.tree_map(lambda x: x * 0, tree)
    path = h.wait(timeout=30)
    assert h.done and path.endswith("step_0000000042")
    got = ckpt.restore(str(tmp_path), 42, make_tree())
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(make_tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
