"""Integration: real training runs — convergence, resume-exactness,
grad-accumulation equivalence, optimizer comparison at tiny scale."""
import jax
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.core import make_optimizer
from repro.data import make_dataset
from repro.models import init_params
from repro.training import init_state, make_train_step
import repro.checkpoint as ckpt


def run(cfg, opt_name, steps, lr=3e-3, grad_accum=1, seed=0, state=None,
        start=0, accum_dtype="float32"):
    tx = make_optimizer(opt_name, lr)
    if state is None:
        params = init_params(jax.random.PRNGKey(seed), cfg)
        state = init_state(params, tx)
    step_fn = jax.jit(make_train_step(cfg, tx, grad_accum=grad_accum,
                                      clip_norm=1.0, accum_dtype=accum_dtype))
    ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=seed)
    losses = []
    for i in range(start, start + steps):
        state, m = step_fn(state, ds.host_batch_at(i))
        losses.append(float(m["loss"]))
    return state, losses


def test_scale_loss_decreases(tiny):
    _, losses = run(tiny, "scale", 30)
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_scale_beats_plain_sgd(tiny):
    """Paper Fig. 2: plain SGD barely moves where normalized SGD converges."""
    _, scale_losses = run(tiny, "scale", 25, lr=3e-3)
    _, sgd_losses = run(tiny, "sgd", 25, lr=3e-3)
    assert scale_losses[-1] < sgd_losses[-1] - 0.3


def test_adam_and_scale_comparable(tiny):
    # per-method lr (paper App. C tunes lr per optimizer)
    _, a = run(tiny, "adam", 30, lr=3e-3)
    _, s = run(tiny, "scale", 30, lr=1e-2)
    assert abs(a[-1] - s[-1]) < 1.0  # same ballpark at toy scale


def test_resume_is_exact(tiny, tmp_path):
    """Fault tolerance: kill + resume == uninterrupted run (bitwise)."""
    state_a, _ = run(tiny, "scale", 10)
    state_b, _ = run(tiny, "scale", 5)
    ckpt.save(str(tmp_path), 5, state_b)
    restored, step = ckpt.restore_latest(str(tmp_path), state_b)
    assert step == 5
    state_c, _ = run(tiny, "scale", 5, state=restored, start=5)
    for a, c in zip(jax.tree_util.tree_leaves(state_a.params),
                    jax.tree_util.tree_leaves(state_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_grad_accum_equivalence(tiny):
    """accum=2 over the same global batch ~= accum=1 (f32 accumulation)."""
    s1, l1 = run(tiny, "scale", 5, grad_accum=1)
    s2, l2 = run(tiny, "scale", 5, grad_accum=2)
    np.testing.assert_allclose(l1, l2, atol=5e-3)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3)


def test_grad_accum_keeps_aux_metrics():
    """MoE aux-loss metrics must survive microbatch accumulation.

    The scan body used to discard the aux dict, so `aux` vanished from the
    metrics whenever grad_accum > 1; it is now averaged across microbatches.
    """
    cfg = tiny_cfg("moe", family="moe", n_experts=4, top_k=2, moe_d_ff=64,
                   capacity_factor=2.0)
    tx = make_optimizer("scale", 3e-3)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=0)
    batch = ds.host_batch_at(0)
    out = {}
    for accum in (1, 2):
        step_fn = jax.jit(make_train_step(cfg, tx, grad_accum=accum,
                                          clip_norm=1.0))
        _, metrics = step_fn(init_state(params, tx), batch)
        assert "aux" in metrics, f"aux metric dropped at grad_accum={accum}"
        # scale provides update_params (fused apply); the metric must survive
        assert "update_norm" in metrics
        out[accum] = metrics
    # aux (load-balancing) loss is nonlinear in per-microbatch routing
    # statistics, so halves differ slightly from the full batch
    np.testing.assert_allclose(float(out[1]["aux"]), float(out[2]["aux"]),
                               atol=5e-3)
    np.testing.assert_allclose(float(out[1]["loss"]), float(out[2]["loss"]),
                               atol=1e-3)


@pytest.mark.parametrize("family_cfg", [
    tiny_cfg("moe", family="moe", n_experts=4, top_k=2, moe_d_ff=64,
             capacity_factor=2.0),
    tiny_cfg("ssm", family="ssm", n_heads=0, n_kv_heads=0, ssm_state=16,
             ssm_headdim=16, ssm_chunk=8),
], ids=lambda c: c.name)
def test_other_families_converge(family_cfg):
    _, losses = run(family_cfg, "scale", 25)
    assert losses[-1] < losses[0] - 0.4


def test_train_cli_end_to_end(tmp_path):
    from repro.launch.train import main
    loss = main(["--arch", "qwen2-7b", "--smoke", "--steps", "12",
                 "--batch", "4", "--seq", "32", "--optimizer", "scale",
                 "--ckpt-dir", str(tmp_path), "--ckpt-every", "6",
                 "--log-every", "6"])
    assert np.isfinite(loss)
    assert ckpt.latest_step(str(tmp_path)) == 12
    # auto-resume continues from 12 and trains 4 more steps
    loss2 = main(["--arch", "qwen2-7b", "--smoke", "--steps", "16",
                  "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
                  "--resume", "auto", "--log-every", "6"])
    assert np.isfinite(loss2)
    assert ckpt.latest_step(str(tmp_path)) == 16
