"""Fused LM-head cross-entropy: pinned jnp reference + kernel parity.

Layered like the optimizer-kernel tests: first pin the jnp reference
(`_mask_pad_vocab`, chunked-scan vs full-logit equality over padded vocab /
masked labels / audio codebooks), then hold the fused dispatch path
(`kernels.dispatch.xent_loss`, Pallas kernels — interpret oracle on CPU)
to that reference for loss, dH and dW across dtypes and ragged shapes, and
finally the shard_map'd variant on a forced-8-device (4, 2) host mesh.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import repro_fused, tiny_cfg
from repro.kernels import dispatch
from repro.kernels.xent import ref as xref
from repro.models import init_params, loss_fn
from repro.models.model import _mask_pad_vocab, _pick_chunk, lm_loss

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 1e-4


def _mk(B, S, D, V, VS, dtype=jnp.float32, seed=0, mask_frac=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32).astype(dtype)
    w = jax.random.normal(ks[1], (D, V), jnp.float32).astype(dtype)
    lo = -1 if mask_frac else 0
    labels = jax.random.randint(ks[2], (B, S), lo, VS)
    return h, w, labels


# ---- reference pinning ----------------------------------------------------

def test_mask_pad_vocab():
    cfg = tiny_cfg(vocab_size=250)  # padded_vocab 256
    logits = jnp.zeros((2, 4, cfg.padded_vocab))
    out = _mask_pad_vocab(logits, cfg)
    assert float(jnp.max(out[..., cfg.vocab_size:])) <= -1e8
    np.testing.assert_array_equal(np.asarray(out[..., :cfg.vocab_size]), 0.0)
    # exact-multiple vocab: identity
    cfg2 = tiny_cfg(vocab_size=256)
    np.testing.assert_array_equal(
        np.asarray(_mask_pad_vocab(logits, cfg2)), np.asarray(logits))
    # audio logits are (B, C, S, V): mask applies to the last axis
    cfg3 = tiny_cfg(family="audio", n_codebooks=2, vocab_size=250)
    la = jnp.zeros((2, 2, 4, cfg3.padded_vocab))
    out3 = _mask_pad_vocab(la, cfg3)
    assert float(jnp.max(out3[..., cfg3.vocab_size:])) <= -1e8
    assert float(jnp.min(out3[..., :cfg3.vocab_size])) == 0.0


def test_pick_chunk_largest_divisor():
    assert _pick_chunk(32, 2048) == 32
    assert _pick_chunk(32, 16) == 16
    assert _pick_chunk(30, 16) == 15
    assert _pick_chunk(36, 16) == 12
    assert _pick_chunk(1, 16) == 1


def test_pick_chunk_warns_on_degenerate_fallback():
    with pytest.warns(UserWarning, match="loss chunk shrinks to 1"):
        assert _pick_chunk(17, 16) == 1  # prime S: per-token scan
    with pytest.warns(UserWarning, match="loss chunk shrinks"):
        assert _pick_chunk(2 * 97, 64) == 2
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # >= half the target: silent
        assert _pick_chunk(32, 16) == 16
        assert _pick_chunk(24, 16) == 12


def _scan_lm_loss(params, cfg, hidden, labels):
    """The chunked jnp reference path, forced regardless of REPRO_FUSED."""
    with repro_fused("off"):
        return lm_loss(params, cfg, hidden, labels)


@pytest.mark.parametrize("vocab_size,loss_chunk", [(250, 16), (256, 7)],
                         ids=["padded_vocab", "ragged_chunk"])
def test_chunked_scan_equals_full_logits(vocab_size, loss_chunk):
    """The scan path == naive full-logit cross-entropy (the contract the
    fused kernels are later held to)."""
    cfg = tiny_cfg(vocab_size=vocab_size, loss_chunk=loss_chunk)
    B, S, D = 2, 32, cfg.d_model
    h, w, labels = _mk(B, S, D, cfg.padded_vocab, vocab_size, seed=1)
    params = {"lm_head": {"w": w}}
    loss, weight = _scan_lm_loss(params, cfg, h, labels)
    ref = xref.losses(h, w, labels, vocab_size)
    ref_w = float(jnp.sum((labels >= 0).astype(jnp.float32)))
    np.testing.assert_allclose(float(loss),
                               float(jnp.sum(ref)) / max(ref_w, 1.0),
                               rtol=1e-6)
    assert float(weight) == ref_w


def test_chunked_scan_all_masked_rows():
    cfg = tiny_cfg(vocab_size=250)
    h, w, _ = _mk(2, 32, cfg.d_model, cfg.padded_vocab, 250, seed=2)
    labels = jnp.full((2, 32), -1, jnp.int32)
    loss, weight = _scan_lm_loss({"lm_head": {"w": w}}, cfg, h, labels)
    assert float(weight) == 0.0 and float(loss) == 0.0


def test_chunked_scan_audio_codebooks():
    cfg = tiny_cfg(family="audio", n_codebooks=2, vocab_size=200)
    B, S, D = 2, 16, cfg.d_model
    params = init_params(jax.random.PRNGKey(3), cfg)
    h = jax.random.normal(jax.random.PRNGKey(4), (B, S, D),
                          jnp.float32).astype(cfg.jdtype)
    labels = jax.random.randint(jax.random.PRNGKey(5), (B, 2, S), -1, 200)
    loss, weight = _scan_lm_loss(params, cfg, h, labels)
    w = params["lm_head"]["w"]
    tot = sum(float(jnp.sum(xref.losses(h, w[c], labels[:, c], 200)))
              for c in range(2))
    ref_w = float(jnp.sum((labels >= 0).astype(jnp.float32)))
    np.testing.assert_allclose(float(loss), tot / max(ref_w, 1.0), rtol=2e-3)
    assert float(weight) == ref_w


# ---- fused dispatch parity ------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(2, 32, 64, 512, 500),
                                   (1, 70, 33, 257, 200),
                                   (2, 16, 128, 384, 384)],
                         ids=["padded", "ragged", "exact"])
def test_fused_xent_loss_and_grads_match_reference(shape, dtype):
    B, S, D, V, VS = shape
    h, w, labels = _mk(B, S, D, V, VS, dtype, seed=6)
    tol = _tol(dtype)

    def f_fused(h, w):
        return jnp.sum(dispatch.xent_loss(h, w, labels, vocab_size=VS))

    def f_ref(h, w):
        return jnp.sum(xref.losses(h, w, labels, VS))

    v1, (dh1, dw1) = jax.value_and_grad(f_fused, argnums=(0, 1))(h, w)
    v2, (dh2, dw2) = jax.value_and_grad(f_ref, argnums=(0, 1))(h, w)
    np.testing.assert_allclose(float(v1), float(v2),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)
    assert dh1.dtype == h.dtype and dw1.dtype == w.dtype
    np.testing.assert_allclose(np.asarray(dh1, np.float32),
                               np.asarray(dh2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(dw1, np.float32),
                               np.asarray(dw2, np.float32), atol=tol)


def test_fused_xent_masked_tokens_contribute_nothing():
    h, w, _ = _mk(2, 16, 32, 256, 256, seed=7)
    labels = jnp.full((2, 16), -1, jnp.int32)
    losses = dispatch.xent_loss(h, w, labels, vocab_size=256)
    np.testing.assert_array_equal(np.asarray(losses), 0.0)
    dh, dw = jax.grad(
        lambda h, w: jnp.sum(dispatch.xent_loss(h, w, labels,
                                                vocab_size=256)),
        argnums=(0, 1))(h, w)
    np.testing.assert_array_equal(np.asarray(dh), 0.0)
    np.testing.assert_array_equal(np.asarray(dw), 0.0)


def test_xent_routing_and_fallbacks(monkeypatch):
    assert dispatch.xent_supported((4, 8, 16), (16, 128))
    assert dispatch.xent_supported((32, 16), (16, 128))
    assert not dispatch.xent_supported((4, 8, 16), (17, 128))  # D mismatch
    assert not dispatch.xent_supported((16,), (16, 128))       # no token dim
    assert dispatch.xent_route((4, 8, 16), (16, 128))[0] == "kernel"
    monkeypatch.setenv("REPRO_FUSED", "off")
    assert dispatch.xent_route((4, 8, 16), (16, 128))[0] == "ref"
    # the off-route still yields correct (reference) values
    h, w, labels = _mk(2, 8, 16, 128, 100, seed=8)
    np.testing.assert_allclose(
        np.asarray(dispatch.xent_loss(h, w, labels, vocab_size=100)),
        np.asarray(xref.losses(h, w, labels, 100)), atol=1e-6)


def test_lm_loss_fused_equals_scan_reference():
    """End-to-end: the default (fused) lm_loss == the REPRO_FUSED=off scan
    path, values and gradients, dense + audio."""
    for cfg in (tiny_cfg(vocab_size=250),
                tiny_cfg(family="audio", n_codebooks=2, vocab_size=200)):
        params = init_params(jax.random.PRNGKey(9), cfg)
        B, S = 2, 32
        h = jax.random.normal(jax.random.PRNGKey(10), (B, S, cfg.d_model),
                              jnp.float32).astype(cfg.jdtype)
        lab_shape = (B, cfg.n_codebooks, S) if cfg.family == "audio" \
            else (B, S)
        labels = jax.random.randint(jax.random.PRNGKey(11), lab_shape, -1,
                                    cfg.vocab_size)

        def head_loss(p, force_off):
            if force_off:
                return _scan_lm_loss(p, cfg, h, labels)[0]
            return lm_loss(p, cfg, h, labels)[0]

        head = {"lm_head": params["lm_head"]}
        l_f, g_f = jax.value_and_grad(head_loss)(head, False)
        l_r, g_r = jax.value_and_grad(head_loss)(head, True)
        np.testing.assert_allclose(float(l_f), float(l_r), rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(g_f),
                        jax.tree_util.tree_leaves(g_r)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-4)


def test_train_step_runs_fused_loss_by_default():
    """The trainer needs no new plumbing off-mesh: loss_fn routes to the
    fused xent wherever covered and the step stays finite/deterministic."""
    from repro.core import make_optimizer
    from repro.data import make_dataset
    from repro.training import init_state, make_train_step
    cfg = tiny_cfg(vocab_size=250)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=4)
    batch = ds.host_batch_at(0)
    tx = make_optimizer("scale", 1e-3)
    step = jax.jit(make_train_step(cfg, tx))
    state, metrics = step(init_state(params, tx), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # and the value agrees with the scan-path loss
    with repro_fused("off"):
        step_off = jax.jit(make_train_step(cfg, tx))
        _, m_off = step_off(init_state(params, tx), batch)
    np.testing.assert_allclose(float(metrics["loss"]), float(m_off["loss"]),
                               rtol=1e-5)


def test_loss_fn_accepts_mesh_kwarg():
    """The trainer feature-detects loss_fn(mesh=...); a 1-device mesh must
    behave exactly like no mesh (replicated plan -> single-device path)."""
    import inspect
    assert "mesh" in inspect.signature(loss_fn).parameters
    from repro.data import make_dataset
    cfg = tiny_cfg(vocab_size=250)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=2)
    batch = ds.host_batch_at(0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    (l1, _) = loss_fn(params, cfg, batch)
    (l2, _) = loss_fn(params, cfg, batch, mesh=mesh)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_vocab_shard_remainder_tiles_masked():
    """Non-last vocab shards: remainder-tile lanes past the local w width
    are undefined memory whose *global* column ids are still < vocab_size
    — they must not enter the logsumexp, the label one-hot, or either
    gradient contraction (regression: the mask only checked the global
    bound, NaN-ing every non-last shard with local_V % bv != 0)."""
    from repro.kernels.xent import xent as xk
    n, d, V, VS = 16, 16, 384, 384
    ks = jax.random.split(jax.random.PRNGKey(12), 3)
    h = jax.random.normal(ks[0], (n, d))
    w = jax.random.normal(ks[1], (d, V))
    lab = jax.random.randint(ks[2], (n,), -1, VS)
    gl = jnp.abs(jax.random.normal(ks[0], (n,))) * (lab >= 0)

    # two hand-combined shards of local width 192; bv=128 leaves a
    # 64-lane undefined remainder region on each shard's second tile
    halves = [(0, 192), (192, 384)]
    parts = [xk.xent_fwd(h, w[:, a:b], lab, vocab_size=VS, col_offset=a,
                         block=(32, 128)) for a, b in halves]
    for lse, _ in parts:
        assert bool(jnp.all(jnp.isfinite(lse)))
    m = jnp.maximum(parts[0][0], parts[1][0])
    lse_g = m + jnp.log(sum(jnp.exp(p[0] - m) for p in parts))
    ll_g = parts[0][1] + parts[1][1]
    rlse, rll = xref.lse_ll(h, w, lab, VS)
    np.testing.assert_allclose(np.asarray(lse_g), np.asarray(rlse),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(ll_g), np.asarray(rll), atol=1e-4)

    rdh, rdw = jax.grad(
        lambda h, w: jnp.sum(xref.losses(h, w, lab, VS) * gl),
        argnums=(0, 1))(h, w)
    dh = sum(xk.xent_bwd_dh(h, w[:, a:b], lab, lse_g, gl, vocab_size=VS,
                            col_offset=a, block=(32, 128))
             for a, b in halves)
    dw = jnp.concatenate(
        [xk.xent_bwd_dw(h, w[:, a:b], lab, lse_g, gl, vocab_size=VS,
                        col_offset=a, block=(32, 128)) for a, b in halves],
        axis=1)
    np.testing.assert_allclose(np.asarray(dh), np.asarray(rdh), atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(rdw), atol=1e-4)


# ---- sharded matrix on a forced 8-device host mesh ------------------------

def test_sharded_xent_parity_under_forced_8_devices():
    """(4, 2) mesh: batch over "data", head FSDP+TP over ("data","model").
    loss/dH/dW must match the unsharded reference for f32 and bf16."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels import dispatch
from repro.kernels.xent import ref as xref

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
B, S, D, V, VS = 8, 16, 32, 256, 200
ks = jax.random.split(jax.random.PRNGKey(0), 3)
for dtype in (jnp.float32, jnp.bfloat16):
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32).astype(dtype)
    w = jax.random.normal(ks[1], (D, V), jnp.float32).astype(dtype)
    lab = jax.random.randint(ks[2], (B, S), -1, VS)
    h_sh = NamedSharding(mesh, P("data", None, None))
    w_sh = NamedSharding(mesh, P("data", "model"))  # FSDP embed + TP vocab
    route, plan = dispatch.xent_route(h.shape, w.shape, None, h_sh, w_sh)
    assert route == "kernel" and plan.tok_axes == ("data",) \
        and plan.voc_axes == ("model",), (route, plan)
    h_s, w_s = jax.device_put(h, h_sh), jax.device_put(w, w_sh)

    def f_fused(h, w):
        return jnp.sum(dispatch.xent_loss(
            h, w, lab, vocab_size=VS, h_sharding=h_sh, w_sharding=w_sh))

    def f_ref(h, w):
        return jnp.sum(xref.losses(h, w, lab, VS))

    v1, (dh1, dw1) = jax.value_and_grad(f_fused, argnums=(0, 1))(h_s, w_s)
    v2, (dh2, dw2) = jax.value_and_grad(f_ref, argnums=(0, 1))(h, w)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        float(v1), float(v2), rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6)
    np.testing.assert_allclose(np.asarray(dh1, np.float32),
                               np.asarray(dh2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(dw1, np.float32),
                               np.asarray(dw2, np.float32), atol=tol)

# ragged local vocab (V=320 over 2-way model axis -> local 160, bv=128
# leaves an undefined remainder region on every shard): remainder lanes
# must stay masked (regression for the local-bound term in _col_masks)
V2, VS2 = 320, 300
w2 = jax.random.normal(ks[1], (D, V2))
lab2 = jax.random.randint(ks[2], (B, S), -1, VS2)
h32 = jax.random.normal(ks[0], (B, S, D))
w_sh2 = NamedSharding(mesh, P(None, "model"))
h_sh2 = NamedSharding(mesh, P("data", None, None))
assert dispatch.xent_route(h32.shape, w2.shape, None, h_sh2,
                           w_sh2)[0] == "kernel"

def f2(h, w):
    return jnp.sum(dispatch.xent_loss(h, w, lab2, vocab_size=VS2,
                                      h_sharding=h_sh2, w_sharding=w_sh2,
                                      block=(32, 128)))
v1, (dh1, dw1) = jax.value_and_grad(f2, argnums=(0, 1))(
    jax.device_put(h32, h_sh2), jax.device_put(w2, w_sh2))
v2, (dh2, dw2) = jax.value_and_grad(
    lambda h, w: jnp.sum(xref.losses(h, w, lab2, VS2)),
    argnums=(0, 1))(h32, w2)
np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)
np.testing.assert_allclose(np.asarray(dh1), np.asarray(dh2), atol=1e-4)
np.testing.assert_allclose(np.asarray(dw1), np.asarray(dw2), atol=1e-4)

# non-divisible vocab on the mesh: must fall back to ref, not mis-shard
bad_w_sh = NamedSharding(mesh, P(None, "model"))
assert dispatch.xent_route((8, 16, 32), (32, 129), None, None,
                           bad_w_sh)[0] == "ref"
# one axis sharding BOTH tokens and vocab: the lse/ll psum would mix
# statistics across token shards — must fall back to ref
assert dispatch.xent_route(
    (8, 16, 32), (32, 256), None,
    NamedSharding(mesh, P("data", None, None)),
    NamedSharding(mesh, P(None, "data")))[0] == "ref"
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


# ---- weighted loss (packed-document denominators) -------------------------

def _weighted_ref(h, w, labels, weights, vocab):
    """Naive weighted mean: sum(w_i * loss_i) / sum of effective weights."""
    per = np.asarray(xref.losses(h, w, labels, vocab), np.float64)
    eff = np.asarray(weights, np.float64) * (np.asarray(labels) >= 0)
    denom = eff.sum()
    return (per * eff).sum() / (denom if denom > 0 else 1.0), eff.sum()


@pytest.mark.parametrize("fused", ["interpret", "off"],
                         ids=["fused", "chunked"])
def test_lm_loss_fractional_weight_denominator(fused):
    """Regression: the mean must divide by the summed effective weight.

    With every weight fractional and the total below 1.0 the old
    ``max(ws, 1.0)`` clamp silently deflated the loss (divided a 0.3-token
    batch by 1.0); the fix divides by ws whenever ws > 0.
    """
    cfg = tiny_cfg(vocab_size=250, loss_chunk=8)
    B, S, D = 1, 16, cfg.d_model
    h, w, labels = _mk(B, S, D, cfg.padded_vocab, 250, seed=11,
                       mask_frac=False)
    weights = jnp.zeros((B, S)).at[0, 3].set(0.3)   # total weight 0.3 < 1
    with repro_fused(fused):
        loss, wt = lm_loss({"lm_head": {"w": w}}, cfg, h, labels,
                           weights=weights)
    ref, ref_w = _weighted_ref(h, w, labels, weights, 250)
    np.testing.assert_allclose(float(loss), ref, rtol=1e-5)
    np.testing.assert_allclose(float(wt), ref_w, rtol=1e-6)


@pytest.mark.parametrize("fused", ["interpret", "off"],
                         ids=["fused", "chunked"])
def test_lm_loss_partial_mask_weights(fused):
    """Mixed masking — label -1, weight 0, and fractional weights — in one
    batch: only label>=0 AND weight>0 tokens count, each at its weight."""
    cfg = tiny_cfg(vocab_size=250, loss_chunk=8)
    B, S, D = 2, 16, cfg.d_model
    h, w, labels = _mk(B, S, D, cfg.padded_vocab, 250, seed=12,
                       mask_frac=False)
    labels = labels.at[0, :4].set(-1)               # label-masked
    weights = jnp.ones((B, S))
    weights = weights.at[1, 8:].set(0.0)            # weight-masked
    weights = weights.at[0, 10].set(0.25)           # fractional
    with repro_fused(fused):
        loss, wt = lm_loss({"lm_head": {"w": w}}, cfg, h, labels,
                           weights=weights)
    ref, ref_w = _weighted_ref(h, w, labels, weights, 250)
    np.testing.assert_allclose(float(loss), ref, rtol=1e-5)
    np.testing.assert_allclose(float(wt), ref_w, rtol=1e-6)


@pytest.mark.parametrize("fused", ["interpret", "off"],
                         ids=["fused", "chunked"])
def test_lm_loss_all_masked_weights_zero_loss_finite_grads(fused):
    """An all-weight-zero batch yields loss 0 / weight 0 — no NaN from a
    0/0 mean — and the gradient through it is finite (exactly zero)."""
    cfg = tiny_cfg(vocab_size=250, loss_chunk=8)
    B, S, D = 2, 16, cfg.d_model
    h, w, labels = _mk(B, S, D, cfg.padded_vocab, 250, seed=13,
                       mask_frac=False)
    weights = jnp.zeros((B, S))
    with repro_fused(fused):
        loss, wt = lm_loss({"lm_head": {"w": w}}, cfg, h, labels,
                           weights=weights)
        assert float(loss) == 0.0 and float(wt) == 0.0
        g = jax.grad(lambda hh: lm_loss({"lm_head": {"w": w}}, cfg, hh,
                                        labels, weights=weights)[0])(h)
    assert bool(jnp.all(jnp.isfinite(g)))
    np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_lm_loss_weighted_fused_matches_chunked():
    """The two routes agree on a weighted batch (same denominator law)."""
    cfg = tiny_cfg(vocab_size=256, loss_chunk=8)
    B, S, D = 2, 32, cfg.d_model
    h, w, labels = _mk(B, S, D, cfg.padded_vocab, 256, seed=14)
    weights = jax.random.uniform(jax.random.PRNGKey(15), (B, S))
    weights = jnp.where(weights > 0.2, weights, 0.0)
    params = {"lm_head": {"w": w}}
    with repro_fused("interpret"):
        lf, wf = lm_loss(params, cfg, h, labels, weights=weights)
    with repro_fused("off"):
        lc, wc = lm_loss(params, cfg, h, labels, weights=weights)
    np.testing.assert_allclose(float(lf), float(lc), rtol=1e-5)
    np.testing.assert_allclose(float(wf), float(wc), rtol=1e-6)
