"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the real single
CPU device; only launch/dryrun.py forces 512 placeholder devices."""
import jax
import jax.numpy as jnp
import pytest

# one canonical REPRO_FUSED pin helper (tests force dispatch routes, e.g.
# 'off' for the jnp reference paths); `python -m pytest` from the repo
# root — the documented tier-1 command — puts `benchmarks` on sys.path
from benchmarks.common import repro_fused  # noqa: F401  (re-exported)
from repro.models import ModelConfig


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_cfg(name="tiny", **kw):
    base = dict(name=name, family="dense", n_layers=2, d_model=64, n_heads=4,
                n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
                attn_kv_block=16, attn_q_block=16, loss_chunk=16)
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def tiny():
    return tiny_cfg()


def tiny_params():
    import jax.numpy as jnp
    return {
        "tok_embed": {"w": jax.random.normal(jax.random.PRNGKey(1), (64, 32))},
        "segments": {"seg0": {"attn": {"wq": jax.random.normal(
            jax.random.PRNGKey(2), (2, 32, 32))}}},
        "norm": {"s": jnp.ones((32,))},
        "lm_head": {"w": jax.random.normal(jax.random.PRNGKey(3), (32, 64))},
    }
