"""Unit tests for SCALE + every baseline optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (apply_updates, colnorm, label_tree, make_optimizer,
                        OPTIMIZER_NAMES)
from repro.core.labels import partition_sizes


def make_params():
    k = jax.random.PRNGKey(0)
    return {
        "tok_embed": {"w": jax.random.normal(k, (32, 16))},
        "layers": {"wq": jax.random.normal(k, (2, 16, 16)),
                   "norm": jnp.ones((2, 16))},
        "lm_head": {"w": jax.random.normal(k, (16, 64))},
        "bias": {"b": jnp.zeros((16,))},
    }


def make_grads(params, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed),
                          len(jax.tree_util.tree_leaves(params)))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, l.shape) for k, l in zip(ks, leaves)])


def test_labels():
    params = make_params()
    labels = label_tree(params)
    assert labels["tok_embed"]["w"] == "first"
    assert labels["lm_head"]["w"] == "last"
    assert labels["layers"]["wq"] == "matrix"
    assert labels["layers"]["norm"] == "vector"  # stacked norm scale
    assert labels["bias"]["b"] == "vector"
    sizes = partition_sizes(params)
    assert sizes["last"] == 16 * 64 and sizes["first"] == 32 * 16


@pytest.mark.parametrize("name", [n for n in OPTIMIZER_NAMES
                                  if n != "scale_fused"])
def test_optimizer_steps_finite_and_decrease_quadratic(name):
    """3 steps on a toy quadratic: finite updates, params move."""
    params = make_params()
    kw = {"rank": 4} if name in ("galore", "fira", "apollo") else {}
    tx = make_optimizer(name, 1e-2, **kw)
    state = tx.init(params)
    p = params
    for _ in range(3):
        grads = jax.tree_util.tree_map(lambda x: 0.5 * x, p)  # grad of 0.25||p||^2
        upd, state = jax.jit(tx.update)(grads, state, p)
        p = apply_updates(p, upd)
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(params)):
        assert bool(jnp.all(jnp.isfinite(a)))
    assert float(jnp.linalg.norm(p["lm_head"]["w"])) < \
        float(jnp.linalg.norm(params["lm_head"]["w"]))


def test_adam_matches_closed_form_scalar():
    tx = make_optimizer("adam", 0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"x": jnp.asarray([2.0])}
    state = tx.init(params)
    g = {"x": jnp.asarray([1.0])}
    upd, state = tx.update(g, state, params)
    # bias-corrected first step of Adam is exactly -lr * g/(|g|+eps) = -lr
    np.testing.assert_allclose(np.asarray(upd["x"]), [-0.1], rtol=1e-5)


def test_scale_update_matches_manual():
    """The SCALE matrix update is -lr * colnorm(g); head uses momentum EMA."""
    lr, beta = 1e-2, 0.9
    tx = make_optimizer("scale", lr, beta=beta)
    params = make_params()
    state = tx.init(params)
    g1 = make_grads(params, 1)
    upd, state = tx.update(g1, state, params)
    np.testing.assert_allclose(
        np.asarray(upd["layers"]["wq"]),
        np.asarray(-lr * colnorm(g1["layers"]["wq"])), atol=1e-6)
    m1 = (1 - beta) * g1["lm_head"]["w"]
    np.testing.assert_allclose(np.asarray(upd["lm_head"]["w"]),
                               np.asarray(-lr * colnorm(m1)), atol=1e-5)
    # second step momentum recursion
    g2 = make_grads(params, 2)
    upd2, state = tx.update(g2, state, params)
    m2 = beta * m1 + (1 - beta) * g2["lm_head"]["w"]
    np.testing.assert_allclose(np.asarray(upd2["lm_head"]["w"]),
                               np.asarray(-lr * colnorm(m2)), atol=1e-5)


def test_scale_state_is_memory_minimal():
    """Momentum buffers exist ONLY for the lm_head (+ tiny vector Adam)."""
    params = make_params()
    tx = make_optimizer("scale", 1e-3)
    state = tx.init(params)
    assert state.mu["lm_head"]["w"].shape == params["lm_head"]["w"].shape
    assert state.mu["layers"]["wq"].size == 0      # stateless matrices
    assert state.mu["tok_embed"]["w"].size == 0    # no first-layer momentum
    assert state.nu["lm_head"]["w"].size == 0      # no 2nd moment anywhere
    assert state.mu["bias"]["b"].shape == (16,)    # vector Adam


def test_scale_momentum_first_last_ablation():
    from repro.core import scale
    tx = scale(1e-3, momentum_on=("first", "last"))
    params = make_params()
    state = tx.init(params)
    assert state.mu["tok_embed"]["w"].shape == params["tok_embed"]["w"].shape


def test_stable_spam_momentum_reset():
    tx = make_optimizer("stable_spam", 1e-3, reset_interval=2)
    params = {"w": jnp.ones((4, 4))}
    state = tx.init(params)
    g = {"w": jnp.ones((4, 4))}
    _, state = tx.update(g, state, params)   # count 0 -> no reset (count>0 guard)
    _, state = tx.update(g, state, params)   # count 1
    mu_before = np.asarray(state.mu["w"]).copy()
    _, state = tx.update(g, state, params)   # count 2 -> reset fired this step
    assert np.all(np.abs(mu_before) > 0)


def test_muon_adam_branch_for_head():
    tx = make_optimizer("muon", 1e-3)
    params = make_params()
    state = tx.init(params)
    g = make_grads(params)
    upd, _ = tx.update(g, state, params)
    # head goes through adam (not NS): update magnitude ~lr, element-wise
    assert float(jnp.max(jnp.abs(upd["lm_head"]["w"]))) < 5e-3


def test_galore_projection_shapes():
    from repro.core import galore
    tx = galore(1e-3, rank=4)
    params = make_params()
    state = tx.init(params)
    # low-rank states for hidden matrices only
    assert state.mu["layers"]["wq"].shape[-2:] in ((4, 16), (16, 4))
    assert state.mu["lm_head"]["w"].shape == params["lm_head"]["w"].shape


def test_schedule_warmup_cosine():
    from repro.core import linear_warmup_cosine
    s = linear_warmup_cosine(1.0, 100, warmup_frac=0.1, final_frac=0.1)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.12
    assert float(s(50)) < 1.0


@pytest.mark.parametrize("name", OPTIMIZER_NAMES)
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_state_is_eval_shape_fixed_point(name, gdtype):
    """update() must return states with exactly init()'s shapes/dtypes.

    A drifting state dtype (e.g. a momentum buffer silently promoted or
    demoted) breaks lax.scan training loops and donated-buffer updates:
    jit caches key on the state aval, so step 2 would recompile or error.
    Regression test for the mu-dtype audit; also covers bf16 gradients
    (mixed-precision accumulators hand those to the optimizer).
    """
    params = make_params()
    grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, gdtype if p.ndim > 1 else p.dtype),
        params)
    tx = make_optimizer(name, 1e-3)
    s0 = jax.eval_shape(tx.init, params)
    s1 = jax.eval_shape(lambda g, s, p: tx.update(g, s, p)[1],
                        grads, s0, params)
    assert (jax.tree_util.tree_structure(s0)
            == jax.tree_util.tree_structure(s1))
    for a, b in zip(jax.tree_util.tree_leaves(s0),
                    jax.tree_util.tree_leaves(s1)):
        assert a.shape == b.shape and a.dtype == b.dtype, (name, a, b)
        assert a.weak_type == b.weak_type, (name, a, b)


@pytest.mark.parametrize("impl", ["jnp", "fused"])
def test_scale_update_params_state_fixed_point(impl):
    """The fused parameter write preserves both param and state avals."""
    params = make_params()
    grads = make_grads(params)
    tx = make_optimizer("scale", 1e-3, impl=impl)
    s0 = jax.eval_shape(tx.init, params)
    p1, s1 = jax.eval_shape(lambda g, s, p: tx.update_params(g, s, p),
                            grads, s0, params)
    assert (jax.tree_util.tree_structure(s0)
            == jax.tree_util.tree_structure(s1))
    for a, b in zip(jax.tree_util.tree_leaves(s0),
                    jax.tree_util.tree_leaves(s1)):
        assert a.shape == b.shape and a.dtype == b.dtype
    for a, b in zip(jax.tree_util.tree_leaves(jax.eval_shape(lambda p: p, params)),
                    jax.tree_util.tree_leaves(p1)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("impl", ["jnp", "fused"])
def test_update_params_matches_classic_path_bf16_grads(impl):
    """update_params vs update+apply_updates under mixed precision.

    With bf16 grads and f32 params the classic path rounds each update to
    the grad dtype before applying. The jnp write-mode branches replay that
    exact cast chain (bitwise equality — auto-switching the trainer onto
    update_params must not change an impl='jnp' run's trajectory). The
    fused kernel write applies in full f32 without the intermediate g.dtype
    rounding, so it matches within the parity tolerance instead.
    """
    params = make_params()
    grads = jax.tree_util.tree_map(
        lambda p: (0.1 * jnp.ones_like(p) + 0.01 * p).astype(
            jnp.bfloat16 if p.ndim > 1 else p.dtype), params)
    tx = make_optimizer("scale", 1e-2, impl=impl)
    sa, sb = tx.init(params), tx.init(params)
    pa = pb = params
    for _ in range(5):
        ua, sa = tx.update(grads, sa, pa)
        pa = apply_updates(pa, ua)
        pb, sb = tx.update_params(grads, sb, pb)
    for x, y in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        if impl == "jnp":
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32), atol=1e-4)
    for x, y in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-5)


@pytest.mark.parametrize("impl", ["jnp", "fused"])
def test_scale_bf16_momentum_state_and_parity(impl):
    """momentum_dtype="bfloat16": mu stored bf16 on momentum groups only,
    state aval is an eval_shape fixed point through both entry points, and
    the trajectory tracks the f32-momentum run within bf16 rounding.
    Cast-on-read/write semantics: EMA + norm in f32, storage rounded."""
    params = make_params()
    grads = make_grads(params)
    tx = make_optimizer("scale", 1e-2, impl=impl,
                        momentum_dtype="bfloat16")
    s0 = tx.init(params)
    assert s0.mu["lm_head"]["w"].dtype == jnp.bfloat16  # momentum: halved
    assert s0.mu["bias"]["b"].dtype == jnp.float32      # Adam moments: f32
    assert s0.mu["layers"]["wq"].shape == (0,)          # stateless: empty

    # only the stored momentum is quantized — the update (normalized
    # direction) stays in the gradient dtype on every route
    u0, _ = tx.update(grads, s0, params)
    assert u0["lm_head"]["w"].dtype == grads["lm_head"]["w"].dtype

    # vectors route to Adam even when listed in momentum_on: init and
    # update must agree on f32 mu (state-dtype fixed point)
    tx_v = make_optimizer("scale", 1e-2, impl=impl,
                          momentum_dtype="bfloat16",
                          momentum_on=("last", "vector"))
    sv = tx_v.init(params)
    assert sv.mu["bias"]["b"].dtype == jnp.float32
    sv1 = jax.eval_shape(lambda g, s, p: tx_v.update(g, s, p)[1],
                         grads, sv, params)
    for a, b in zip(jax.tree_util.tree_leaves(jax.eval_shape(lambda: sv)),
                    jax.tree_util.tree_leaves(sv1)):
        assert a.shape == b.shape and a.dtype == b.dtype

    # fixed point: update and update_params preserve every state aval
    for step in (lambda g, s, p: tx.update(g, s, p)[1],
                 lambda g, s, p: tx.update_params(g, s, p)[1]):
        s1 = jax.eval_shape(step, grads, s0, params)
        assert (jax.tree_util.tree_structure(jax.eval_shape(lambda: s0))
                == jax.tree_util.tree_structure(s1))
        for a, b in zip(jax.tree_util.tree_leaves(s0),
                        jax.tree_util.tree_leaves(s1)):
            assert a.shape == b.shape and a.dtype == b.dtype

    # quality: bf16 momentum tracks the f32 run within rounding tolerance
    tx32 = make_optimizer("scale", 1e-2, impl=impl)
    p16, s16 = params, tx.init(params)
    p32, s32 = params, tx32.init(params)
    for _ in range(3):
        p16, s16 = tx.update_params(grads, s16, p16)
        p32, s32 = tx32.update_params(grads, s32, p32)
    for a, b in zip(jax.tree_util.tree_leaves(p16),
                    jax.tree_util.tree_leaves(p32)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)


def test_scale_bf16_momentum_fused_matches_jnp():
    """impl='fused' and impl='jnp' agree under bf16 momentum storage."""
    params = make_params()
    grads = make_grads(params)
    txs = [make_optimizer("scale", 1e-2, impl=i, momentum_dtype="bfloat16")
           for i in ("jnp", "fused")]
    states = [tx.init(params) for tx in txs]
    ps = [params, params]
    for _ in range(3):
        for i, tx in enumerate(txs):
            ps[i], states[i] = tx.update_params(grads, states[i], ps[i])
    for a, b in zip(jax.tree_util.tree_leaves((ps[0], states[0])),
                    jax.tree_util.tree_leaves((ps[1], states[1]))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)


def test_scale_momentum_dtype_rejects_unknown():
    with pytest.raises(ValueError, match="momentum_dtype"):
        make_optimizer("scale", 1e-2, momentum_dtype="float16")


# ---------------------------------------------------------------------------
# Registry + staged-pipeline zoo matrix
# ---------------------------------------------------------------------------

def test_registry_rejects_unknown_name():
    with pytest.raises(ValueError, match="unknown optimizer 'adamm'"):
        make_optimizer("adamm", 1e-3)
    # the error enumerates the valid choices
    with pytest.raises(ValueError, match="scale"):
        make_optimizer("nope", 1e-3)


def test_registry_rejects_unknown_kwarg():
    with pytest.raises(ValueError, match=r"unknown kwarg.*'adam'"):
        make_optimizer("adam", 1e-3, beta3=0.9)
    with pytest.raises(ValueError, match="valid kwargs"):
        make_optimizer("scale", 1e-3, momemtum_on=("last",))
    # known kwargs still pass through
    make_optimizer("adam", 1e-3, weight_decay=0.1)


def test_registry_exposes_specs():
    from repro.core import OPTIMIZER_REGISTRY
    assert tuple(OPTIMIZER_REGISTRY) == OPTIMIZER_NAMES
    fused = {n for n, s in OPTIMIZER_REGISTRY.items() if s.fused}
    assert fused == {"scale", "scale_fused", "adapm", "sgd_colnorm",
                     "sgd_rownorm"}
    assert "momentum" in OPTIMIZER_REGISTRY["sgd_momentum"].valid_kwargs()
    assert OPTIMIZER_REGISTRY["adamw"].defaults == {"weight_decay": 0.01}


@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
@pytest.mark.parametrize("name", [n for n in OPTIMIZER_NAMES
                                  if n != "scale_fused"])
def test_zoo_update_params_matches_classic_path(name, gdtype):
    """Every registry optimizer's write path is bitwise the classic path.

    The pipeline's jnp write branch must replay the exact
    update -> astype(g.dtype) -> p + u.astype(p.dtype) cast chain, so the
    trainer auto-switching onto update_params cannot change a trajectory
    for any zoo member (scale_fused is covered by the fused parity tests
    at tolerance).
    """
    params = make_params()
    kw = {"rank": 4} if name in ("galore", "fira", "apollo") else {}
    tx = make_optimizer(name, 1e-2, **kw)
    assert tx.update_params is not None
    grads = jax.tree_util.tree_map(
        lambda p: (0.1 * jnp.ones_like(p) + 0.01 * p).astype(
            gdtype if p.ndim > 1 else p.dtype), params)
    sa, sb = tx.init(params), tx.init(params)
    pa = pb = params
    # unjitted on purpose: op-by-op execution is the bitwise reference
    # (under jit XLA may contract the -lr*d multiply and the p+u add into
    # an fma, a 1-ulp difference that is fusion, not semantics)
    for _ in range(3):
        ua, sa = tx.update(grads, sa, pa)
        pa = apply_updates(pa, ua)
        pb, sb = tx.update_params(grads, sb, pb)
    for x, y in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", ["sgd_colnorm", "sgd_rownorm"])
def test_normalized_sgd_fused_impl_matches_reference(name):
    """impl='fused' (interpret-mode kernels off-TPU) vs the jnp reference."""
    params = make_params()
    grads = make_grads(params)
    tx_ref = make_optimizer(name, 1e-2)
    tx_fus = make_optimizer(name, 1e-2, impl="fused")
    sa, sb = tx_ref.init(params), tx_fus.init(params)
    pa = pb = params
    for _ in range(2):
        ua, sa = tx_ref.update(grads, sa, pa)
        pa = apply_updates(pa, ua)
        pb, sb = tx_fus.update_params(grads, sb, pb)
    for x, y in zip(jax.tree_util.tree_leaves(pa),
                    jax.tree_util.tree_leaves(pb)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-5)


@pytest.mark.parametrize("name", ["adam", "muon"])
def test_momentum_dtype_bf16_extends_to_zoo(name):
    """momentum_dtype='bfloat16' on adam/muon: >=2-D first moments stored
    bf16, second moments + vector moments stay f32, state is an eval_shape
    fixed point, and the trajectory tracks f32 within bf16 rounding."""
    params = make_params()
    grads = make_grads(params)
    tx16 = make_optimizer(name, 1e-3, momentum_dtype="bfloat16")
    tx32 = make_optimizer(name, 1e-3)
    s16 = tx16.init(params)
    assert s16.mu["lm_head"]["w"].dtype == jnp.bfloat16
    assert s16.mu["layers"]["wq"].dtype == jnp.bfloat16
    assert s16.mu["bias"]["b"].dtype == jnp.float32
    for l in jax.tree_util.tree_leaves(s16.nu):
        assert l.dtype == jnp.float32
    a0 = jax.eval_shape(tx16.init, params)
    a1 = jax.eval_shape(lambda g, s, p: tx16.update(g, s, p)[1],
                        grads, a0, params)
    for a, b in zip(jax.tree_util.tree_leaves(a0),
                    jax.tree_util.tree_leaves(a1)):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert a.weak_type == b.weak_type
    s32 = tx32.init(params)
    p16 = p32 = params
    for _ in range(3):
        u16, s16 = tx16.update(grads, s16, p16)
        p16 = apply_updates(p16, u16)
        u32, s32 = tx32.update(grads, s32, p32)
        p32 = apply_updates(p32, u32)
    for x, y in zip(jax.tree_util.tree_leaves(p16),
                    jax.tree_util.tree_leaves(p32)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", ["adam", "muon", "normalized_sgd"])
def test_momentum_dtype_rejects_unknown_across_zoo(name):
    from repro.core import adam, muon, normalized_sgd
    fn = {"adam": adam, "muon": muon, "normalized_sgd": normalized_sgd}[name]
    with pytest.raises(ValueError, match="momentum_dtype"):
        fn(1e-3, momentum_dtype="fp8")


def test_adams_matches_reference_and_keeps_sgdm_state():
    """AdamS: denom is synthesized from (m, g) each step — no nu buffer."""
    from repro.core import make_optimizer
    params = make_params()
    grads = make_grads(params)
    b1, b2, eps, lr, wd = 0.9, 0.999, 1e-8, 1e-2, 0.1
    tx = make_optimizer("adams", lr, b1=b1, b2=b2, eps=eps, weight_decay=wd)
    s = tx.init(params)
    # SGDM-sized: first moment allocated everywhere, second moment nowhere
    for l in jax.tree_util.tree_leaves(s.nu):
        assert l.size == 0
    for m, p in zip(jax.tree_util.tree_leaves(s.mu),
                    jax.tree_util.tree_leaves(params)):
        assert m.shape == p.shape

    m_ref = jax.tree_util.tree_map(lambda p: np.zeros(p.shape, np.float32),
                                   params)
    for t in range(3):
        upd, s = tx.update(grads, s, params)
        for path in (("tok_embed", "w"), ("lm_head", "w"), ("bias", "b")):
            g = np.asarray(grads[path[0]][path[1]], np.float32)
            p = np.asarray(params[path[0]][path[1]], np.float32)
            m = m_ref[path[0]][path[1]]
            m[...] = b1 * m + (1 - b1) * g
            mh = m / (1 - b1 ** (t + 1))
            den = np.sqrt(b2 * mh ** 2 + (1 - b2) * g ** 2) + eps
            np.testing.assert_allclose(
                np.asarray(upd[path[0]][path[1]]),
                -lr * (mh / den + wd * p), rtol=1e-6, atol=1e-7)


def test_adapm_is_scale_with_embedding_momentum():
    """AdaPM = SCALE's plan with momentum on first AND last groups."""
    from repro.core import make_optimizer
    params = make_params()
    grads = make_grads(params)
    tx = make_optimizer("adapm", 1e-2)
    s = tx.init(params)
    u, s = tx.update(grads, s, params)
    assert s.mu["tok_embed"]["w"].size > 0       # embedding carries momentum
    assert s.mu["lm_head"]["w"].size > 0         # head carries momentum
    assert s.mu["layers"]["wq"].size == 0        # hidden stays stateless
    # hidden-matrix updates are bitwise SCALE's (same stateless colnorm)
    tx0 = make_optimizer("scale", 1e-2)
    u0, _ = tx0.update(grads, tx0.init(params), params)
    np.testing.assert_array_equal(np.asarray(u["layers"]["wq"]),
                                  np.asarray(u0["layers"]["wq"]))
