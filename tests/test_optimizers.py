"""Unit tests for SCALE + every baseline optimizer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LabelRules, apply_updates, colnorm, label_tree,
                        make_optimizer, OPTIMIZER_NAMES)
from repro.core.labels import partition_sizes


def make_params():
    k = jax.random.PRNGKey(0)
    return {
        "tok_embed": {"w": jax.random.normal(k, (32, 16))},
        "layers": {"wq": jax.random.normal(k, (2, 16, 16)),
                   "norm": jnp.ones((2, 16))},
        "lm_head": {"w": jax.random.normal(k, (16, 64))},
        "bias": {"b": jnp.zeros((16,))},
    }


def make_grads(params, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed),
                          len(jax.tree_util.tree_leaves(params)))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, l.shape) for k, l in zip(ks, leaves)])


def test_labels():
    params = make_params()
    labels = label_tree(params)
    assert labels["tok_embed"]["w"] == "first"
    assert labels["lm_head"]["w"] == "last"
    assert labels["layers"]["wq"] == "matrix"
    assert labels["layers"]["norm"] == "vector"  # stacked norm scale
    assert labels["bias"]["b"] == "vector"
    sizes = partition_sizes(params)
    assert sizes["last"] == 16 * 64 and sizes["first"] == 32 * 16


@pytest.mark.parametrize("name", [n for n in OPTIMIZER_NAMES
                                  if n != "scale_fused"])
def test_optimizer_steps_finite_and_decrease_quadratic(name):
    """3 steps on a toy quadratic: finite updates, params move."""
    params = make_params()
    kw = {"rank": 4} if name in ("galore", "fira", "apollo") else {}
    tx = make_optimizer(name, 1e-2, **kw)
    state = tx.init(params)
    p = params
    for _ in range(3):
        grads = jax.tree_util.tree_map(lambda x: 0.5 * x, p)  # grad of 0.25||p||^2
        upd, state = jax.jit(tx.update)(grads, state, p)
        p = apply_updates(p, upd)
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(params)):
        assert bool(jnp.all(jnp.isfinite(a)))
    assert float(jnp.linalg.norm(p["lm_head"]["w"])) < \
        float(jnp.linalg.norm(params["lm_head"]["w"]))


def test_adam_matches_closed_form_scalar():
    tx = make_optimizer("adam", 0.1, b1=0.9, b2=0.999, eps=1e-8)
    params = {"x": jnp.asarray([2.0])}
    state = tx.init(params)
    g = {"x": jnp.asarray([1.0])}
    upd, state = tx.update(g, state, params)
    # bias-corrected first step of Adam is exactly -lr * g/(|g|+eps) = -lr
    np.testing.assert_allclose(np.asarray(upd["x"]), [-0.1], rtol=1e-5)


def test_scale_update_matches_manual():
    """The SCALE matrix update is -lr * colnorm(g); head uses momentum EMA."""
    lr, beta = 1e-2, 0.9
    tx = make_optimizer("scale", lr, beta=beta)
    params = make_params()
    state = tx.init(params)
    g1 = make_grads(params, 1)
    upd, state = tx.update(g1, state, params)
    np.testing.assert_allclose(
        np.asarray(upd["layers"]["wq"]),
        np.asarray(-lr * colnorm(g1["layers"]["wq"])), atol=1e-6)
    m1 = (1 - beta) * g1["lm_head"]["w"]
    np.testing.assert_allclose(np.asarray(upd["lm_head"]["w"]),
                               np.asarray(-lr * colnorm(m1)), atol=1e-5)
    # second step momentum recursion
    g2 = make_grads(params, 2)
    upd2, state = tx.update(g2, state, params)
    m2 = beta * m1 + (1 - beta) * g2["lm_head"]["w"]
    np.testing.assert_allclose(np.asarray(upd2["lm_head"]["w"]),
                               np.asarray(-lr * colnorm(m2)), atol=1e-5)


def test_scale_state_is_memory_minimal():
    """Momentum buffers exist ONLY for the lm_head (+ tiny vector Adam)."""
    params = make_params()
    tx = make_optimizer("scale", 1e-3)
    state = tx.init(params)
    assert state.mu["lm_head"]["w"].shape == params["lm_head"]["w"].shape
    assert state.mu["layers"]["wq"].size == 0      # stateless matrices
    assert state.mu["tok_embed"]["w"].size == 0    # no first-layer momentum
    assert state.nu["lm_head"]["w"].size == 0      # no 2nd moment anywhere
    assert state.mu["bias"]["b"].shape == (16,)    # vector Adam


def test_scale_momentum_first_last_ablation():
    from repro.core import scale
    tx = scale(1e-3, momentum_on=("first", "last"))
    params = make_params()
    state = tx.init(params)
    assert state.mu["tok_embed"]["w"].shape == params["tok_embed"]["w"].shape


def test_stable_spam_momentum_reset():
    tx = make_optimizer("stable_spam", 1e-3, reset_interval=2)
    params = {"w": jnp.ones((4, 4))}
    state = tx.init(params)
    g = {"w": jnp.ones((4, 4))}
    _, state = tx.update(g, state, params)   # count 0 -> no reset (count>0 guard)
    _, state = tx.update(g, state, params)   # count 1
    mu_before = np.asarray(state.mu["w"]).copy()
    _, state = tx.update(g, state, params)   # count 2 -> reset fired this step
    assert np.all(np.abs(mu_before) > 0)


def test_muon_adam_branch_for_head():
    tx = make_optimizer("muon", 1e-3)
    params = make_params()
    state = tx.init(params)
    g = make_grads(params)
    upd, _ = tx.update(g, state, params)
    # head goes through adam (not NS): update magnitude ~lr, element-wise
    assert float(jnp.max(jnp.abs(upd["lm_head"]["w"]))) < 5e-3


def test_galore_projection_shapes():
    from repro.core import galore
    tx = galore(1e-3, rank=4)
    params = make_params()
    state = tx.init(params)
    # low-rank states for hidden matrices only
    assert state.mu["layers"]["wq"].shape[-2:] in ((4, 16), (16, 4))
    assert state.mu["lm_head"]["w"].shape == params["lm_head"]["w"].shape


def test_schedule_warmup_cosine():
    from repro.core import linear_warmup_cosine
    s = linear_warmup_cosine(1.0, 100, warmup_frac=0.1, final_frac=0.1)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert float(s(100)) <= 0.12
    assert float(s(50)) < 1.0
