"""Flash attention: jnp scan reference (custom VJP) vs naive oracle, the
fused Pallas kernels behind ``dispatch.flash_attention`` vs that reference,
and the shard_map'd variant on a forced-8-device (4, 2) host mesh.

Layered like the xent tests: first pin the scan reference (including the
rectangular-causal T > S support cached prefill continuation needs), then
hold the fused dispatch path — interpret oracle on CPU — to it for the
forward and dQ/dK/dV across dtypes, GQA ratios, ragged shapes, the
``kv_len`` decode bound and fully-masked rows, and finally the sharded
matrix in a subprocess mesh.
"""
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import repro_fused
from repro.kernels import dispatch
from repro.kernels.attention import ref as aref
from repro.models.layers import (_pick_block, causal_blockwise_attention,
                                 chunked_q_attention, decode_attention,
                                 flash_attention, largest_divisor)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # minimal install: only the property test skips
    HAVE_HYPOTHESIS = False


def naive(q, k, v, scale, causal=True, kv_len=None):
    """The test-scale full-softmax oracle (kernels/attention/ref.py)."""
    return aref.attention(q, k, v, scale=scale, causal=causal, kv_len=kv_len)


def _gqa(B, S, T, H, K, hd, dtype=jnp.float32, seed=0, hdv=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, K, hdv or hd),
                          jnp.float32).astype(dtype)
    dout = jax.random.normal(ks[3], (B, S, H, hdv or hd),
                             jnp.float32).astype(dtype)
    return q, k, v, dout


# ---- the jnp scan reference ------------------------------------------------

@pytest.mark.parametrize("B,S,T,H,hd,blk,causal", [
    (2, 64, 64, 4, 16, 16, True),
    (1, 128, 128, 8, 32, 32, True),
    (2, 96, 96, 2, 8, 48, True),
    (2, 64, 32, 4, 16, 16, False),
    (1, 60, 60, 2, 8, 16, True),     # non-divisible -> block fallback
    (1, 16, 48, 2, 8, 16, True),     # rectangular causal: cached prefill
    (2, 24, 60, 2, 8, 12, True),     # rectangular + block fallback
])
def test_flash_matches_naive(B, S, T, H, hd, blk, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    dout = jax.random.normal(ks[3], (B, S, H, hd))
    out = flash_attention(q, k, v, blk, hd ** -0.5, causal)
    ref = naive(q, k, v, hd ** -0.5, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    f = lambda *a: jnp.sum(flash_attention(*a, blk, hd ** -0.5, causal) * dout)
    g = lambda *a: jnp.sum(naive(*a, hd ** -0.5, causal) * dout)
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gg = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gg):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_rectangular_causal_rejects_more_queries_than_keys():
    q, k, v, _ = _gqa(1, 8, 4, 2, 2, 8)
    with pytest.raises(ValueError, match="needs T >= S"):
        flash_attention(q, k, v, 4, 0.35, True)


if HAVE_HYPOTHESIS:
    @given(seed=st.integers(0, 2**16), s_blocks=st.integers(1, 4),
           h=st.sampled_from([1, 2, 4]), hd=st.sampled_from([4, 8, 16]))
    @settings(max_examples=15, deadline=None)
    def test_flash_property(seed, s_blocks, h, hd):
        S = 16 * s_blocks
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, S, h, hd))
        k = jax.random.normal(ks[1], (1, S, h, hd))
        v = jax.random.normal(ks[2], (1, S, h, hd))
        out = flash_attention(q, k, v, 16, hd ** -0.5, True)
        ref = naive(q, k, v, hd ** -0.5, True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5)


def test_gqa_repeat_equivalence():
    """GQA via repeated kv == grouped-head einsum oracle (reference path)."""
    B, S, H, K, hd = 2, 64, 8, 2, 16
    q, k, v, _ = _gqa(B, S, S, H, K, hd, seed=1)
    with repro_fused("off"):
        out = causal_blockwise_attention(q, k, v, 16, hd ** -0.5)
    kf = jnp.repeat(k, H // K, 2)
    vf = jnp.repeat(v, H // K, 2)
    ref = naive(q, kf, vf, hd ** -0.5, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_q_attention_kv_len_mask():
    B, S, T, H, hd = 1, 4, 32, 2, 8
    q, k, v, _ = _gqa(B, S, T, H, H, hd, seed=2)
    out = chunked_q_attention(q, k, v, 4, hd ** -0.5, kv_len=jnp.asarray(10))
    ref = naive(q, k[:, :10], v[:, :10], hd ** -0.5, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# ---- shared divisor helper (block fallbacks) -------------------------------

def test_largest_divisor():
    assert largest_divisor(60, 16) == 15
    assert largest_divisor(64, 64) == 64
    assert largest_divisor(17, 16) == 1
    assert largest_divisor(1, 8) == 1


def test_pick_block_common_divisor_and_warning():
    # common-divisor search replaces the silent decrement loop
    assert _pick_block(64, 64, 16) == 16
    assert _pick_block(60, 60, 16) == 15
    assert _pick_block(24, 60, 16) == 12
    with pytest.warns(UserWarning, match="tile shrinks to 1"):
        assert _pick_block(17, 17, 16) == 1  # prime S: per-position scan
    with pytest.warns(UserWarning, match="tile shrinks"):
        assert _pick_block(2 * 97, 2 * 97, 64) == 2
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # >= half the target: silent
        assert _pick_block(60, 60, 16) == 15
        assert _pick_block(32, 48, 16) == 16


# ---- fused dispatch parity -------------------------------------------------

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    # bf16 dK/dV reduce over up to 8 group heads of bf16-rounded products
    return 6e-2 if dtype == jnp.bfloat16 else 2e-5


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [
    (2, 32, 32, 4, 4, 16, True),    # GQA ratio 1
    (2, 32, 32, 8, 2, 16, True),    # GQA ratio 4
    (1, 32, 32, 8, 1, 8, True),     # GQA ratio 8 (MQA)
    (1, 60, 124, 4, 2, 8, True),    # ragged rectangular causal T > S
    (2, 48, 20, 4, 2, 8, False),    # ragged non-causal cross attention
], ids=["gqa1", "gqa4", "gqa8", "rect_ragged", "cross_ragged"])
def test_fused_flash_matches_reference(shape, dtype):
    """dispatch.flash_attention (kernels, no kv repeat) == repeated-kv scan
    for the forward and all three gradients."""
    B, S, T, H, K, hd, causal = shape
    q, k, v, dout = _gqa(B, S, T, H, K, hd, dtype, seed=3)
    scale = hd ** -0.5
    assert dispatch.attn_route(q.shape, k.shape, causal)[0] == "kernel"

    def f_fused(q, k, v):
        return jnp.sum(dispatch.flash_attention(
            q, k, v, scale=scale, causal=causal).astype(jnp.float32)
            * dout.astype(jnp.float32))

    # reference: the jnp scan over repeated kv (grad through the repeat
    # sums group heads back onto the (B, T, K, hd) layout)
    def f_ref(q, k, v):
        kf, vf = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
        return jnp.sum(flash_attention(q, kf, vf, 16, scale, causal)
                       .astype(jnp.float32) * dout.astype(jnp.float32))

    v1, g1 = jax.value_and_grad(f_fused, argnums=(0, 1, 2))(q, k, v)
    v2, g2 = jax.value_and_grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    tol = _tol(dtype)
    np.testing.assert_allclose(float(v1), float(v2),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)
    for a, b, name in zip(g1, g2, ("dq", "dk", "dv")):
        assert a.shape == b.shape and a.dtype == b.dtype, name
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol,
                                   err_msg=name)


def test_fused_decode_over_cache_kv_len():
    """The rectangular decode shape (S=1..block vs a T cache) with the
    traced kv_len bound == chunked_q_attention == naive over k[:kv_len]."""
    B, T, H, K, hd = 2, 64, 4, 2, 8
    scale = hd ** -0.5
    for S, fill in ((1, 10), (4, 33), (8, 64)):
        q, k, v, _ = _gqa(B, S, T, H, K, hd, seed=4 + S)
        kv_len = jnp.asarray(fill)
        out = dispatch.flash_attention(q, k, v, scale=scale, causal=False,
                                       kv_len=kv_len)
        ref = chunked_q_attention(q, k, v, S, scale, kv_len=kv_len)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5)
        kf, vf = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
        nref = naive(q, kf[:, :fill], vf[:, :fill], scale, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(nref),
                                   atol=2e-5)
    # decode_attention routes the same call (and falls back bitwise)
    q, k, v, _ = _gqa(B, 1, T, H, K, hd, seed=9)
    out = decode_attention(q, k, v, 1, scale, kv_len=jnp.asarray(7))
    with repro_fused("off"):
        ref = decode_attention(q, k, v, 1, scale, kv_len=jnp.asarray(7))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_fused_fully_masked_rows_emit_zero():
    """kv_len=0 masks every key: the flash convention (l clamped at 1e-30)
    emits exactly 0 output and 0 gradients — where a naive softmax NaNs."""
    q, k, v, dout = _gqa(1, 4, 16, 4, 2, 8, seed=10)
    out = dispatch.flash_attention(q, k, v, scale=0.35, causal=False,
                                   kv_len=jnp.asarray(0))
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    grads = jax.grad(
        lambda q, k, v: jnp.sum(dispatch.flash_attention(
            q, k, v, scale=0.35, causal=False, kv_len=jnp.asarray(0))
            * dout), argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        np.testing.assert_array_equal(np.asarray(g), 0.0)


def test_attn_routing_and_fallbacks(monkeypatch):
    assert dispatch.attn_supported((2, 8, 4, 16), (2, 8, 2, 16))
    assert dispatch.attn_supported((2, 8, 4, 16), (2, 32, 2, 16))  # T > S
    assert not dispatch.attn_supported((2, 8, 4, 16), (2, 4, 2, 16))  # T < S
    assert dispatch.attn_supported((2, 8, 4, 16), (2, 4, 2, 16),
                                   causal=False)
    assert not dispatch.attn_supported((2, 8, 4, 16), (2, 8, 3, 16))  # H % K
    assert not dispatch.attn_supported((2, 8, 4, 16), (2, 8, 2, 8))  # hd
    assert not dispatch.attn_supported((2, 8, 4, 16), (1, 8, 2, 16))  # B
    assert not dispatch.attn_supported((8, 4, 16), (8, 2, 16))  # ndim
    assert dispatch.attn_route((2, 8, 4, 16), (2, 8, 2, 16))[0] == "kernel"
    # causal + kv_len has no implemented semantics on either route: the
    # entry point must refuse rather than silently pick one per route
    qe, ke, ve, _ = _gqa(1, 4, 8, 2, 2, 8, seed=14)
    with pytest.raises(ValueError, match="kv_len requires causal=False"):
        dispatch.flash_attention(qe, ke, ve, scale=0.35, causal=True,
                                 kv_len=jnp.asarray(4))
    monkeypatch.setenv("REPRO_FUSED", "off")
    assert dispatch.attn_route((2, 8, 4, 16), (2, 8, 2, 16))[0] == "ref"
    with pytest.raises(ValueError, match="kv_len requires causal=False"):
        dispatch.flash_attention(qe, ke, ve, scale=0.35, causal=True,
                                 kv_len=jnp.asarray(4))
    # the off-route still yields correct (scan-reference) values, bitwise
    q, k, v, _ = _gqa(1, 16, 16, 4, 2, 8, seed=11)
    out = dispatch.flash_attention(q, k, v, scale=0.35)
    kf, vf = jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(flash_attention(q, kf, vf, 128, 0.35,
                                                    True)))


def test_forward_fused_equals_scan_reference():
    """End-to-end: a tiny model forward + loss grads with the default
    (fused) attention == the REPRO_FUSED=off scan path."""
    from conftest import tiny_cfg
    from repro.models import init_params, loss_fn
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(12), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(13), (2, 32), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}

    def loss(p):
        return loss_fn(p, cfg, batch)[0]

    l_f, g_f = jax.value_and_grad(loss)(params)
    with repro_fused("off"):
        l_r, g_r = jax.value_and_grad(loss)(params)
    np.testing.assert_allclose(float(l_f), float(l_r), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(g_f),
                    jax.tree_util.tree_leaves(g_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ---- sharded matrix on a forced 8-device host mesh ------------------------

def test_sharded_attention_parity_under_forced_8_devices():
    """(4, 2) mesh: batch over "data", heads over "model" — each device
    runs its local (B/4, S, H/2, hd) x (B/4, T, K/2, hd) problem with no
    collectives. out/dQ/dK/dV must match the unsharded scan reference for
    f32 and bf16 across GQA ratios; inexpressible layouts fall back."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels import dispatch
from repro.models.layers import flash_attention

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
B, S, T, H, hd = 8, 16, 16, 8, 8
scale = hd ** -0.5
qsh = NamedSharding(mesh, P("data", None, "model", None))
for dtype in (jnp.float32, jnp.bfloat16):
    for K in (8, 2):  # GQA ratios 1 and 4, kv heads TP-shard alongside q
        ks = jax.random.split(jax.random.PRNGKey(K), 4)
        q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32).astype(dtype)
        k = jax.random.normal(ks[1], (B, T, K, hd), jnp.float32).astype(dtype)
        v = jax.random.normal(ks[2], (B, T, K, hd), jnp.float32).astype(dtype)
        do = jax.random.normal(ks[3], (B, S, H, hd), jnp.float32).astype(dtype)
        route, plan = dispatch.attn_route(q.shape, k.shape, True, None,
                                          qsh, qsh)
        assert route == "kernel" and plan.batch_axes == ("data",) \
            and plan.head_axes == ("model",), (route, plan)
        q_s, k_s, v_s = (jax.device_put(x, qsh) for x in (q, k, v))

        def f_fused(q, k, v):
            return jnp.sum(dispatch.flash_attention(
                q, k, v, scale=scale, causal=True, q_sharding=qsh,
                kv_sharding=qsh).astype(jnp.float32)
                * do.astype(jnp.float32))

        def f_ref(q, k, v):
            kf, vf = jnp.repeat(k, H // K, 2), jnp.repeat(v, H // K, 2)
            return jnp.sum(flash_attention(q, kf, vf, 16, scale, True)
                           .astype(jnp.float32) * do.astype(jnp.float32))

        v1, g1 = jax.value_and_grad(f_fused, argnums=(0, 1, 2))(q_s, k_s, v_s)
        v2, g2 = jax.value_and_grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        # bf16 compares bf16-rounded outputs/grads whose sums/reductions
        # round differently between the two implementations
        tol = 1e-1 if dtype == jnp.bfloat16 else 1e-4
        np.testing.assert_allclose(
            float(v1), float(v2),
            rtol=5e-2 if dtype == jnp.bfloat16 else 1e-5)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=tol)

# MQA: K=1 cannot shard over the 2-way head axis while q does -> the
# kernel's q_head // group indexing would misalign; must fall back
ksh1 = NamedSharding(mesh, P("data", None, None, None))
assert dispatch.attn_route((8, 16, 8, 8), (8, 16, 1, 8), True, None,
                           qsh, ksh1)[0] == "ref"
# sequence-sharded kv (the decode cache layout) -> ref
cache_sh = NamedSharding(mesh, P("data", "model", None, None))
assert dispatch.attn_route((8, 1, 8, 8), (8, 16, 8, 8), False, None,
                           NamedSharding(mesh, P("data", None, None, None)),
                           cache_sh)[0] == "ref"
# batch not divisible by its axes -> ref
assert dispatch.attn_route((6, 16, 8, 8), (6, 16, 8, 8), True, None,
                           qsh, qsh)[0] == "ref"

# end-to-end under the mesh: loss_fn(mesh=...) routes attention + xent
# through the sharded kernel plans and must match the off-mesh value
from conftest import tiny_cfg
from repro.models import init_params, loss_fn
from repro.models.sharding import Rules, tree_shardings
from repro.models import param_logical_axes, param_shapes
cfg = tiny_cfg(vocab_size=256)
params = init_params(jax.random.PRNGKey(5), cfg)
toks = jax.random.randint(jax.random.PRNGKey(6), (8, 32), 0, 256)
batch = {"tokens": toks, "labels": toks}
shardings = tree_shardings(param_logical_axes(cfg), mesh, Rules(),
                           param_shapes(cfg))
params_s = jax.tree_util.tree_map(jax.device_put, params, shardings)
l_mesh = loss_fn(params_s, cfg, batch, mesh=mesh)[0]
l_ref = loss_fn(params, cfg, batch)[0]
np.testing.assert_allclose(float(l_mesh), float(l_ref), rtol=1e-5)
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    here = os.path.dirname(__file__)
    env["PYTHONPATH"] = (os.path.join(here, "..", "src") + os.pathsep + here
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
