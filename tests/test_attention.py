"""Flash attention (custom VJP) vs naive oracle — forward and gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import (causal_blockwise_attention,
                                 chunked_q_attention, flash_attention)


def naive(q, k, v, scale, causal=True):
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    if causal:
        S, T = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((S, T), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhqs,bshd->bqhd", p.astype(v.dtype), v)


@pytest.mark.parametrize("B,S,T,H,hd,blk,causal", [
    (2, 64, 64, 4, 16, 16, True),
    (1, 128, 128, 8, 32, 32, True),
    (2, 96, 96, 2, 8, 48, True),
    (2, 64, 32, 4, 16, 16, False),
    (1, 60, 60, 2, 8, 16, True),     # non-divisible -> block fallback
])
def test_flash_matches_naive(B, S, T, H, hd, blk, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    dout = jax.random.normal(ks[3], (B, S, H, hd))
    out = flash_attention(q, k, v, blk, hd ** -0.5, causal)
    ref = naive(q, k, v, hd ** -0.5, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    f = lambda *a: jnp.sum(flash_attention(*a, blk, hd ** -0.5, causal) * dout)
    g = lambda *a: jnp.sum(naive(*a, hd ** -0.5, causal) * dout)
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gg = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gg):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@given(seed=st.integers(0, 2**16), s_blocks=st.integers(1, 4),
       h=st.sampled_from([1, 2, 4]), hd=st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_flash_property(seed, s_blocks, h, hd):
    S = 16 * s_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, h, hd))
    k = jax.random.normal(ks[1], (1, S, h, hd))
    v = jax.random.normal(ks[2], (1, S, h, hd))
    out = flash_attention(q, k, v, 16, hd ** -0.5, True)
    ref = naive(q, k, v, hd ** -0.5, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_gqa_repeat_equivalence():
    """GQA via repeated kv == grouped-head einsum oracle."""
    B, S, H, K, hd = 2, 64, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    out = causal_blockwise_attention(q, k, v, 16, hd ** -0.5)
    kf = jnp.repeat(k, H // K, 2)
    vf = jnp.repeat(v, H // K, 2)
    ref = naive(q, kf, vf, hd ** -0.5, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_q_attention_kv_len_mask():
    B, S, T, H, hd = 1, 4, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    out = chunked_q_attention(q, k, v, 4, hd ** -0.5, kv_len=jnp.asarray(10))
    ref = naive(q, k[:, :10], v[:, :10], hd ** -0.5, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
