"""Sharded fused-update correctness (PR 2).

The fused SCALE step must match the single-device jnp reference when
params/grads are sharded over a ("data", "model") mesh: the kernels run on
local shards and the per-slice sums-of-squares are psum-ed over the mesh
axes sharding each matrix's reduce dim. On a stock single-CPU run these
tests still execute the full shard_map code path (1x1 mesh, size-1
collectives); CI additionally runs this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` where the mesh is
genuinely 4x2, and the subprocess test below forces 8 devices regardless
of the parent process.

Also covers the PR's satellite regressions: REPRO_FUSED participating in
the dispatch cache key, clip-factor folding being exactly clip-then-update,
f32 update_norm under bf16 params, make_host_mesh divisibility validation,
and the grad-accum batch-divisibility error.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from conftest import tiny_cfg
from repro.core import make_optimizer
from repro.kernels import dispatch
from repro.kernels.colnorm import ref as cref
from repro.kernels.scale_head import ref as href

SHAPES_2D = [(64, 128), (128, 64)]
SHAPES_3D = [(2, 64, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]
KINDS = ["col", "row", "larger"]


def _mesh():
    """(data, model) mesh over every available device (4x2 when forced to
    8 host devices, 1x1 on a stock CPU run)."""
    n = len(jax.devices())
    data = max(d for d in range(1, n + 1) if n % d == 0 and d <= max(n // 2, 1))
    return jax.make_mesh((data, n // data), ("data", "model"))


def _sharding(mesh, ndim):
    spec = P("data", "model") if ndim == 2 else P(None, "data", "model")
    return NamedSharding(mesh, spec)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


def _mk(shape, dtype, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    th = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    m = jax.random.normal(ks[2], shape, jnp.float32)
    return th, g, m


@pytest.mark.parametrize("shape", SHAPES_2D + SHAPES_3D)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_sharded_dispatch_parity(shape, dtype, kind):
    """All four entry points: sharded kernels == unsharded jnp oracle."""
    mesh = _mesh()
    sh = _sharding(mesh, len(shape))
    axis = dispatch.resolve_kind(kind, shape)
    th, g, m = _mk(shape, dtype, 3)
    th_s, g_s, m_s = (jax.device_put(x, sh) for x in (th, g, m))
    tol = _tol(dtype)

    np.testing.assert_allclose(
        np.asarray(dispatch.normalize(g_s, kind, sharding=sh), np.float32),
        np.asarray(cref.normalize(g, axis), np.float32), atol=tol)
    np.testing.assert_allclose(
        np.asarray(dispatch.norm_update(th_s, g_s, 0.01, kind, sharding=sh),
                   np.float32),
        np.asarray(cref.norm_update(th, g, 0.01, axis), np.float32), atol=tol)
    gf, gf_s = g.astype(jnp.float32), g_s.astype(jnp.float32)
    m_new, d = dispatch.momentum_norm(m_s, gf_s, 0.9, kind, sharding=sh)
    rm, rd = href.momentum_norm(m, gf, 0.9, axis)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(rm), atol=1e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-5)
    t_new, m_new2 = dispatch.momentum_norm_update(th_s, m_s, gf_s, 0.9, 0.01,
                                                  kind, sharding=sh)
    rt, rm2 = href.momentum_norm_update(th, m, gf, 0.9, 0.01, axis)
    np.testing.assert_allclose(np.asarray(t_new, np.float32),
                               np.asarray(rt, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(m_new2), np.asarray(rm2), atol=1e-5)


def _census_params(dtype=jnp.float32):
    # head (momentum) + 2-D/3-D matrices + vector: every dispatch branch
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    return {
        "tok_embed": {"w": jax.random.normal(ks[0], (64, 32)).astype(dtype)},
        "layers": {"wq": jax.random.normal(ks[1], (2, 32, 64)).astype(dtype),
                   "w2": jax.random.normal(ks[2], (32, 128)).astype(dtype)},
        "norm": {"s": jnp.ones((32,), dtype)},
        "lm_head": {"w": jax.random.normal(ks[3], (32, 64)).astype(dtype)},
    }


def _census_shardings(params, mesh):
    def leaf(p):
        if p.ndim == 2:
            return NamedSharding(mesh, P("data", "model"))
        if p.ndim == 3:
            return NamedSharding(mesh, P(None, "data", "model"))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(leaf, params)


@pytest.mark.parametrize("dtype", DTYPES)
def test_sharded_fused_step_matches_jnp_reference(dtype):
    """update_params with shardings + folded clip == clip-then-update jnp."""
    mesh = _mesh()
    params = _census_params(dtype)
    grads = jax.tree_util.tree_map(
        lambda p: (0.1 * jnp.ones_like(p, jnp.float32)
                   + 0.03 * p.astype(jnp.float32)).astype(p.dtype), params)
    shardings = _census_shardings(params, mesh)
    params_s = jax.device_put(params, shardings)
    grads_s = jax.device_put(grads, shardings)
    clip = jnp.asarray(0.7, jnp.float32)

    ref = make_optimizer("scale", 1e-2)
    fused = make_optimizer("scale", 1e-2, impl="fused")
    p_ref, s_ref = ref.update_params(
        jax.tree_util.tree_map(lambda g: g * clip, grads),
        ref.init(params), params)
    p_sh, s_sh = fused.update_params(grads_s, fused.init(params_s), params_s,
                                     shardings=shardings, grad_scale=clip)
    tol = _tol(dtype)
    for a, b in zip(jax.tree_util.tree_leaves(p_sh),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)
    for a, b in zip(jax.tree_util.tree_leaves(s_sh),
                    jax.tree_util.tree_leaves(s_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_grad_scale_fold_bitwise_on_jnp_path(monkeypatch):
    """With kernels off, folding the clip factor is clip-then-update
    *bitwise* (the scale multiplies g exactly like the trainer tree-map)."""
    monkeypatch.setenv("REPRO_FUSED", "off")
    params = _census_params(jnp.float32)
    grads = jax.tree_util.tree_map(
        lambda p: 0.1 * jnp.ones_like(p) + 0.03 * p, params)
    clip = jnp.asarray(0.37, jnp.float32)
    tx = make_optimizer("scale", 1e-2, impl="fused")
    a, sa = tx.update_params(grads, tx.init(params), params, grad_scale=clip)
    b, sb = tx.update_params(
        jax.tree_util.tree_map(lambda g: g * clip, grads),
        tx.init(params), params)
    for x, y in zip(jax.tree_util.tree_leaves((a, sa)),
                    jax.tree_util.tree_leaves((b, sb))):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_repro_fused_mode_keys_dispatch_cache(monkeypatch):
    """Flipping REPRO_FUSED mid-process must not serve stale compilations:
    the resolved mode is a static arg of the jitted impls (cache-keyed)."""
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 128))
    dispatch._normalize_impl.clear_cache()
    monkeypatch.setenv("REPRO_FUSED", "off")
    a = dispatch.normalize(g)
    assert dispatch._normalize_impl._cache_size() == 1
    monkeypatch.setenv("REPRO_FUSED", "interpret")
    b = dispatch.normalize(g)
    # same shape, new mode -> new cache entry, not a stale 'off' replay
    assert dispatch._normalize_impl._cache_size() == 2
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_make_host_mesh_rejects_non_divisor():
    from repro.launch.mesh import make_host_mesh
    n = len(jax.devices())
    bad = n + 1 if n > 1 else 3
    with pytest.raises(ValueError, match=f"{n} device"):
        make_host_mesh(data=bad)
    with pytest.raises(ValueError):
        make_host_mesh(data=0)
    assert make_host_mesh(data=n).shape["data"] == n


def test_grad_accum_remainder_raises():
    cfg = tiny_cfg()
    tx = make_optimizer("scale", 3e-3)
    from repro.data import make_dataset
    from repro.models import init_params
    from repro.training import init_state, make_train_step
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=0)
    step_fn = jax.jit(make_train_step(cfg, tx, grad_accum=3))
    with pytest.raises(ValueError, match=r"batch size 8 \(remainder 2\)"):
        step_fn(init_state(params, tx), ds.host_batch_at(0))


def test_update_norm_bf16_fused_matches_unfused():
    """Fused-path update_norm (param diff) must be computed in f32: bf16
    params would otherwise round small updates away."""
    cfg = tiny_cfg(dtype="bfloat16")
    from repro.data import make_dataset
    from repro.models import init_params
    from repro.training import init_state, make_train_step
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=8, seed=0)
    batch = ds.host_batch_at(0)
    norms = {}
    for fused in (True, False):
        tx = make_optimizer("scale", 1e-3)
        step_fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0,
                                          fused_apply=fused))
        _, metrics = step_fn(init_state(params, tx), batch)
        norms[fused] = float(metrics["update_norm"])
    assert norms[True] > 0
    # diff-of-params (fused) vs update-tree norm (classic): identical up to
    # the param-dtype rounding of the applied update
    np.testing.assert_allclose(norms[True], norms[False], rtol=0.05)


def test_sharded_parity_under_forced_8_devices():
    """End-to-end 8-way host mesh in a subprocess (works from any parent)."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import make_optimizer
from repro.kernels import dispatch
from repro.kernels.colnorm import ref as cref

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
for dtype in (jnp.float32, jnp.bfloat16):
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    for shape, spec in [((64, 128), P("data", "model")),
                        ((2, 64, 128), P(None, "data", "model"))]:
        sh = NamedSharding(mesh, spec)
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        g = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
        th = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
        g_s, th_s = jax.device_put(g, sh), jax.device_put(th, sh)
        for kind in ("col", "row", "larger"):
            axis = dispatch.resolve_kind(kind, shape)
            out = dispatch.norm_update(th_s, g_s, 0.01, kind, sharding=sh)
            assert out.sharding.is_equivalent_to(sh, len(shape))
            np.testing.assert_allclose(
                np.asarray(out, np.float32),
                np.asarray(cref.norm_update(th, g, 0.01, axis), np.float32),
                atol=tol)
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


FUSED_ZOO = [("scale_fused", "scale", {}),
             ("sgd_colnorm", "sgd_colnorm", {"impl": "fused"}),
             ("sgd_rownorm", "sgd_rownorm", {"impl": "fused"})]


@pytest.mark.parametrize("name,ref_name,kw", FUSED_ZOO,
                         ids=[n for n, _, _ in FUSED_ZOO])
def test_sharded_fused_registry_zoo_matches_reference(name, ref_name, kw):
    """Every fused-capable registry optimizer: sharded update_params with a
    folded clip factor == clip-then-update on the unsharded jnp reference.
    Generalizes the scale-only parity test to the whole fused zoo now that
    the staged pipeline owns the kernel lowering."""
    mesh = _mesh()
    params = _census_params(jnp.float32)
    grads = jax.tree_util.tree_map(
        lambda p: 0.1 * jnp.ones_like(p) + 0.03 * p, params)
    shardings = _census_shardings(params, mesh)
    params_s = jax.device_put(params, shardings)
    grads_s = jax.device_put(grads, shardings)
    clip = jnp.asarray(0.7, jnp.float32)

    ref = make_optimizer(ref_name, 1e-2)
    fused = make_optimizer(name, 1e-2, **kw)
    p_ref, s_ref = ref.update_params(
        jax.tree_util.tree_map(lambda g: g * clip, grads),
        ref.init(params), params)
    p_sh, s_sh = fused.update_params(grads_s, fused.init(params_s), params_s,
                                     shardings=shardings, grad_scale=clip)
    for a, b in zip(jax.tree_util.tree_leaves(p_sh),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s_sh),
                    jax.tree_util.tree_leaves(s_ref)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_fused_zoo_parity_under_forced_8_devices():
    """Fused-capable registry optimizers end-to-end on a real 4x2 mesh:
    sharded update_params == unsharded jnp reference, in a subprocess so
    the 8 forced host devices don't depend on the parent's XLA_FLAGS."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import make_optimizer

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
ks = jax.random.split(jax.random.PRNGKey(5), 3)
params = {"tok_embed": {"w": jax.random.normal(ks[0], (64, 32))},
          "layers": {"wq": jax.random.normal(ks[1], (2, 32, 64))},
          "lm_head": {"w": jax.random.normal(ks[2], (32, 64))},
          "norm": {"s": jnp.ones((32,))}}
grads = jax.tree_util.tree_map(lambda p: 0.1 * jnp.ones_like(p) + 0.03 * p,
                               params)
def sh(p):
    if p.ndim == 2:
        return NamedSharding(mesh, P("data", "model"))
    if p.ndim == 3:
        return NamedSharding(mesh, P(None, "data", "model"))
    return NamedSharding(mesh, P())
shardings = jax.tree_util.tree_map(sh, params)
params_s = jax.device_put(params, shardings)
grads_s = jax.device_put(grads, shardings)
for name, ref_name, kw in [("scale_fused", "scale", {}),
                           ("sgd_colnorm", "sgd_colnorm", {"impl": "fused"})]:
    ref = make_optimizer(ref_name, 1e-2)
    fused = make_optimizer(name, 1e-2, **kw)
    p_ref, _ = ref.update_params(grads, ref.init(params), params)
    p_sh, _ = fused.update_params(grads_s, fused.init(params_s), params_s,
                                  shardings=shardings)
    for a, b in zip(jax.tree_util.tree_leaves(p_sh),
                    jax.tree_util.tree_leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
