"""Packed multi-document pretraining (PR 9): format invariants, the
packed-vs-unpacked parity property, zero cross-document attention, and the
sharded packed train step.

The core invariant: a packed batch's loss and grads equal the same
documents laid out one per row. ``data.pipeline.unpack_to_rows`` is
*offset-preserving* (each document keeps its packed lane positions, all
other lanes are pad), so on the jnp reference attention path
(``REPRO_FUSED=off``) the per-token losses are **bitwise** identical —
every document's tokens hit the same tiles with the same masked lanes in
both layouts. Aggregates (mean loss, param grads) only agree to tolerance
because their summation trees differ across layouts.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import repro_fused, tiny_cfg
from repro.data import make_dataset
from repro.data.pipeline import unpack_to_rows
from repro.kernels.xent import ref as xref
from repro.models import forward, init_params, loss_fn

B, S = 4, 64
KEYS = ("tokens", "labels", "segment_ids", "positions", "loss_weights")


@pytest.fixture(scope="module")
def packed():
    cfg = tiny_cfg()
    ds = make_dataset(cfg, seq_len=S, global_batch=B, seed=3,
                      pack_documents=True)
    return cfg, ds.global_batch_at(step=5)


# ---- format invariants ----------------------------------------------------

def test_packed_batch_format(packed):
    cfg, batch = packed
    assert set(batch) == set(KEYS)
    for k in KEYS:
        assert batch[k].shape == (B, S), k
    segs = np.asarray(batch["segment_ids"])
    poss = np.asarray(batch["positions"])
    labs = np.asarray(batch["labels"])
    toks = np.asarray(batch["tokens"])
    wts = np.asarray(batch["loss_weights"])
    assert segs.min() == 0 and segs.max() >= 2  # multiple docs somewhere
    for b in range(B):
        row = segs[b]
        nz = row[row > 0]
        # docs fill from the left in placement order; pad is the right tail
        assert (np.diff(nz) >= 0).all() and (np.diff(nz) <= 1).all()
        assert (row[len(nz):] == 0).all()
        for s in np.unique(nz):
            lanes = np.flatnonzero(row == s)
            # contiguous document, positions restart at 0
            assert (np.diff(lanes) == 1).all()
            np.testing.assert_array_equal(poss[b, lanes],
                                          np.arange(len(lanes)))
            # labels are next-token WITHIN the document; the last token
            # (and anything weight-0) predicts nothing
            np.testing.assert_array_equal(labs[b, lanes[:-1]],
                                          toks[b, lanes[1:]])
            assert labs[b, lanes[-1]] == -1
            np.testing.assert_array_equal(wts[b, lanes[:-1]], 1.0)
            assert wts[b, lanes[-1]] == 0.0
        pad = row == 0
        assert (labs[b, pad] == -1).all() and (wts[b, pad] == 0.0).all()


def test_packed_batch_deterministic(packed):
    cfg, batch = packed
    ds2 = make_dataset(cfg, seq_len=S, global_batch=B, seed=3,
                       pack_documents=True)
    again = ds2.global_batch_at(step=5)
    for k in KEYS:
        np.testing.assert_array_equal(np.asarray(batch[k]),
                                      np.asarray(again[k]))
    other = ds2.global_batch_at(step=6)
    assert not np.array_equal(np.asarray(batch["tokens"]),
                              np.asarray(other["tokens"]))


def test_unpack_to_rows_is_offset_preserving(packed):
    _, batch = packed
    rows = unpack_to_rows(batch)
    segs = np.asarray(batch["segment_ids"])
    n_docs = sum(len(np.unique(segs[b][segs[b] > 0])) for b in range(B))
    assert rows["tokens"].shape == (n_docs, S)
    i = 0
    for b in range(B):
        for s in np.unique(segs[b]):
            if s == 0:
                continue
            m = segs[b] == s
            np.testing.assert_array_equal(
                np.asarray(rows["tokens"][i])[m],
                np.asarray(batch["tokens"][b])[m])
            assert (np.asarray(rows["segment_ids"][i])[~m] == 0).all()
            assert (np.asarray(rows["labels"][i])[~m] == -1).all()
            i += 1


# ---- the parity property --------------------------------------------------

def _per_token_losses(cfg, params, batch):
    """(B, S) f32 weighted per-token losses on whatever path is active."""
    h, _, _ = forward(params, cfg, batch["tokens"],
                      positions=batch["positions"],
                      segment_ids=batch["segment_ids"])
    per = xref.losses(h, params["lm_head"]["w"], batch["labels"],
                      cfg.vocab_size)
    return per * batch["loss_weights"]


def test_packed_vs_unpacked_bitwise_on_reference_path(packed):
    """Per-token losses are BITWISE equal packed vs unpacked (ref path)."""
    cfg, batch = packed
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows = unpack_to_rows(batch)
    with repro_fused("off"):
        per_p = np.asarray(_per_token_losses(cfg, params, batch))
        per_u = np.asarray(_per_token_losses(cfg, params, rows))
    segs = np.asarray(batch["segment_ids"])
    i = 0
    for b in range(B):
        for s in np.unique(segs[b]):
            if s == 0:
                continue
            m = segs[b] == s
            np.testing.assert_array_equal(per_p[b][m], per_u[i][m],
                                          err_msg=f"row {b} doc {s}")
            i += 1


def test_packed_vs_unpacked_loss_and_grads(packed):
    """Scalar loss and param grads match across layouts (to tolerance:
    the summation trees differ, so aggregates are not bitwise)."""
    cfg, batch = packed
    params = init_params(jax.random.PRNGKey(0), cfg)

    def mean_loss(p, bt):
        return loss_fn(p, cfg, bt)[0]

    with repro_fused("off"):
        lp, gp = jax.value_and_grad(mean_loss)(params, batch)
        lu, gu = jax.value_and_grad(mean_loss)(params,
                                               unpack_to_rows(batch))
    np.testing.assert_allclose(float(lp), float(lu), rtol=1e-6)
    for a, b_ in zip(jax.tree_util.tree_leaves(gp),
                     jax.tree_util.tree_leaves(gu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-6)


def test_packed_fused_path_matches_reference(packed):
    """The fused attention/xent route agrees with the jnp reference on a
    packed batch (interpret oracle on CPU)."""
    cfg, batch = packed
    params = init_params(jax.random.PRNGKey(0), cfg)
    with repro_fused("interpret"):
        lf, _ = loss_fn(params, cfg, batch)
    with repro_fused("off"):
        lr, _ = loss_fn(params, cfg, batch)
    np.testing.assert_allclose(float(lf), float(lr), rtol=1e-5)


def test_zero_cross_document_attention(packed):
    """Perturbing one document leaves every OTHER document's per-token
    losses bitwise unchanged — the segment mask admits no leakage."""
    cfg, batch = packed
    params = init_params(jax.random.PRNGKey(0), cfg)
    segs = np.asarray(batch["segment_ids"])
    b = next(b for b in range(B) if segs[b].max() >= 2)
    mutant = dict(batch)
    toks = np.asarray(batch["tokens"]).copy()
    m1 = segs[b] == 1
    toks[b, m1] = (toks[b, m1] + 7) % cfg.vocab_size
    mutant["tokens"] = jnp.asarray(toks)
    with repro_fused("off"):
        base = np.asarray(_per_token_losses(cfg, params, batch))
        pert = np.asarray(_per_token_losses(cfg, params, mutant))
    other = (segs[b] >= 2)
    np.testing.assert_array_equal(base[b][other], pert[b][other])
    assert not np.array_equal(base[b][m1], pert[b][m1])  # doc 1 DID change
    # untouched rows are bitwise untouched
    rest = [r for r in range(B) if r != b]
    np.testing.assert_array_equal(base[rest], pert[rest])


# ---- sharded packed training ----------------------------------------------

def test_packed_train_cli_under_forced_8_devices():
    """The --pack-documents driver end-to-end on a forced 8-device mesh:
    sharded params, shard_map'd fused kernels, packed batches with the
    extra per-token leaves flowing through the jitted step."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
assert len(jax.devices()) == 8
from repro.launch.train import main
loss = main(["--arch", "qwen2-7b", "--smoke", "--steps", "3",
             "--batch", "8", "--seq", "32", "--pack-documents",
             "--log-every", "1"])
assert loss == loss and loss < 20.0, loss
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
