"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.colnorm import ops as cops, ref as cref
from repro.kernels.scale_head import ops as hops, ref as href

SHAPES = [(8, 128), (256, 256), (256, 512), (512, 256), (1024, 512),
          (64, 384), (768, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(shape, dtype, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    th = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    m = jax.random.normal(ks[2], shape, jnp.float32)
    return th, g, m


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_colnorm_kernel(shape, dtype):
    _, g, _ = _mk(shape, dtype, 0)
    out = cops.colnorm(g)
    ref = cref.colnorm(g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_colnorm_update_kernel(shape, dtype):
    th, g, _ = _mk(shape, dtype, 1)
    out = cops.colnorm_update(th, g, 0.01)
    ref = cref.colnorm_update(th, g, 0.01)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("beta", [0.9, 0.5])
def test_head_update_kernel(shape, dtype, beta):
    th, g, m = _mk(shape, dtype, 2)
    t_new, m_new = hops.head_update(th, m, g, beta, 0.01)
    rt, rm = href.head_update(th, m, g, beta, 0.01)
    np.testing.assert_allclose(np.asarray(t_new, np.float32),
                               np.asarray(rt, np.float32), atol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(rm), atol=1e-5)


def test_momentum_colnorm_direction_unit_columns():
    _, g, m = _mk((256, 256), jnp.float32, 3)
    m_new, d = hops.momentum_colnorm(m, g, 0.9)
    norms = np.linalg.norm(np.asarray(d), axis=0)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_untileable_shape_falls_back():
    g = jax.random.normal(jax.random.PRNGKey(4), (7, 33))
    out = cops.colnorm(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(cref.colnorm(g)),
                               atol=1e-6)


def test_fused_scale_optimizer_equals_reference():
    from repro.core import make_optimizer
    params = {"layers": {"w": jax.random.normal(jax.random.PRNGKey(5), (256, 256))},
              "lm_head": {"w": jax.random.normal(jax.random.PRNGKey(6), (256, 512))}}
    grads = jax.tree_util.tree_map(
        lambda p: 0.1 * jnp.ones_like(p) + 0.01 * p, params)
    a, b = make_optimizer("scale", 1e-2), make_optimizer("scale_fused", 1e-2)
    sa, sb = a.init(params), b.init(params)
    for _ in range(3):
        ua, sa = a.update(grads, sa, params)
        ub, sb = b.update(grads, sb, params)
        for x, y in zip(jax.tree_util.tree_leaves(ua),
                        jax.tree_util.tree_leaves(ub)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)
