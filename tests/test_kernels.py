"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(on CPU the dispatch layer runs the kernel bodies through the Pallas
interpreter, so these exercise the real kernels)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch
from repro.kernels.colnorm import ops as cops, ref as cref
from repro.kernels.scale_head import ops as hops, ref as href

SHAPES = [(8, 128), (256, 256), (256, 512), (512, 256), (1024, 512),
          (64, 384), (768, 128)]
# non-tile-divisible 2-D (vocab-like / odd MLP dims) and stacked 3-D
# (scan-over-layers / per-expert) shapes that must NOT fall back to jnp
RAGGED_SHAPES = [(7, 33), (50, 257), (300, 300), (513, 128), (8, 130)]
STACKED_SHAPES = [(2, 8, 128), (4, 100, 64), (3, 50, 129)]
DTYPES = [jnp.float32, jnp.bfloat16]
KINDS = ["col", "row", "larger"]


def _mk(shape, dtype, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(ks[0], shape, jnp.float32).astype(dtype)
    th = jax.random.normal(ks[1], shape, jnp.float32).astype(dtype)
    m = jax.random.normal(ks[2], shape, jnp.float32)
    return th, g, m


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 1e-5


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_colnorm_kernel(shape, dtype):
    _, g, _ = _mk(shape, dtype, 0)
    out = cops.colnorm(g)
    ref = cref.colnorm(g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_colnorm_update_kernel(shape, dtype):
    th, g, _ = _mk(shape, dtype, 1)
    out = cops.colnorm_update(th, g, 0.01)
    ref = cref.colnorm_update(th, g, 0.01)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("beta", [0.9, 0.5])
def test_head_update_kernel(shape, dtype, beta):
    th, g, m = _mk(shape, dtype, 2)
    t_new, m_new = hops.head_update(th, m, g, beta, 0.01)
    rt, rm = href.head_update(th, m, g, beta, 0.01)
    np.testing.assert_allclose(np.asarray(t_new, np.float32),
                               np.asarray(rt, np.float32), atol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(rm), atol=1e-5)


def test_momentum_colnorm_direction_unit_columns():
    _, g, m = _mk((256, 256), jnp.float32, 3)
    m_new, d = hops.momentum_colnorm(m, g, 0.9)
    norms = np.linalg.norm(np.asarray(d), axis=0)
    np.testing.assert_allclose(norms, 1.0, atol=1e-4)


def test_ragged_shape_stays_fused():
    g = jax.random.normal(jax.random.PRNGKey(4), (7, 33))
    assert dispatch.supported(g.shape, "col")
    out = cops.colnorm(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(cref.colnorm(g)),
                               atol=1e-6)


# ---- dispatch coverage matrix: ndim x norm-kind x dtype x raggedness ------

@pytest.mark.parametrize("shape", RAGGED_SHAPES + STACKED_SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("kind", KINDS)
def test_dispatch_parity_matrix(shape, dtype, kind):
    """Fused vs jnp reference over the full coverage matrix (rtol<=1e-5)."""
    assert dispatch.supported(shape, kind), (shape, kind)
    axis = dispatch.resolve_kind(kind, shape)
    th, g, m = _mk(shape, dtype, 11)
    tol = _tol(dtype)
    np.testing.assert_allclose(
        np.asarray(dispatch.normalize(g, kind), np.float32),
        np.asarray(cref.normalize(g, axis), np.float32), atol=tol)
    np.testing.assert_allclose(
        np.asarray(dispatch.norm_update(th, g, 0.01, kind), np.float32),
        np.asarray(cref.norm_update(th, g, 0.01, axis), np.float32), atol=tol)
    gf = g.astype(jnp.float32)
    m_new, d = dispatch.momentum_norm(m, gf, 0.9, kind)
    rm, rd = href.momentum_norm(m, gf, 0.9, axis)
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(rm), atol=1e-5)
    np.testing.assert_allclose(np.asarray(d), np.asarray(rd), atol=1e-5)
    t_new, m_new2 = dispatch.momentum_norm_update(th, m, gf, 0.9, 0.01, kind)
    rt, rm2 = href.momentum_norm_update(th, m, gf, 0.9, 0.01, axis)
    np.testing.assert_allclose(np.asarray(t_new, np.float32),
                               np.asarray(rt, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(m_new2), np.asarray(rm2), atol=1e-5)


def test_registry_covers_every_op():
    """Every dispatch entry point is registered and parity-checked here.

    Keeps the REGISTRY introspection table honest: a new op added to
    dispatch.py without a REGISTRY entry (or vice versa) fails this test.
    """
    public_ops = {"normalize", "norm_update", "momentum_norm",
                  "momentum_norm_update", "xent_loss", "flash_attention"}
    assert set(dispatch.REGISTRY) == public_ops
    th, g, m = _mk((50, 257), jnp.float32, 21)
    h = jax.random.normal(jax.random.PRNGKey(22), (40, 50))
    lab = jax.random.randint(jax.random.PRNGKey(23), (40,), -1, 250)
    aq = jax.random.normal(jax.random.PRNGKey(24), (2, 16, 4, 8))
    akv = jax.random.normal(jax.random.PRNGKey(25), (2, 16, 2, 8))
    args = {
        "normalize": ((g,), {}),
        "norm_update": ((th, g, 0.01), {}),
        "momentum_norm": ((m, g, 0.9), {}),
        "momentum_norm_update": ((th, m, g, 0.9, 0.01), {}),
        "xent_loss": ((h, th, lab), {"vocab_size": 250}),
        "flash_attention": ((aq, akv, akv), {"scale": 0.35, "causal": True}),
    }
    for op, (fused_fn, ref_fn) in dispatch.REGISTRY.items():
        a, kw = args[op]
        out = fused_fn(*a, **kw)
        ref = ref_fn(*a, **kw)
        out = out if isinstance(out, tuple) else (out,)
        ref = ref if isinstance(ref, tuple) else (ref,)
        for a, b in zip(out, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, err_msg=op)


def test_dispatch_fallback_kinds_do_not_crash():
    """Off-matrix kinds/shapes fall back to jnp instead of erroring."""
    g = jax.random.normal(jax.random.PRNGKey(9), (16, 24))
    from repro.core.normalization import normalize as core_norm
    for kind in ("sign", "ns"):
        np.testing.assert_allclose(
            np.asarray(dispatch.normalize(g, kind)),
            np.asarray(core_norm(g, kind)), atol=1e-6)
    g4 = jax.random.normal(jax.random.PRNGKey(10), (2, 2, 8, 8))
    np.testing.assert_allclose(
        np.asarray(dispatch.normalize(g4, "col")),
        np.asarray(core_norm(g4, "col")), atol=1e-6)
    with pytest.raises(ValueError):
        dispatch.resolve_kind("larger", (16,))


def test_dispatch_backend_mode():
    """Compiled on TPU, interpret oracle elsewhere; 'larger' resolves by shape."""
    assert dispatch.use_interpret() == (dispatch.backend() != "tpu")
    assert dispatch.resolve_kind("larger", (256, 128)) == "col"
    assert dispatch.resolve_kind("larger", (128, 256)) == "row"
    assert dispatch.resolve_kind("larger", (4, 128, 256)) == "row"
    assert not dispatch.supported((128,), "col")       # vectors: Adam branch
    assert not dispatch.supported((2, 2, 8, 8), "col")  # >3-D: jnp fallback
    assert not dispatch.supported((8, 8), "ns")         # NS: jnp fallback


def test_dispatch_off_env(monkeypatch):
    monkeypatch.setenv("REPRO_FUSED", "off")
    assert not dispatch.supported((256, 256), "col")
    monkeypatch.setenv("REPRO_FUSED", "bogus")
    with pytest.raises(ValueError):
        dispatch.supported((256, 256), "col")


# ---- fused optimizer end-to-end ------------------------------------------

def _scale_params():
    # wsq is square on purpose: the 'larger' kind's tie-break must resolve
    # to the same axis in both impls (shared via core.normalization)
    return {
        "tok_embed": {"w": jax.random.normal(jax.random.PRNGKey(5), (50, 32))},
        "layers": {"wq": jax.random.normal(jax.random.PRNGKey(6), (2, 33, 32)),
                   "w2": jax.random.normal(jax.random.PRNGKey(7), (37, 129)),
                   "wsq": jax.random.normal(jax.random.PRNGKey(9), (24, 24))},
        "norm": {"s": jnp.ones((32,))},
        "lm_head": {"w": jax.random.normal(jax.random.PRNGKey(8), (32, 77))},
    }


@pytest.mark.parametrize("kw", [
    {}, {"norm_rest": "row"}, {"norm_last": "larger", "norm_rest": "larger"},
    {"lr_scaling": True}, {"momentum_on": ("last", "matrix")},
], ids=["col", "row", "larger", "lr_scaling", "mmt_matrix"])
def test_fused_scale_optimizer_equals_reference(kw):
    """Fused == jnp over ragged 2-D + stacked 3-D params, all branches."""
    from repro.core import apply_updates, make_optimizer
    params = _scale_params()
    grads = jax.tree_util.tree_map(
        lambda p: 0.1 * jnp.ones_like(p) + 0.01 * p, params)
    a = make_optimizer("scale", 1e-2, **kw)
    b = make_optimizer("scale", 1e-2, impl="fused", **kw)
    sa, sb, sc = a.init(params), b.init(params), b.init(params)
    pa = pb = pc = params
    for _ in range(3):
        ua, sa = a.update(grads, sa, pa)
        pa = apply_updates(pa, ua)
        ub, sb = b.update(grads, sb, pb)
        pb = apply_updates(pb, ub)
        pc, sc = b.update_params(grads, sc, pc)  # fused in-place write
    for x, y, z in zip(jax.tree_util.tree_leaves(pa),
                       jax.tree_util.tree_leaves(pb),
                       jax.tree_util.tree_leaves(pc)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-6)
        np.testing.assert_allclose(np.asarray(x), np.asarray(z), atol=2e-6)
    for x, y, z in zip(jax.tree_util.tree_leaves(sa),
                       jax.tree_util.tree_leaves(sb),
                       jax.tree_util.tree_leaves(sc)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-6)
        np.testing.assert_allclose(np.asarray(x), np.asarray(z), atol=2e-6)


def test_fused_state_treedef_identical_to_jnp():
    """impl='fused' and impl='jnp' states are interchangeable (checkpoints)."""
    from repro.core import make_optimizer
    params = _scale_params()
    sa = make_optimizer("scale", 1e-2).init(params)
    sb = make_optimizer("scale_fused", 1e-2).init(params)
    assert (jax.tree_util.tree_structure(sa)
            == jax.tree_util.tree_structure(sb))
    for x, y in zip(jax.tree_util.tree_leaves(sa),
                    jax.tree_util.tree_leaves(sb)):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
