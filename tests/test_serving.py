"""Serving: prefill+decode must reproduce full-sequence logits; greedy
generation runs end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_cfg
from repro.models import forward, init_params, logits_from_hidden
from repro.training import greedy_generate, make_decode_step, make_prefill_step

CFGS = [
    tiny_cfg("dense"),
    tiny_cfg("mla", attention_kind="mla", q_lora_rank=32, kv_lora_rank=16,
             qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16),
    tiny_cfg("ssm", family="ssm", n_heads=0, n_kv_heads=0, ssm_state=16,
             ssm_headdim=16, ssm_chunk=8),
    tiny_cfg("hybrid", family="hybrid", hybrid_period=4, n_layers=4,
             n_experts=4, top_k=2, ssm_state=16, ssm_headdim=16, ssm_chunk=8,
             capacity_factor=4.0),
]


@pytest.mark.parametrize("cfg", CFGS, ids=lambda c: c.name)
def test_prefill_decode_matches_full(cfg):
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S, P = 2, 32, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    h_full, _, _ = forward(params, cfg, toks, mode="train")
    ref = logits_from_hidden(params, cfg, h_full)[:, -1]

    prefill = jax.jit(make_prefill_step(cfg, max_seq=S))
    decode = jax.jit(make_decode_step(cfg))
    state, logits = prefill(params, toks[:, :P])
    assert int(state.index) == P
    for i in range(P, S):
        state, logits = decode(params, state, toks[:, i:i + 1])
    np.testing.assert_allclose(np.asarray(logits[:, -1]), np.asarray(ref),
                               atol=2e-4)


def test_greedy_generate_deterministic():
    cfg = tiny_cfg("dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                cfg.vocab_size)
    out1 = greedy_generate(cfg, params, prompt, n_steps=6, max_seq=16)
    out2 = greedy_generate(cfg, params, prompt, n_steps=6, max_seq=16)
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert int(jnp.max(out1)) < cfg.vocab_size


def test_decode_cache_donation_shapes():
    cfg = tiny_cfg("dense")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prefill = make_prefill_step(cfg, max_seq=16)
    state, _ = prefill(params, jnp.zeros((1, 8), jnp.int32))
    k = state.cache["seg0_dense"]["attn"]["k"]
    assert k.shape == (2, 1, 16, cfg.n_kv_heads, cfg.head_dim)  # (L,B,S,K,hd)
