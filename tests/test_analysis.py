"""Tests for the repro.analysis static-analysis subsystem.

Each analyzer pass gets fixture snippets with seeded violations asserting
the exact rule IDs fire, plus a clean negative fixture; the registry-drift
pass is exercised against mutated registry rows and a mutated docstring
table; the CLI contract (exit 0 on the committed tree, non-zero on a
seeded fixture) runs through ``python -m repro.analysis`` itself.
"""
import dataclasses
import json
import subprocess
import sys
from pathlib import Path

from repro.analysis import collective_axes, jax_hygiene, kernel_contract
from repro.analysis import registry_drift
from repro.analysis.findings import (Finding, load_baseline,
                                     split_by_baseline, write_baseline)
from repro.analysis.lowering import (extract_region, region_matches,
                                     render_lowering_table)
from repro.core.api import OPTIMIZER_REGISTRY

REPO = Path(__file__).resolve().parents[1]
DISPATCH = REPO / "src" / "repro" / "kernels" / "dispatch.py"


def rules(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------------------
# kernel-contract (KC)
# --------------------------------------------------------------------------

BAD_KERNEL = '''
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref, acc):
    o_ref[...] = jnp.dot(x_ref[...], y_ref[...])
    acc[...] += jnp.sum(x_ref[...])


def run(x, y):
    grid = (4, 4, 2)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                  pl.BlockSpec((8, 8), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
        input_output_aliases={5: 0},
    )(x, y)
'''

BAD_SCRATCH = '''
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, o_ref, acc):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        _kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
        scratch_shapes=[pltpu.VMEM((8, 1), jnp.bfloat16)],
    )(x)
'''

CLEAN_KERNEL = '''
import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mask(m, bm, i):
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
    return rows < m


def _kernel(x_ref, o_ref, *, m, bm):
    i = pl.program_id(0)
    xm = jnp.where(_mask(m, bm, i), x_ref[...], 0.0)
    o_ref[...] = jnp.dot(xm, xm)


def run(x, m, bm):
    return pl.pallas_call(
        functools.partial(_kernel, m=m, bm=bm),
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
    )(x)
'''


def test_kc_missing_mask_and_arity_and_alias():
    found = kernel_contract.analyze_source("fixture.py", BAD_KERNEL)
    assert rules(found) == {"KC001", "KC003", "KC002"}
    # index_map arity flagged for all three 2-arg specs on the 3-D grid
    assert sum(f.rule == "KC001" for f in found) == 3
    # both the dot and the scratch sum accumulation are unmasked
    assert sum(f.rule == "KC003" for f in found) == 2
    # alias key 5 is out of range of the 2 inputs
    assert any(f.rule == "KC002" and "out of range" in f.message
               for f in found)


def test_kc_low_precision_scratch():
    found = kernel_contract.analyze_source("fixture.py", BAD_SCRATCH)
    assert rules(found) == {"KC004"}
    assert "bfloat16" in found[0].message


def test_kc_clean_fixture_negative():
    assert kernel_contract.analyze_source("fixture.py", CLEAN_KERNEL) == []


def test_kc_masked_through_nested_when_and_helper():
    # the real-kernel shape: compute hidden in a nested @pl.when function,
    # mask produced by a tuple-returning helper (resolver must follow both)
    src = '''
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masks(i, bm, m):
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0) + i * bm
    return rows, rows < m


def _kernel(x_ref, o_ref, *, m, bm):
    i = pl.program_id(0)

    @pl.when(i >= 0)
    def _compute():
        _, valid = _masks(i, bm, m)
        xm = jnp.where(valid, x_ref[...], 0.0)
        o_ref[...] = jnp.dot(xm, xm)


def run(x, m, bm):
    import functools
    return pl.pallas_call(
        functools.partial(_kernel, m=m, bm=bm),
        grid=(2,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (i, 0)),
    )(x)
'''
    assert kernel_contract.analyze_source("fixture.py", src) == []


# The segment-id mask shape PR 9's packed attention kernels use: the score
# tile's validity ANDs the iota remainder/causal bounds with a segment-id
# equality ((bq, 1) == (1, bk)) read from dedicated operand refs.
_SEG_KERNEL_TEMPLATE = '''
import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _masks(i, j, bq, bk, kl, qseg, kseg):
    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = {valid_expr}
    valid &= qseg == kseg
    return valid


def _kernel(q_ref, k_ref, v_ref, qs_ref, ks_ref, o_ref, *, bq, bk, kl):
    i, j = pl.program_id(0), pl.program_id(1)
    s = jnp.dot(q_ref[...], k_ref[...])
    valid = _masks(i, j, bq, bk, kl, qs_ref[...], ks_ref[...])
    s = jnp.where(valid, s, -1e30)
    p = jnp.where(valid, jnp.exp(s), 0.0)
    o_ref[...] = jnp.dot(p, v_ref[...])


def run(q, k, v, qs, ks, kl):
    return pl.pallas_call(
        functools.partial(_kernel, bq=8, bk=8, kl=kl),
        grid=(2, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
                  pl.BlockSpec((8, 8), lambda i, j: (0, j)),
                  pl.BlockSpec((8, 8), lambda i, j: (j, 0)),
                  pl.BlockSpec((8, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, 8), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, 0)),
    )(q, k, v, qs, ks)
'''

SEGMENT_KERNEL_CLEAN = _SEG_KERNEL_TEMPLATE.format(
    valid_expr="(cols < kl) & (rows >= cols)")
# segment equality ALONE: remainder lanes of a ragged tile are never
# bounded by the tile iota, so undefined memory still reaches both dots
SEGMENT_KERNEL_SEG_ONLY = _SEG_KERNEL_TEMPLATE.format(
    valid_expr="jnp.full((bq, bk), True)")


def test_kc_segment_mask_with_iota_bound_is_clean():
    assert kernel_contract.analyze_source(
        "fixture.py", SEGMENT_KERNEL_CLEAN) == []


def test_kc_segment_equality_alone_is_not_a_remainder_mask():
    found = kernel_contract.analyze_source("fixture.py",
                                           SEGMENT_KERNEL_SEG_ONLY)
    assert rules(found) == {"KC003"}
    # both the score dot and the p @ v contraction are unprotected
    assert sum(f.rule == "KC003" for f in found) == 2


# --------------------------------------------------------------------------
# collective-axes (CX)
# --------------------------------------------------------------------------

BAD_AXES = '''
from jax import lax
from jax.experimental.shard_map import shard_map

AXIS = "data"


def f(x):
    return lax.psum(x, "model")


def g(x):
    return lax.pmax(x, AXIS)


def h(x, mesh, sp):
    def body(a, b):
        return a + b
    return shard_map(body, mesh=mesh, in_specs=(sp,), out_specs=sp)(x)
'''

CLEAN_AXES = '''
from jax import lax
from jax.experimental.shard_map import shard_map


def f(x, plan):
    axes = plan.spec3[1]
    return lax.psum(x, axes) if axes else x


def h(x, y, mesh, sp):
    def body(a, b):
        return a + b
    return shard_map(body, mesh=mesh, in_specs=(sp, sp),
                     out_specs=sp)(x, y)
'''


def test_cx_seeded_violations():
    found = collective_axes.analyze_source("fixture.py", BAD_AXES)
    assert rules(found) == {"CX001", "CX002", "CX003"}
    by_rule = {f.rule: f for f in found}
    assert "'model'" in by_rule["CX001"].message
    assert "'data'" in by_rule["CX002"].message
    assert "1 entries" in by_rule["CX003"].message


def test_cx_clean_fixture_negative():
    assert collective_axes.analyze_source("fixture.py", CLEAN_AXES) == []


def test_cx_dynamic_dispatch_probe_clean():
    assert collective_axes.check_dispatch_contract() == []


# --------------------------------------------------------------------------
# jax-hygiene (JH)
# --------------------------------------------------------------------------

BAD_HYGIENE = '''
import os
import jax
import jax.numpy as jnp


def step(x):
    if jnp.abs(x).max() > 1.0:
        x = x / 2
    return x


def probe(fn, x):
    try:
        return fn(x, extra=1)
    except TypeError:
        return fn(x)


@jax.jit
def jitted(x):
    mode = os.environ.get("REPRO_FUSED", "auto")
    return x if mode == "off" else x * 2
'''

CLEAN_HYGIENE = '''
import inspect
import os
import jax
import jax.numpy as jnp


def resolve_mode():
    return os.environ.get("REPRO_FUSED", "auto")  # outside jit: fine


def step(x, mode):
    if jnp.issubdtype(x.dtype, jnp.floating):  # static fact: fine
        x = jnp.where(jnp.abs(x) > 1.0, x / 2, x)
    return x


def probe(fn):
    return "extra" in inspect.signature(fn).parameters
'''


def test_jh_seeded_violations():
    found = jax_hygiene.analyze_source("fixture.py", BAD_HYGIENE)
    assert rules(found) == {"JH001", "JH002", "JH003"}


def test_jh_clean_fixture_negative():
    assert jax_hygiene.analyze_source("fixture.py", CLEAN_HYGIENE) == []


# --------------------------------------------------------------------------
# registry-drift (RD)
# --------------------------------------------------------------------------

def test_rd_committed_tree_clean():
    assert registry_drift.run() == []


def test_rd_fused_flag_mutation_fails():
    mutated = dict(OPTIMIZER_REGISTRY)
    mutated["sgd_colnorm"] = dataclasses.replace(
        mutated["sgd_colnorm"], fused=False)
    found = registry_drift.run(registry=mutated)
    got = rules(found)
    # the lowering table drifts, the Stages plans contradict the flag,
    # and the col kind is fused-coverable but marked unfused
    assert {"RD001", "RD003", "RD005"} <= got
    assert any("sgd_colnorm" in f.message for f in found)


def test_rd_registry_row_rename_fails():
    mutated = {("scole" if k == "scale" else k): v
               for k, v in OPTIMIZER_REGISTRY.items()}
    found = registry_drift.run(registry=mutated, build=False)
    assert "RD001" in rules(found)


def test_rd_docstring_table_mutation_fails():
    source = DISPATCH.read_text()
    region, _, _ = extract_region(source)
    assert "sgd_rownorm" in region
    mutated = source.replace("sgd_rownorm         yes",
                             "sgd_rownorm         no ")
    assert not region_matches(mutated)
    found = registry_drift.run(dispatch_source=mutated, build=False)
    assert "RD001" in rules(found)


def test_rd_coverage_matrix_missing_op():
    rendered = render_lowering_table()
    from repro.kernels import dispatch
    ops = [op for op in dispatch.REGISTRY if op != "flash_attention"]
    doc = ('"""' + " ".join(f"``{op}``" for op in ops)
           + "\n\n.. lowering-table-begin\n" + rendered
           + "\n.. lowering-table-end\n" + '"""\n')
    found = registry_drift.run(dispatch_source=doc, build=False)
    assert rules(found) == {"RD002"}
    assert any("flash_attention" in f.message for f in found)


def test_rd_unreachable_fused_flag():
    def no_impl_factory(lr, kind="col"):
        from repro.core.optimizers import normalized_sgd
        return normalized_sgd(lr, kind=kind)

    mutated = dict(OPTIMIZER_REGISTRY)
    mutated["sgd_colnorm"] = dataclasses.replace(
        mutated["sgd_colnorm"], factory=no_impl_factory)
    found = registry_drift.run(registry=mutated, build=False)
    assert "RD004" in rules(found)


def test_lowering_table_in_sync_on_disk():
    assert region_matches(DISPATCH.read_text())


def test_pipeline_carries_plans():
    from repro.core import make_optimizer
    tx = make_optimizer("scale")
    assert tx.plans is not None and set(tx.plans) == {
        "first", "last", "matrix", "vector"}
    # the plans drive RD003: scale's matrix plan is a bare col norm
    assert tx.plans["matrix"].norm == "col"


# --------------------------------------------------------------------------
# findings / baseline mechanics
# --------------------------------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    f1 = Finding("KC003", "a.py", 10, "msg one")
    f2 = Finding("CX001", "b.py", 20, "msg two")
    path = tmp_path / "baseline.json"
    write_baseline(path, [f1])
    baseline = load_baseline(path)
    # line numbers do not participate in the key
    shifted = Finding("KC003", "a.py", 99, "msg one")
    new, suppressed = split_by_baseline([shifted, f2], baseline)
    assert new == [f2] and suppressed == [shifted]


def test_committed_baseline_is_empty():
    doc = json.loads(
        (REPO / "src" / "repro" / "analysis" / "baseline.json").read_text())
    assert doc["schema"] == "repro.analysis/baseline/v1"
    assert doc["suppressions"] == []


# --------------------------------------------------------------------------
# CLI contract
# --------------------------------------------------------------------------

def _run_cli(*args):
    import os
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=str(REPO), env=env)


def test_cli_exits_zero_on_committed_tree(tmp_path):
    out = tmp_path / "report.json"
    proc = _run_cli("--json", str(out))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro.analysis/v1"
    assert doc["counts"]["new"] == 0


def test_cli_exits_nonzero_on_seeded_fixture(tmp_path):
    for name, src, want in [("bad_kernel.py", BAD_KERNEL, "KC"),
                            ("bad_axes.py", BAD_AXES, "CX"),
                            ("bad_hygiene.py", BAD_HYGIENE, "JH")]:
        fix = tmp_path / name
        fix.write_text(src)
        proc = _run_cli("--paths", str(fix), "--json", "-")
        assert proc.returncode == 2, (name, proc.stdout, proc.stderr)
        assert want in proc.stdout, (name, proc.stdout)


def test_cli_clean_fixture_exits_zero(tmp_path):
    fix = tmp_path / "clean.py"
    fix.write_text(CLEAN_KERNEL)
    proc = _run_cli("--paths", str(fix))
    assert proc.returncode == 0, proc.stdout + proc.stderr
