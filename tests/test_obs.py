"""Telemetry plane (repro.obs): sinks + logger, the in-jit stats
collector's bitwise-inertness and paper-shaped output, timing/profiling
units, dispatch fallback deltas, and the driver's multi-host log hygiene
(SIGTERM flush, single-writer JSONL under forced 8 devices)."""
import json
import os
import signal
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_optimizer
from repro.core.labels import LAYER_GROUPS, layer_group
from repro.data import make_dataset
from repro.kernels import dispatch
from repro.obs import (SCHEMA, CSVSink, JSONLSink, MemorySink, MetricsLogger,
                       ProfileWindow, StatsPolicy, StepTimer, jsonable,
                       split_stats, stats_keys, validate_jsonl,
                       validate_record)
from repro.training import GuardPolicy, init_state, make_train_step
from tests.conftest import tiny_cfg

from repro.models import init_params


# --------------------------------------------------------------- labels

def test_layer_group_shared_helper():
    assert layer_group("lm_head/w") == "lm_head"
    assert layer_group("tok_embed/w") == "embedding"
    assert layer_group("segments/seg0/attn/wq") == "hidden"
    # tied models have no lm_head: the embedding IS the head
    assert layer_group("tok_embed/w", tied=True) == "lm_head"
    assert layer_group("segments/seg0/mlp/w1", tied=True) == "hidden"
    assert LAYER_GROUPS == ("embedding", "hidden", "lm_head")


def test_variance_analysis_uses_shared_helper():
    import benchmarks.variance_analysis as va
    assert not hasattr(va, "_group_of")
    assert va.layer_group is layer_group


# ------------------------------------------------------- record grammar

def test_validate_record_accepts_well_formed():
    validate_record({"schema": SCHEMA, "kind": "train_step", "host": 0,
                     "step": 3, "t": 1.5, "loss": 2.0, "tag": "x",
                     "fallbacks": {"attention": 2}, "dims": [1, 2]})


@pytest.mark.parametrize("bad", [
    {"schema": SCHEMA, "kind": "x", "host": 0, "step": 1},          # no t
    {"schema": "other/v9", "kind": "x", "host": 0, "step": 1, "t": 0.0},
    {"schema": SCHEMA, "kind": "", "host": 0, "step": 1, "t": 0.0},
    {"schema": SCHEMA, "kind": "x", "host": "0", "step": 1, "t": 0.0},
    {"schema": SCHEMA, "kind": "x", "host": 0, "step": 1, "t": 0.0,
     "loss": float("nan")},                                         # raw NaN
    {"schema": SCHEMA, "kind": "x", "host": 0, "step": 1, "t": 0.0,
     "deep": {"a": {"b": {"c": 1}}}},                               # too deep
])
def test_validate_record_rejects(bad):
    with pytest.raises(ValueError):
        validate_record(bad)


def test_jsonable_coerces_device_and_nonfinite():
    assert jsonable(jnp.float32(1.5)) == 1.5
    assert jsonable(np.int64(7)) == 7 and isinstance(jsonable(np.int64(7)),
                                                     int)
    assert jsonable(float("nan")) is None
    assert jsonable(float("inf")) is None
    assert jsonable(jnp.array([1.0, 2.0])) == [1.0, 2.0]
    assert jsonable({"a": np.float32("nan")}) == {"a": None}


# ------------------------------------------------------- sinks + logger

def test_jsonl_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with MetricsLogger([JSONLSink(path)], host=0, flush_every=2) as lg:
        lg.log("train_step", 1, loss=2.5,
               fields={"stats/lm_head/grad_norm": jnp.float32(3.0)})
        lg.log("event", 2, event="rollback", bad=float("nan"))
    assert validate_jsonl(path) == 2
    recs = [json.loads(x) for x in open(path)]
    assert recs[0]["loss"] == 2.5
    assert recs[0]["stats/lm_head/grad_norm"] == 3.0
    assert recs[1]["bad"] is None       # NaN -> null, line stays strict JSON
    assert all(r["schema"] == SCHEMA and r["host"] == 0 for r in recs)


def test_logger_rejects_shadowed_required_key():
    with MetricsLogger([MemorySink()]) as lg:
        with pytest.raises(ValueError, match="shadow"):
            lg.log("x", 0, fields={"step": 7})


def test_csv_sink_fixed_header(tmp_path):
    path = str(tmp_path / "m.csv")
    with MetricsLogger([CSVSink(path)], host=1) as lg:
        lg.log("train_step", 1, loss=1.0, extra="a,b")
        lg.log("train_step", 2, loss=2.0, novel=9)  # unknown col dropped
    lines = open(path).read().splitlines()
    header = lines[0].split(",")
    assert header[:5] == ["schema", "kind", "host", "step", "t"]
    assert "extra" in header and "novel" not in header
    assert '"a,b"' in lines[1]
    assert len(lines) == 3


def test_memory_sink_background_flush_cadence():
    sink = MemorySink()
    lg = MetricsLogger([sink], flush_every=3)
    for i in range(7):
        lg.log("x", i)
    assert lg.flush()                    # synchronous barrier
    assert [r["step"] for r in sink.records] == list(range(7))
    assert sink.flushes >= 2             # two cadence flushes + barrier
    lg.close()
    lg.log("late", 99)                   # post-close logs are dropped
    assert len(sink.records) == 7


def test_console_host_gating(capsys):
    with MetricsLogger([], host=1) as lg:
        lg.console("hello", step=3)
    assert capsys.readouterr().out == ""
    with MetricsLogger([], host=0) as lg:
        lg.console("hello", step=3)
        lg.console("step    10 loss 1.0", raw=True)
    out = capsys.readouterr().out.splitlines()
    assert out[0] == "[h0 s3] hello"
    # raw lines keep their greppable start and still carry the host tag
    assert out[1].startswith("step ") and out[1].endswith("host 0")


# ------------------------------------------------------ fallback deltas

def test_fallback_snapshot_delta_no_reset():
    dispatch.reset_fallbacks()
    before = dispatch.fallback_snapshot()
    dispatch._FALLBACK_COUNTS["attention"] = 3
    mid = dispatch.fallback_snapshot()
    assert dispatch.fallback_delta(before, mid) == {"attention": 3}
    dispatch._FALLBACK_COUNTS["attention"] = 5
    dispatch._FALLBACK_COUNTS["xent"] = 1
    assert dispatch.fallback_delta(mid) == {"attention": 2, "xent": 1}
    # delta never mutates the cumulative counters chaos tests assert on
    assert dispatch.fallback_counts()["attention"] == 5
    dispatch.reset_fallbacks()


# ------------------------------------------------------- timing/profile

def test_step_timer_snapshot_resets():
    t = StepTimer()
    with t.section("data"):
        time.sleep(0.01)
    with t.section("data"):
        pass
    snap = t.snapshot()
    assert snap["time/data_n"] == 2 and snap["time/data_s"] >= 0.01
    assert snap["time/wall_s"] >= snap["time/data_s"]
    snap2 = t.snapshot()
    assert "time/data_s" not in snap2    # deltas: accumulators reset


@pytest.mark.parametrize("spec,want", [
    ("", None), ("5", (5, 5)), ("2:9", (2, 9))])
def test_profile_window_parse(spec, want, tmp_path):
    win = ProfileWindow.parse(spec, str(tmp_path))
    if want is None:
        assert win is None
    else:
        assert (win.start, win.stop) == want


@pytest.mark.parametrize("spec", ["a:b", "1:2:3", "9:2", "-1"])
def test_profile_window_parse_rejects(spec, tmp_path):
    with pytest.raises(ValueError):
        ProfileWindow.parse(spec, str(tmp_path))


# ------------------------------------------------- the stats collector

def _run(steps, stats, guard=None, seed=0, pack=False, tied=False, **cfg_kw):
    if tied:
        from repro.core.labels import LabelRules
        cfg = tiny_cfg(tie_embeddings=True, **cfg_kw)
        tx = make_optimizer("scale", 1e-2, rules=LabelRules.tied())
    else:
        cfg = tiny_cfg(**cfg_kw)
        tx = make_optimizer("scale", 1e-2)
    state = init_state(init_params(jax.random.PRNGKey(seed), cfg),
                       tx, guard=guard is not None)
    fn = jax.jit(make_train_step(cfg, tx, clip_norm=1.0, guard=guard,
                                 stats=stats))
    ds = make_dataset(cfg, seq_len=32, global_batch=4, seed=seed,
                      pack_documents=pack)
    metrics = {}
    for i in range(steps):
        state, metrics = fn(state, ds.host_batch_at(i))
    return state, metrics


def test_stats_bitwise_inert_with_guard():
    """The acceptance invariant: a run with the collector woven in ends in
    *bitwise* the params/opt_state of a run without it."""
    base, _ = _run(4, stats=None, guard=GuardPolicy())
    obs, metrics = _run(4, stats=StatsPolicy(every_k=2), guard=GuardPolicy())
    for a, b in zip(jax.tree_util.tree_leaves((base.params, base.opt_state)),
                    jax.tree_util.tree_leaves((obs.params, obs.opt_state))):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(metrics["stats/valid"]) == 1.0   # step 4 is on cadence


def test_stats_cadence_and_split():
    policy = StatsPolicy(every_k=3)
    _, m_on = _run(3, stats=policy)        # completed step 3: on cadence
    _, m_off = _run(4, stats=policy)       # completed step 4: off cadence
    assert float(m_on["stats/valid"]) == 1.0
    assert float(m_off["stats/valid"]) == 0.0
    for k in m_off:
        if k.startswith("stats/"):
            assert float(m_off[k]) == 0.0, k   # dead branch: zeros exactly
    plain, stat_vals = split_stats(m_on, policy)
    assert stat_vals and not any(k.startswith("stats/") for k in plain)
    assert "loss" in plain
    plain_off, stats_off = split_stats(m_off, policy)
    assert stats_off == {}                 # off-cadence records stay small
    assert split_stats(m_on, None) == (dict(m_on), {})


def test_stats_keys_cover_groups():
    keys = stats_keys(StatsPolicy())
    for grp in LAYER_GROUPS:
        for name in ("grad_norm", "colnorm_disp", "update_ratio",
                     "momentum_norm"):
            assert f"stats/{grp}/{name}" in keys
    lean = stats_keys(StatsPolicy(momentum=False, colnorms=False,
                                  ratios=False))
    assert lean == sorted(["stats/valid"] + [f"stats/{g}/grad_norm"
                                             for g in LAYER_GROUPS])


def test_stats_paper_ordering_and_momentum_placement():
    """Fig. 4/10 live: lm-head gradient column-norm dispersion dominates
    the hidden stack, and (SCALE) only the head carries first-moment
    state. Needs a non-toy vocab: token-frequency imbalance is what the
    head's column norms trace, and a 256-token vocab has too little of
    it."""
    _, m = _run(4, stats=StatsPolicy(every_k=4), vocab_size=1024)
    disp = {g: float(m[f"stats/{g}/colnorm_disp"]) for g in LAYER_GROUPS}
    assert disp["lm_head"] > disp["hidden"] > 0
    assert float(m["stats/lm_head/grad_norm"]) > 0
    # SCALE: the head carries momentum; the embedding is stateless (its mu
    # leaf is a zero-size placeholder the collector skips). Hidden is not
    # asserted zero — the norm gains there carry the non-matrix Adam state.
    assert float(m["stats/lm_head/momentum_norm"]) > 0
    assert float(m["stats/embedding/momentum_norm"]) == 0.0


def test_stats_under_packed_training():
    """Packed multi-document batches thread extra leaves through the step;
    the collector must coexist with them (and with the guard)."""
    _, m = _run(2, stats=StatsPolicy(every_k=2), guard=GuardPolicy(),
                pack=True)
    assert float(m["stats/valid"]) == 1.0
    assert np.isfinite(float(m["stats/lm_head/grad_norm"]))
    assert float(m["stats/lm_head/update_ratio"]) >= 0


def test_stats_tied_head_reports_under_lm_head():
    _, m = _run(2, stats=StatsPolicy(every_k=2, tied=True), tied=True)
    assert float(m["stats/valid"]) == 1.0
    # the tied (V, D) embedding is the head: stats land in lm_head and the
    # embedding group is empty
    assert float(m["stats/lm_head/grad_norm"]) > 0
    assert float(m["stats/embedding/grad_norm"]) == 0.0


def test_stats_every_k_validation():
    from repro.obs import make_stats_fn
    with pytest.raises(ValueError, match="every_k"):
        make_stats_fn(StatsPolicy(every_k=0))


# ------------------------------------------------ driver integration

def _cli_env():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    env.pop("REPRO_FAULTS", None)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    return env


def test_cli_writes_schema_valid_jsonl_with_stats(tmp_path, capsys):
    """In-process tiny run: the JSONL validates, stats records appear on
    cadence, and the head's dispersion dominates (the acceptance check)."""
    from repro.launch.train import main
    main(["--arch", "qwen2-7b", "--smoke", "--steps", "4", "--batch", "4",
          "--seq", "32", "--log-every", "2", "--log-dir", str(tmp_path),
          "--metrics-every", "2", "--stats-every", "2"])
    capsys.readouterr()
    path = tmp_path / "metrics.0.jsonl"
    assert validate_jsonl(str(path)) >= 4
    recs = [json.loads(x) for x in open(path)]
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "run_header" and kinds[-1] == "run_end"
    steps = [r for r in recs if r["kind"] == "train_step"]
    assert [r["step"] for r in steps] == sorted({r["step"] for r in steps})
    on_cadence = [r for r in steps if "stats/lm_head/colnorm_disp" in r]
    assert on_cadence, steps
    for r in on_cadence:
        assert r["stats/lm_head/colnorm_disp"] > \
            r["stats/hidden/colnorm_disp"]
    assert all("time/step_s" in r and "tokens_per_s" in r for r in steps)
    assert recs[-1]["reason"] == "done"


def test_cli_sigterm_flushes_metrics_tail(tmp_path):
    """SIGTERM mid-run: the logger's flush-on-exit gets the run_end record
    (reason=sigterm) onto disk before the process dies."""
    logdir = tmp_path / "logs"
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen2-7b",
         "--smoke", "--steps", "100000", "--batch", "2", "--seq", "32",
         "--log-every", "1", "--metrics-every", "1", "--log-dir",
         str(logdir), "--ckpt-dir", str(tmp_path / "ckpt"),
         "--ckpt-every", "100000"],
        env=_cli_env(), stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)
    lines = []
    try:
        for line in proc.stdout:
            lines.append(line)
            if line.startswith("step "):
                break
        else:
            pytest.fail("driver exited before its first step:\n"
                        + "".join(lines))
        proc.send_signal(signal.SIGTERM)
        lines.extend(proc.stdout)
        assert proc.wait(timeout=300) == 0, "".join(lines)
    finally:
        proc.kill()
    path = logdir / "metrics.0.jsonl"
    assert validate_jsonl(str(path)) >= 2
    recs = [json.loads(x) for x in open(path)]
    assert recs[-1]["kind"] == "run_end"
    assert recs[-1]["reason"] == "sigterm"
    assert any(r["kind"] == "train_step" for r in recs)


def test_single_writer_jsonl_under_forced_8_devices(tmp_path):
    """8-way sharded run, single process: exactly one metrics file
    (metrics.0.jsonl), every record host 0, schema-valid."""
    script = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import glob, json
from repro.launch.train import main
from repro.obs import validate_jsonl
logdir = sys.argv[1]
main(["--arch", "qwen2-7b", "--smoke", "--steps", "3", "--batch", "8",
      "--seq", "32", "--log-every", "1", "--log-dir", logdir,
      "--metrics-every", "1", "--stats-every", "3"])
files = sorted(glob.glob(os.path.join(logdir, "metrics.*.jsonl")))
assert files == [os.path.join(logdir, "metrics.0.jsonl")], files
n = validate_jsonl(files[0])
assert n >= 5, n
recs = [json.loads(x) for x in open(files[0])]
assert all(r["host"] == 0 for r in recs), recs
assert any("stats/lm_head/grad_norm" in r for r in recs)
print("OK")
"""
    res = subprocess.run([sys.executable, "-c", script, str(tmp_path)],
                         env=_cli_env(), capture_output=True, text=True,
                         timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


def test_serving_latency_records():
    from repro.training.serving import greedy_generate
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    sink = MemorySink()
    with MetricsLogger([sink]) as lg:
        prompt = jnp.zeros((2, 16), jnp.int32)
        out = greedy_generate(cfg, params, prompt, n_steps=4, max_seq=64,
                              logger=lg)
    assert out.shape == (2, 4)
    phases = {r["phase"]: r for r in sink.records if r["kind"] == "serve"}
    assert set(phases) == {"prefill", "decode"}
    assert phases["prefill"]["prompt_tokens"] == 32
    assert phases["prefill"]["latency_ms"] > 0
    d = phases["decode"]
    assert d["decode_steps"] == 3 and d["p99_ms"] >= d["p50_ms"] >= 0
    for r in sink.records:
        validate_record(r)
