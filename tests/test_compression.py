"""Gradient compression: the column-scale-cancellation property that makes
int8 compression ~free for SCALE but biased for Adam."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import colnorm, make_optimizer
from repro.core.compression import (compress, compressed, compression_ratio,
                                    decompress)


def test_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    rt = decompress(compress({"g": g}), {"g": g})["g"]
    # per-column relative error bounded by the int8 grid (1/254 of col max)
    colmax = np.max(np.abs(np.asarray(g)), axis=0)
    err = np.max(np.abs(np.asarray(rt - g)), axis=0)
    assert np.all(err <= colmax / 254 + 1e-7)


@given(m=st.integers(4, 24), n=st.integers(2, 16), seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_colnorm_invariant_to_column_rescaling(m, n, seed):
    """The algebraic root of the synergy: colnorm(g * s_col) == colnorm(g)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (m, n)) + 0.1
    s = jnp.exp(jax.random.normal(jax.random.fold_in(
        jax.random.PRNGKey(seed), 1), (1, n)))
    a = np.asarray(colnorm(g))
    b = np.asarray(colnorm(g * s))
    np.testing.assert_allclose(a, b, atol=2e-3)


def test_scale_update_nearly_unchanged_by_compression():
    """SCALE direction is invariant to the quantization *scale*; only the
    8-bit in-column rounding remains -> tiny update perturbation."""
    params = {"layers": {"w": jnp.zeros((128, 64))},
              "lm_head": {"w": jnp.zeros((64, 128))}}
    g = {"layers": {"w": jax.random.normal(jax.random.PRNGKey(1), (128, 64))},
         "lm_head": {"w": jax.random.normal(jax.random.PRNGKey(2), (64, 128))}}
    tx = make_optimizer("scale", 1e-2)
    ctx = compressed(make_optimizer("scale", 1e-2))
    u1, _ = tx.update(g, tx.init(params), params)
    u2, _ = ctx.update(g, ctx.init(params), params)
    for a, b in zip(jax.tree_util.tree_leaves(u1),
                    jax.tree_util.tree_leaves(u2)):
        rel = float(jnp.linalg.norm(a - b) / jnp.linalg.norm(a))
        assert rel < 0.01, rel  # <1% direction perturbation


def test_compression_ratio():
    g = {"w": jnp.zeros((256, 256), jnp.bfloat16)}
    r = compression_ratio(g)
    assert 1.9 < r < 2.0  # bf16 -> int8 + scales

    g32 = {"w": jnp.zeros((256, 256), jnp.float32)}
    assert 3.8 < compression_ratio(g32) < 4.0


def test_compressed_training_converges(tiny=None):
    from conftest import tiny_cfg
    from repro.data import make_dataset
    from repro.models import init_params
    from repro.training import init_state, make_train_step
    cfg = tiny_cfg()
    tx = compressed(make_optimizer("scale", 1e-2))
    state = init_state(init_params(jax.random.PRNGKey(0), cfg), tx)
    step = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
    ds = make_dataset(cfg, seq_len=32, global_batch=8)
    losses = []
    for i in range(20):
        state, m = step(state, ds.host_batch_at(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
