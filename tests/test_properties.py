"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import apply_updates, make_optimizer, global_norm
from repro.core.memory import optimizer_state_elements

SMALL = st.integers(2, 12)


@given(m=SMALL, n=SMALL, seed=st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_scale_update_column_norm_equals_lr(m, n, seed):
    """Per column, the SCALE matrix update has magnitude exactly lr."""
    lr = 0.01
    tx = make_optimizer("scale", lr)
    params = {"layers": {"w": jnp.zeros((m, n))}}
    g = {"layers": {"w": jax.random.normal(jax.random.PRNGKey(seed), (m, n))
                    + 0.1}}
    upd, _ = tx.update(g, tx.init(params), params)
    norms = np.linalg.norm(np.asarray(upd["layers"]["w"]), axis=0)
    np.testing.assert_allclose(norms, lr, rtol=1e-3)


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_apply_updates_is_addition(seed):
    k = jax.random.PRNGKey(seed)
    p = {"w": jax.random.normal(k, (4, 4))}
    u = {"w": jax.random.normal(jax.random.fold_in(k, 1), (4, 4))}
    out = apply_updates(p, u)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(p["w"] + u["w"]), atol=1e-6)


@given(d=st.sampled_from([64, 128]), L=st.integers(1, 6),
       v=st.sampled_from([256, 512]))
@settings(max_examples=15, deadline=None)
def test_memory_invariants(d, L, v):
    shapes = {"tok_embed": {"w": (v, d)}, "lm_head": {"w": (d, v)}}
    for i in range(L):
        shapes[f"l{i}"] = {"w": (d, 4 * d), "o": (4 * d, d)}
    sgd = optimizer_state_elements(shapes, "sgd")
    scale = optimizer_state_elements(shapes, "scale")
    muon = optimizer_state_elements(shapes, "muon")
    adam = optimizer_state_elements(shapes, "adam")
    assert sgd == 0
    assert sgd <= scale <= muon <= adam
    assert scale == d * v  # exactly one lm_head momentum buffer
    assert adam == 2 * sum(int(np.prod(s)) for s in
                           [x for grp in shapes.values() for x in grp.values()])


@given(seed=st.integers(0, 2**16), steps=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_momentum_ema_bounded(seed, steps):
    """|m_t| <= max_i |g_i| under EMA with beta in (0,1)."""
    tx = make_optimizer("scale", 1e-3, beta=0.9)
    params = {"lm_head": {"w": jnp.zeros((4, 8))}}
    state = tx.init(params)
    gmax = 0.0
    for i in range(steps):
        g = {"lm_head": {"w": jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(seed), i), (4, 8))}}
        gmax = max(gmax, float(jnp.max(jnp.abs(g["lm_head"]["w"]))))
        _, state = tx.update(g, state, params)
    assert float(jnp.max(jnp.abs(state.mu["lm_head"]["w"]))) <= gmax + 1e-6


@given(b=st.integers(1, 3), s=st.sampled_from([16, 32]),
       seed=st.integers(0, 2**10))
@settings(max_examples=8, deadline=None)
def test_loss_chunking_invariant(b, s, seed):
    """Chunked LM loss == unchunked softmax cross-entropy."""
    from conftest import tiny_cfg
    from repro.models import init_params, forward, lm_loss
    import dataclasses
    cfg = tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab_size)
    h, _, _ = forward(params, cfg, toks)
    loss_c, _ = lm_loss(params, cfg, h, toks)
    cfg2 = dataclasses.replace(cfg, loss_chunk=s)  # single chunk
    loss_u, _ = lm_loss(params, cfg2, h, toks)
    np.testing.assert_allclose(float(loss_c), float(loss_u), rtol=1e-5)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
