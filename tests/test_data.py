"""Data pipeline: determinism, shard-awareness, marginals, learnability."""
import jax.numpy as jnp
import numpy as np

from conftest import tiny_cfg
from repro.data import DataConfig, SyntheticLM, make_dataset


def test_deterministic_and_resumable():
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=16, global_batch=4))
    a = ds.global_batch_at(3)
    b = ds.global_batch_at(3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = ds.global_batch_at(4)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_host_sharding_partitions_global_batch():
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=16, global_batch=8))
    full = ds.global_batch_at(0)["tokens"]
    parts = [ds.host_batch_at(0, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(parts, 0)),
                                  np.asarray(full))


def test_labels_are_shifted_tokens():
    ds = SyntheticLM(DataConfig(vocab_size=128, seq_len=16, global_batch=2))
    b = ds.global_batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert int(b["labels"][0, -1]) == -1


def test_zipf_marginal_skew():
    """Frequent-token skew (drives the paper's Fig. 10 column-norm effect)."""
    ds = SyntheticLM(DataConfig(vocab_size=512, seq_len=256, global_batch=16,
                                bigram_prob=0.0))
    toks = np.asarray(ds.global_batch_at(0)["tokens"]).ravel()
    counts = np.bincount(toks, minlength=512)
    top16 = counts[np.argsort(counts)[-16:]].sum()
    assert top16 / counts.sum() > 0.3  # heavy head


def test_bigram_structure_is_learnable_signal():
    cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=8,
                     bigram_prob=1.0)
    ds = SyntheticLM(cfg)
    toks = np.asarray(ds.global_batch_at(0)["tokens"])
    # fully deterministic chain: next == (a*prev+b) % V
    a, b = ds._a, ds._b
    nxt = (a * toks[:, :-1] + b) % cfg.vocab_size
    np.testing.assert_array_equal(nxt, toks[:, 1:])


def test_audio_and_vlm_batch_shapes():
    audio = tiny_cfg("audio", family="audio", n_codebooks=4, vocab_size=64)
    ds = make_dataset(audio, seq_len=8, global_batch=2)
    b = ds.global_batch_at(0)
    assert b["tokens"].shape == (2, 4, 8)
    vlm = tiny_cfg("vlm", family="vlm", cross_attn_every=2, n_layers=4,
                   n_image_tokens=8)
    ds = make_dataset(vlm, seq_len=8, global_batch=2)
    b = ds.global_batch_at(0)
    assert b["image_embeds"].shape == (2, 8, vlm.d_model)
