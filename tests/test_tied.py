"""Tied-embedding LM heads (``tie_embeddings=True``), end to end.

Layers mirror the untied suites:

  * model layer — the param tree has no ``lm_head``; forward/serving/loss
    read ``tok_embed.w`` transposed and the fused xent dispatches the
    transposed-w kernels (no reference fallback on covered shapes);
  * kernel layer — fused loss/dH/dW parity vs the full-logit oracle over
    ``w.T`` across dtypes / padded vocab / ragged shapes, dW emitted in
    the (V, D) storage layout, plus the forced-8-device (4, 2) mesh matrix
    (run in the ``tier1-multidevice`` CI job);
  * optimizer layer — the tied matrix routes to the ``last`` momentum
    group under ``LabelRules.tied()`` (hard error under the untied default
    rules), its col norm flips to a row norm of the (V, D) storage, the
    state is an eval_shape fixed point, and memory accounting counts tied
    params once.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import repro_fused, tiny_cfg
from repro.core import LabelRules, make_optimizer
from repro.core.labels import label_tree, transposed_tree
from repro.core.memory import memory_report
from repro.core.normalization import rownorm
from repro.kernels import dispatch
from repro.kernels.xent import ref as xref
from repro.models import (head_weight, init_params, lm_loss,
                          logits_from_hidden, model_spec,
                          param_logical_axes, param_shapes)
from repro.models.model import _mask_pad_vocab, loss_fn

DTYPES = [jnp.float32, jnp.bfloat16]


def tied_cfg(**kw):
    kw.setdefault("vocab_size", 250)  # padded_vocab 256: padding exercised
    return tiny_cfg(tie_embeddings=True, **kw)


# ---- model layer ----------------------------------------------------------

def test_tied_tree_has_no_lm_head():
    cfg = tied_cfg()
    assert "lm_head" not in model_spec(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert "lm_head" not in params
    w, tied = head_weight(params, cfg)
    assert tied and w.shape == (cfg.padded_vocab, cfg.d_model)
    assert param_logical_axes(cfg)["tok_embed"]["w"] == ("vocab", "embed")
    # tied params are counted once: exactly one (V, D) head/embedding
    untied = tiny_cfg(vocab_size=250)
    n_tied = sum(int(np.prod(s)) for s in jax.tree_util.tree_leaves(
        param_shapes(cfg), is_leaf=lambda x: isinstance(x, tuple)))
    n_untied = sum(int(np.prod(s)) for s in jax.tree_util.tree_leaves(
        param_shapes(untied), is_leaf=lambda x: isinstance(x, tuple)))
    assert n_untied - n_tied == cfg.padded_vocab * cfg.d_model


def test_tied_serving_logits_match_transposed_matmul():
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(1), cfg)
    h = jax.random.normal(jax.random.PRNGKey(2), (2, 8, cfg.d_model))
    got = logits_from_hidden(params, cfg, h)
    want = _mask_pad_vocab(h @ params["tok_embed"]["w"].T, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_tied_audio_heads_match_reference():
    cfg = tied_cfg(family="audio", n_codebooks=2, vocab_size=200)
    params = init_params(jax.random.PRNGKey(3), cfg)
    B, S = 2, 16
    h = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model),
                          jnp.float32).astype(cfg.jdtype)
    labels = jax.random.randint(jax.random.PRNGKey(5), (B, 2, S), -1, 200)
    loss, weight = lm_loss(params, cfg, h, labels)
    ew = params["tok_embed"]["w"]  # (C, V, D)
    tot = sum(float(jnp.sum(xref.losses(h, ew[c].T, labels[:, c], 200)))
              for c in range(2))
    ref_w = float(jnp.sum((labels >= 0).astype(jnp.float32)))
    np.testing.assert_allclose(float(loss), tot / max(ref_w, 1.0), rtol=2e-3)
    assert float(weight) == ref_w
    # serving logits: per-codebook h @ w[c].T
    got = logits_from_hidden(params, cfg, h)
    want = _mask_pad_vocab(jnp.einsum("bsd,cvd->bcsv", h, ew), cfg)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=1e-2)


# ---- kernel / dispatch layer ----------------------------------------------

def test_transposed_route_covered_not_fallback():
    """Covered tied shapes must take the kernel route (acceptance bar: no
    reference fallback), and the D-mismatch check follows the layout."""
    assert dispatch.xent_supported((4, 8, 16), (128, 16), transposed=True)
    assert not dispatch.xent_supported((4, 8, 16), (16, 128), transposed=True)
    assert dispatch.xent_route((4, 8, 16), (128, 16),
                               transposed=True)[0] == "kernel"
    cfg = tied_cfg()
    h_shape = (2, 32, cfg.d_model)
    w, _ = head_weight(init_params(jax.random.PRNGKey(0), cfg), cfg)
    assert dispatch.xent_route(h_shape, tuple(w.shape),
                               transposed=True)[0] == "kernel"


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(2, 32, 64, 512, 500),
                                   (1, 70, 33, 257, 200),
                                   (2, 16, 128, 384, 384)],
                         ids=["padded", "ragged", "exact"])
def test_transposed_xent_loss_and_grads_match_reference(shape, dtype):
    """Same parity matrix as the untied kernels, with w in (V, D); dW must
    come back in (V, D) so it lands directly on the embedding."""
    B, S, D, V, VS = shape
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32).astype(dtype)
    wt = jax.random.normal(ks[1], (V, D), jnp.float32).astype(dtype)
    labels = jax.random.randint(ks[2], (B, S), -1, VS)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4

    def f_fused(h, wt):
        return jnp.sum(dispatch.xent_loss(h, wt, labels, vocab_size=VS,
                                          transposed=True))

    def f_ref(h, wt):
        return jnp.sum(xref.losses(h, wt.swapaxes(-1, -2), labels, VS))

    v1, (dh1, dw1) = jax.value_and_grad(f_fused, argnums=(0, 1))(h, wt)
    v2, (dh2, dw2) = jax.value_and_grad(f_ref, argnums=(0, 1))(h, wt)
    np.testing.assert_allclose(float(v1), float(v2),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)
    assert dw1.shape == wt.shape and dw1.dtype == wt.dtype
    np.testing.assert_allclose(np.asarray(dh1, np.float32),
                               np.asarray(dh2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(dw1, np.float32),
                               np.asarray(dw2, np.float32), atol=tol)


def test_tied_lm_loss_fused_equals_scan_reference():
    """End-to-end tied lm_loss: fused (default) == REPRO_FUSED=off chunked
    scan over tok_embed.w.T, values and gradients — the same tolerances as
    the untied parity test."""
    for cfg in (tied_cfg(),
                tied_cfg(family="audio", n_codebooks=2, vocab_size=200)):
        params = init_params(jax.random.PRNGKey(9), cfg)
        B, S = 2, 32
        h = jax.random.normal(jax.random.PRNGKey(10), (B, S, cfg.d_model),
                              jnp.float32).astype(cfg.jdtype)
        lab_shape = (B, cfg.n_codebooks, S) if cfg.family == "audio" \
            else (B, S)
        labels = jax.random.randint(jax.random.PRNGKey(11), lab_shape, -1,
                                    cfg.vocab_size)

        def head_loss(p, force_off):
            if force_off:
                with repro_fused("off"):
                    return lm_loss(p, cfg, h, labels)[0]
            return lm_loss(p, cfg, h, labels)[0]

        head = {"tok_embed": params["tok_embed"]}
        l_f, g_f = jax.value_and_grad(head_loss)(head, False)
        l_r, g_r = jax.value_and_grad(head_loss)(head, True)
        np.testing.assert_allclose(float(l_f), float(l_r), rtol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(g_f),
                        jax.tree_util.tree_leaves(g_r)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=2e-4)


# ---- optimizer layer ------------------------------------------------------

def test_tied_rules_route_embedding_to_last_with_momentum():
    """The routing satellite: under LabelRules.tied() the tied embedding
    carries momentum state; under the untied default it does not."""
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rules = LabelRules.tied()
    labels = label_tree(params, rules)
    assert labels["tok_embed"]["w"] == "last"
    assert transposed_tree(params, rules)["tok_embed"]["w"] is True
    tx = make_optimizer("scale", 1e-3, rules=rules)
    state = tx.init(params)
    assert state.mu["tok_embed"]["w"].shape == params["tok_embed"]["w"].shape
    # untied model, untied rules: the embedding is 'first', no momentum
    ucfg = tiny_cfg(vocab_size=250)
    uparams = init_params(jax.random.PRNGKey(0), ucfg)
    ustate = make_optimizer("scale", 1e-3).init(uparams)
    assert ustate.mu["tok_embed"]["w"].size == 0


def test_tied_tree_under_untied_rules_is_hard_error():
    """An unmatched logit-producing matrix must not silently land outside
    the 'last' group: scale on a tied tree with the default rules raises."""
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tx = make_optimizer("scale", 1e-3)
    with pytest.raises(ValueError, match="LabelRules.tied"):
        tx.init(params)
    # and the same guard holds in the update path (state built elsewhere)
    rules_state = make_optimizer("scale", 1e-3,
                                 rules=LabelRules.tied()).init(params)
    with pytest.raises(ValueError, match="LabelRules.tied"):
        tx.update(params, rules_state, params)


def test_tied_head_update_is_row_normalized_momentum():
    """Output-dim normalization is preserved: the (V, D) tied head's update
    is -lr * rownorm(EMA) — the row norm of the storage layout IS the col
    norm of the (D, V) use layout."""
    lr, beta = 1e-2, 0.9
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree_util.tree_map(
        lambda p: (0.1 * jnp.ones_like(p) + 0.01 * p).astype(jnp.float32),
        params)
    tx = make_optimizer("scale", lr, beta=beta, rules=LabelRules.tied())
    state = tx.init(params)
    upd, state = tx.update(grads, state, params)
    m1 = (1 - beta) * grads["tok_embed"]["w"]
    np.testing.assert_allclose(np.asarray(upd["tok_embed"]["w"]),
                               np.asarray(-lr * rownorm(m1)), atol=1e-6)
    upd2, _ = tx.update(grads, state, params)
    m2 = beta * m1 + (1 - beta) * grads["tok_embed"]["w"]
    np.testing.assert_allclose(np.asarray(upd2["tok_embed"]["w"]),
                               np.asarray(-lr * rownorm(m2)), atol=1e-6)


@pytest.mark.parametrize("impl", ["jnp", "fused"])
def test_tied_state_is_eval_shape_fixed_point(impl):
    """The eval_shape fixed point holds for the tied tree through both
    entry points (lax.scan loops / donated buffers depend on it)."""
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    tx = make_optimizer("scale", 1e-3, impl=impl, rules=LabelRules.tied())
    s0 = jax.eval_shape(tx.init, params)
    for step in (lambda g, s, p: tx.update(g, s, p)[1],
                 lambda g, s, p: tx.update_params(g, s, p)[1]):
        s1 = jax.eval_shape(step, grads, s0, params)
        assert (jax.tree_util.tree_structure(s0)
                == jax.tree_util.tree_structure(s1))
        for a, b in zip(jax.tree_util.tree_leaves(s0),
                        jax.tree_util.tree_leaves(s1)):
            assert a.shape == b.shape and a.dtype == b.dtype, (impl, a, b)


def test_tied_fused_scale_matches_jnp_reference():
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    grads = jax.tree_util.tree_map(
        lambda p: (0.1 * jnp.ones_like(p) + 0.02 * p).astype(p.dtype), params)
    txs = [make_optimizer("scale", 1e-2, impl=i, rules=LabelRules.tied())
           for i in ("jnp", "fused")]
    states = [tx.init(params) for tx in txs]
    ps = [params, params]
    for _ in range(3):
        for i, tx in enumerate(txs):
            ps[i], states[i] = tx.update_params(grads, states[i], ps[i])
    for a, b in zip(jax.tree_util.tree_leaves((ps[0], states[0])),
                    jax.tree_util.tree_leaves((ps[1], states[1]))):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_tied_memory_accounted_once():
    """Tied shapes + tied rules: weights shrink by one head matrix and the
    SCALE momentum follows the tie onto the embedding."""
    cfg, ucfg = tied_cfg(), tiny_cfg(vocab_size=250)
    tied_r = memory_report(param_shapes(cfg), "scale",
                           rules=LabelRules.tied())
    untied_r = memory_report(param_shapes(ucfg), "scale")
    head_bytes = cfg.padded_vocab * cfg.d_model * 2
    assert untied_r.weight_bytes - tied_r.weight_bytes == head_bytes
    # momentum moved onto the tied matrix, not dropped
    assert tied_r.state_bytes == untied_r.state_bytes
    # without tied rules the head momentum silently disappears — the
    # accounting mirrors the optimizer's (hard-error-guarded) behavior
    assert memory_report(param_shapes(cfg), "scale").state_bytes \
        < tied_r.state_bytes


# ---- trainer end-to-end ---------------------------------------------------

def test_tied_train_step_fused_paths_active():
    """Acceptance: tie_embeddings=True trains through make_train_step with
    the fused xent + fused SCALE paths on covered shapes, produces no
    lm_head, and matches the REPRO_FUSED=off reference loss."""
    from repro.data import make_dataset
    from repro.training import init_state, make_train_step
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    assert "lm_head" not in params
    # the shapes this step will dispatch are kernel-covered (no fallback)
    w, tied = head_weight(params, cfg)
    assert tied
    assert dispatch.xent_route((4, 32, cfg.d_model), tuple(w.shape),
                               transposed=True)[0] == "kernel"
    assert dispatch.supported(tuple(w.shape), "row")
    ds = make_dataset(cfg, seq_len=32, global_batch=4)
    batch = ds.host_batch_at(0)
    tx = make_optimizer("scale", 3e-3, impl="fused", rules=LabelRules.tied())
    step = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
    state = init_state(params, tx)
    losses = []
    for i in range(8):
        state, metrics = step(state, ds.host_batch_at(i))
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)) and losses[-1] < losses[0]
    assert state.opt_state.mu["tok_embed"]["w"].size > 0
    with repro_fused("off"):
        step_off = jax.jit(make_train_step(cfg, tx, clip_norm=1.0))
        _, m_off = step_off(init_state(params, tx), batch)
    _, m_on = step(init_state(params, tx), batch)
    np.testing.assert_allclose(float(m_on["loss"]), float(m_off["loss"]),
                               rtol=1e-5)


def test_tied_loss_fn_mesh_kwarg_single_device():
    """1-device mesh must equal no mesh for the tied loss (replicated plan
    -> single-device kernel path), mirroring the untied test."""
    from repro.data import make_dataset
    cfg = tied_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=2)
    batch = ds.host_batch_at(0)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    (l1, _) = loss_fn(params, cfg, batch)
    (l2, _) = loss_fn(params, cfg, batch, mesh=mesh)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


# ---- sharded matrix on a forced 8-device host mesh ------------------------

def test_sharded_tied_xent_parity_under_forced_8_devices():
    """(4, 2) mesh: batch over "data"; tied w (V, D) with vocab TP over
    "model" (dim 0) and FSDP embed over "data" (dim 1, gathered at entry).
    loss/dH/dW must match the unsharded reference for f32 and bf16, dW in
    the (V, D) storage layout."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels import dispatch
from repro.kernels.xent import ref as xref

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))
B, S, D, V, VS = 8, 16, 32, 256, 200
ks = jax.random.split(jax.random.PRNGKey(0), 3)
for dtype in (jnp.float32, jnp.bfloat16):
    h = jax.random.normal(ks[0], (B, S, D), jnp.float32).astype(dtype)
    wt = jax.random.normal(ks[1], (V, D), jnp.float32).astype(dtype)
    lab = jax.random.randint(ks[2], (B, S), -1, VS)
    h_sh = NamedSharding(mesh, P("data", None, None))
    # (V, D) storage: vocab TP on dim 0, FSDP embed on dim 1 (gathered)
    w_sh = NamedSharding(mesh, P("model", "data"))
    route, plan = dispatch.xent_route(h.shape, wt.shape, None, h_sh, w_sh,
                                      transposed=True)
    assert route == "kernel" and plan.tok_axes == ("data",) \
        and plan.voc_axes == ("model",), (route, plan)
    h_s, w_s = jax.device_put(h, h_sh), jax.device_put(wt, w_sh)

    def f_fused(h, wt):
        return jnp.sum(dispatch.xent_loss(
            h, wt, lab, vocab_size=VS, h_sharding=h_sh, w_sharding=w_sh,
            transposed=True))

    def f_ref(h, wt):
        return jnp.sum(xref.losses(h, wt.swapaxes(-1, -2), lab, VS))

    v1, (dh1, dw1) = jax.value_and_grad(f_fused, argnums=(0, 1))(h_s, w_s)
    v2, (dh2, dw2) = jax.value_and_grad(f_ref, argnums=(0, 1))(h, wt)
    assert dw1.shape == wt.shape
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        float(v1), float(v2), rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6)
    np.testing.assert_allclose(np.asarray(dh1, np.float32),
                               np.asarray(dh2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(dw1, np.float32),
                               np.asarray(dw2, np.float32), atol=tol)

# ragged local vocab (V=320 over 2-way model axis -> local 160, bv=128
# leaves an undefined remainder region on every shard): remainder ROWS of
# the transposed w must stay masked
V2, VS2 = 320, 300
wt2 = jax.random.normal(ks[1], (V2, D))
lab2 = jax.random.randint(ks[2], (B, S), -1, VS2)
h32 = jax.random.normal(ks[0], (B, S, D))
w_sh2 = NamedSharding(mesh, P("model", None))
h_sh2 = NamedSharding(mesh, P("data", None, None))
assert dispatch.xent_route(h32.shape, wt2.shape, None, h_sh2, w_sh2,
                           transposed=True)[0] == "kernel"

def f2(h, wt):
    return jnp.sum(dispatch.xent_loss(h, wt, lab2, vocab_size=VS2,
                                      h_sharding=h_sh2, w_sharding=w_sh2,
                                      block=(32, 128), transposed=True))
v1, (dh1, dw1) = jax.value_and_grad(f2, argnums=(0, 1))(
    jax.device_put(h32, h_sh2), jax.device_put(wt2, w_sh2))
v2, (dh2, dw2) = jax.value_and_grad(
    lambda h, wt: jnp.sum(xref.losses(h, wt.T, lab2, VS2)),
    argnums=(0, 1))(h32, wt2)
np.testing.assert_allclose(float(v1), float(v2), rtol=1e-6)
np.testing.assert_allclose(np.asarray(dh1), np.asarray(dh2), atol=1e-4)
np.testing.assert_allclose(np.asarray(dw1), np.asarray(dw2), atol=1e-4)

# non-divisible vocab (dim 0 now) on the mesh: fall back, don't mis-shard
assert dispatch.xent_route(
    (8, 16, 32), (129, 32), None, None,
    NamedSharding(mesh, P("model", None)), transposed=True)[0] == "ref"
# one axis sharding BOTH tokens and vocab: must fall back
assert dispatch.xent_route(
    (8, 16, 32), (256, 32), None,
    NamedSharding(mesh, P("data", None, None)),
    NamedSharding(mesh, P("data", None)), transposed=True)[0] == "ref"

# end-to-end: tied model + sharded fused train step stays finite and
# matches the unsharded run
from conftest import tiny_cfg
from repro.core import LabelRules, make_optimizer
from repro.data import make_dataset
from repro.models import init_params
from repro.training import init_state, make_train_step

cfg = tiny_cfg(vocab_size=250, tie_embeddings=True)
params = init_params(jax.random.PRNGKey(0), cfg)
tx = make_optimizer("scale", 3e-3, impl="fused", rules=LabelRules.tied())
ds = make_dataset(cfg, seq_len=32, global_batch=8)
batch = ds.host_batch_at(0)
s1, m1 = jax.jit(make_train_step(cfg, tx))(init_state(params, tx), batch)
step_m = make_train_step(cfg, tx, mesh=mesh)
with mesh:
    s2, m2 = jax.jit(step_m)(init_state(params, tx), batch)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                jax.tree_util.tree_leaves(s2.params)):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-5)
print("OK")
"""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_FUSED", None)
    here = os.path.dirname(__file__)
    root = os.path.join(here, "..")
    # src (repro), tests (conftest), repo root (benchmarks, via conftest)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), here, root,
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
