"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and finiteness. FULL configs are only
shape-checked (param counts vs nameplate) — never allocated."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.core import make_optimizer
from repro.data import make_dataset
from repro.models import (count_params, forward, init_params,
                          logits_from_hidden, param_shapes)
from repro.training import init_state, make_train_step

NAMEPLATE_B = {
    "deepseek-67b": (60, 75), "qwen2-7b": (7, 8.5), "granite-3-8b": (7.5, 9),
    "mistral-large-123b": (115, 130), "mamba2-370m": (0.3, 0.5),
    "llama-3.2-vision-11b": (9, 12), "dbrx-132b": (125, 140),
    "deepseek-v3-671b": (660, 685), "jamba-1.5-large-398b": (390, 405),
    "musicgen-medium": (1.2, 2.2),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    cfg = get_arch(arch)
    n = count_params(param_shapes(cfg)) / 1e9
    lo, hi = NAMEPLATE_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_arch(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    B, S = 2, 32
    ds = make_dataset(cfg, seq_len=S, global_batch=B)
    batch = ds.host_batch_at(0)

    hidden, _, aux = forward(params, cfg, batch["tokens"],
                             image_embeds=batch.get("image_embeds"))
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden)))

    logits = logits_from_hidden(params, cfg, hidden)
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.n_codebooks, S, cfg.padded_vocab)
    else:
        assert logits.shape == (B, S, cfg.padded_vocab)
    # padded vocab entries are masked to -inf-ish
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(jnp.max(logits[..., cfg.vocab_size:])) <= -1e8

    tx = make_optimizer("scale", 1e-3)
    step = jax.jit(make_train_step(cfg, tx))
    state = init_state(params, tx)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    state, m2 = step(state, batch)
    assert bool(jnp.isfinite(m2["loss"]))


def test_deepseek_v3_active_params():
    cfg = get_arch("deepseek-v3-671b")
    active = count_params(param_shapes(cfg), cfg=cfg, active_only=True) / 1e9
    assert 34 <= active <= 40  # official: 37B activated


def test_jamba_active_params():
    cfg = get_arch("jamba-1.5-large-398b")
    active = count_params(param_shapes(cfg), cfg=cfg, active_only=True) / 1e9
    assert 85 <= active <= 100  # official: 94B active


def test_granite_vocab_padding():
    cfg = get_arch("granite-3-8b")
    assert cfg.vocab_size == 49155 and cfg.padded_vocab % 128 == 0


@pytest.mark.parametrize("arch", ["gpt2-medium", "qwen2-500m", "gemma-2b"])
def test_appendix_f_archs_smoke(arch):
    """Paper Appendix F architectures (GPT2 / Qwen2-500M / Gemma-2B):
    reduced-width one-train-step smoke incl. learned-pos + GELU paths."""
    import dataclasses
    cfg = get_arch(arch)
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16, d_ff=128,
        vocab_size=256, dtype="float32", max_position=64,
        attn_kv_block=16, attn_q_block=16, loss_chunk=16)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = make_dataset(cfg, seq_len=32, global_batch=2)
    tx = make_optimizer("scale", 1e-3)
    step = jax.jit(make_train_step(cfg, tx))
    state = init_state(params, tx)
    state, metrics = step(state, ds.host_batch_at(0))
    assert bool(jnp.isfinite(metrics["loss"]))
    if cfg.pos_embed == "learned":
        assert state.opt_state.mu["pos_embed"]["w"].size == 0  # stateless
