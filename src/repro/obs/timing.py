"""Host-side step-time breakdown and device-memory accounting.

:class:`StepTimer` accumulates named wall-clock sections (``data`` wait,
blocked ``step`` time, ``ckpt`` IO, ...) between metric emissions;
``snapshot()`` returns seconds-per-section (+ call counts) and resets, so
every emitted record carries the breakdown *since the last record* —
deltas, matching the dispatch fallback-delta semantics.

:func:`device_memory` reads ``jax.local_devices()[i].memory_stats()``
where the backend provides it (TPU/GPU; CPU returns nothing) and reports
live/peak bytes per local device plus totals. Failures are swallowed —
memory accounting must never take down a training run.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

import jax


class StepTimer:
    """Accumulating wall-clock section timer (not thread-safe: the train
    loop is single-threaded on the host)."""

    def __init__(self):
        self._acc: dict = {}
        self._n: dict = {}
        self._t0 = time.perf_counter()

    @contextmanager
    def section(self, name: str):
        t = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t
            self._acc[name] = self._acc.get(name, 0.0) + dt
            self._n[name] = self._n.get(name, 0) + 1

    def add(self, name: str, seconds: float) -> None:
        self._acc[name] = self._acc.get(name, 0.0) + float(seconds)
        self._n[name] = self._n.get(name, 0) + 1

    def snapshot(self) -> dict:
        """{'time/<name>_s': secs, 'time/<name>_n': calls, 'time/wall_s':
        wall-clock since the previous snapshot}; resets the accumulators."""
        now = time.perf_counter()
        out = {"time/wall_s": now - self._t0}
        for name, secs in self._acc.items():
            out[f"time/{name}_s"] = secs
            out[f"time/{name}_n"] = self._n[name]
        self._acc, self._n, self._t0 = {}, {}, now
        return out


def device_memory() -> dict:
    """Per-local-device live/peak HBM bytes, where the backend exposes it.

    Keys: ``mem/dev<i>/bytes_in_use``, ``mem/dev<i>/peak_bytes`` plus
    ``mem/total_bytes_in_use`` / ``mem/total_peak_bytes``. Empty dict on
    backends without ``memory_stats`` (host CPU).
    """
    out: dict = {}
    total_live = total_peak = 0
    try:
        devices = jax.local_devices()
    except Exception:
        return out
    for i, d in enumerate(devices):
        try:
            ms = d.memory_stats()
        except Exception:
            ms = None
        if not ms:
            continue
        live = int(ms.get("bytes_in_use", 0))
        peak = int(ms.get("peak_bytes_in_use", live))
        out[f"mem/dev{i}/bytes_in_use"] = live
        out[f"mem/dev{i}/peak_bytes"] = peak
        total_live += live
        total_peak += peak
    if out:
        out["mem/total_bytes_in_use"] = total_live
        out["mem/total_peak_bytes"] = total_peak
    return out
