"""repro.obs — the training telemetry plane.

Structured metrics sinks (JSONL canonical / CSV / in-memory) behind a
non-blocking :class:`MetricsLogger`, the in-jit per-layer-group gradient
statistics collector (:class:`StatsPolicy` — the paper's Fig. 4/10
quantities as live metrics), host-side step-time + device-memory
accounting, and profiler trace hooks. See README.md in this package for
the metric catalogue and schema.
"""
from .metrics import (SCHEMA, CSVSink, JSONLSink, MemorySink, MetricsLogger,
                      jsonable, validate_jsonl, validate_record)
from .profile import ProfileWindow, trace_span
from .stats import StatsPolicy, make_stats_fn, split_stats, stats_keys
from .timing import StepTimer, device_memory

__all__ = [
    "SCHEMA", "CSVSink", "JSONLSink", "MemorySink", "MetricsLogger",
    "jsonable", "validate_jsonl", "validate_record",
    "ProfileWindow", "trace_span",
    "StatsPolicy", "make_stats_fn", "split_stats", "stats_keys",
    "StepTimer", "device_memory",
]
