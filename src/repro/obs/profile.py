"""Profiler trace hooks: a step-window driver for ``jax.profiler`` plus
named spans the trace viewer groups work under.

``--profile-steps A:B`` on the training CLI parses into a
:class:`ProfileWindow`; the loop calls ``maybe_start(step)`` before and
``maybe_stop(step)`` after each step, so exactly steps ``A..B`` (inclusive,
0-indexed like the log lines) land in the trace. Spans:

  * in-jit work is annotated with ``jax.named_scope`` inside the trainer
    (``fwd``, ``optimizer_update``, ``guard``, ``obs_stats``) — those names
    show up on the compiled op metadata;
  * host-side phases (checkpoint IO, data wait) wrap in
    :func:`trace_span`, a ``jax.profiler.TraceAnnotation`` when available
    and a no-op otherwise — safe to leave on every step.
"""
from __future__ import annotations

import os
import warnings
from contextlib import nullcontext
from typing import Optional

import jax


def trace_span(name: str):
    """Context manager naming a host-side span in the profiler timeline."""
    ta = getattr(jax.profiler, "TraceAnnotation", None)
    if ta is None:
        return nullcontext()
    try:
        return ta(name)
    except Exception:
        return nullcontext()


class ProfileWindow:
    """Drive ``jax.profiler.start_trace/stop_trace`` over a step range."""

    def __init__(self, start: int, stop: int, logdir: str):
        if stop < start or start < 0:
            raise ValueError(f"profile window must be 0 <= start <= stop, "
                             f"got {start}:{stop}")
        self.start = int(start)
        self.stop = int(stop)
        self.logdir = logdir
        self.active = False
        self.done = False

    @classmethod
    def parse(cls, spec: str, logdir: str) -> Optional["ProfileWindow"]:
        """``"A:B"`` (inclusive) or ``"A"`` (single step) -> window;
        ``""`` -> None."""
        if not spec:
            return None
        parts = spec.split(":")
        if len(parts) not in (1, 2):
            raise ValueError(
                f"--profile-steps wants 'A:B' or 'A', got {spec!r}")
        try:
            a = int(parts[0])
            b = int(parts[1]) if len(parts) == 2 else a
        except ValueError as e:
            raise ValueError(
                f"--profile-steps wants integers, got {spec!r}") from e
        return cls(a, b, logdir)

    def maybe_start(self, step: int) -> bool:
        if self.done or self.active or step < self.start or step > self.stop:
            return False
        try:
            os.makedirs(self.logdir, exist_ok=True)
            jax.profiler.start_trace(self.logdir)
            self.active = True
        except Exception as e:  # profiling must never kill the run
            warnings.warn(f"profiler: start_trace failed ({e}); "
                          "disabling the profile window")
            self.done = True
        return self.active

    def maybe_stop(self, step: int) -> bool:
        """Stop after the last window step (call with the step just run)."""
        if not self.active or step < self.stop:
            return False
        try:
            jax.profiler.stop_trace()
        except Exception as e:
            warnings.warn(f"profiler: stop_trace failed ({e})")
        self.active = False
        self.done = True
        return True

    def finalize(self) -> None:
        """Stop an open trace (run ended inside the window / SIGTERM)."""
        if self.active:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass
            self.active = False
            self.done = True
