"""In-jit per-layer-group gradient/update/momentum statistics.

The paper's argument is observational: gradient variance concentrates in
the output layer (Fig. 4) and LM-head gradient column norms follow token
frequency (Fig. 10) — which is why SCALE puts momentum on the head and
normalizes column-wise. This module makes those facts *live* training
metrics instead of an offline benchmark: a :class:`StatsPolicy` handed to
``make_train_step(stats=...)`` weaves a collector into the jitted step
that, every ``every_k`` steps, computes per layer group (``embedding`` /
``hidden`` / ``lm_head`` — the shared :func:`repro.core.labels.layer_group`
bucketing the offline ``benchmarks/variance_analysis.py`` uses):

  * ``grad_norm``      — group L2 gradient norm (the Fig. 4 proxy: at any
    healthy step ``lm_head`` dominates ``hidden``);
  * ``colnorm_max`` / ``colnorm_med`` / ``colnorm_disp`` — max, median and
    max/median ratio of per-output-column gradient norms over the group's
    matrices (Fig. 10 live: the head's dispersion is the token-frequency
    imbalance column-wise normalization fixes; tied heads reduce along
    their transposed storage axis);
  * ``update_norm`` / ``param_norm`` / ``update_ratio`` — the applied
    update and its scale relative to the parameters (post-guard: a
    guard-skipped step truthfully reports 0);
  * ``momentum_norm``  — L2 norm of the optimizer's first-moment buffers
    (``PipeState.mu``; zero-size placeholders of stateless groups are
    skipped, bf16 storage is read in f32).

Cadence discipline: the collector runs under a traced
``step % every_k == 0`` predicate via ``lax.cond`` — off the cadence step
the compute branch is dead (no reductions issued, metrics are zeros and
``stats/valid`` is 0). It is JH001-clean (no Python branching on traced
values) and *bitwise-inert by construction*: it only ever reads the step's
tensors, so a run with stats enabled produces exactly the params/opt_state
of a run without (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.labels import (LAYER_GROUPS, LabelRules, layer_group,
                               path_str)

_f32 = jnp.float32


class StatsPolicy(NamedTuple):
    """Static stats configuration (Python values, resolved outside jit).

    ``every_k``: collection cadence in steps (every step when 1; must be
    >= 1). ``tied``: the model ties embeddings — the token embedding IS
    the LM head, so it reports under ``lm_head`` and its column norms
    reduce along the transposed (V, D) storage axis. ``momentum``:
    include first-moment-buffer norms. ``colnorms``: include the Fig. 10
    column-norm dispersion stats. ``ratios``: include update/param norm
    ratios. ``prefix``: metric-key prefix (``<prefix>/<group>/<name>``).
    """
    every_k: int = 50
    tied: bool = False
    momentum: bool = True
    colnorms: bool = True
    ratios: bool = True
    prefix: str = "stats"


def _col_sq_norms(g, transposed: bool) -> jnp.ndarray:
    """Flattened squared per-output-column norms of a >=2-D gradient.

    A matrix stored (d_in, d_out) reduces axis -2 (one norm per output
    column, the Fig. 10 quantity: for the (D, V) head that is one norm
    per vocab token). Transposed (tied (V, D)) storage reduces axis -1.
    Stacked 3-D leaves (scan-over-layers / per-expert) contribute every
    slice's columns.
    """
    gf = g.astype(_f32)
    axis = -1 if transposed else -2
    return jnp.sum(gf * gf, axis=axis).reshape(-1)


def make_stats_fn(policy: StatsPolicy):
    """Build ``stats_fn(step, grads, old_params, new_params, opt_state)``.

    Returns a traced function producing a flat ``{key: f32 scalar}`` dict
    with identical keys every step (jit-stable metrics structure);
    ``<prefix>/valid`` is 1.0 exactly on cadence steps and every other
    stat is 0 off-cadence. Groups with no matching parameters report 0.
    """
    if policy.every_k < 1:
        raise ValueError(f"StatsPolicy.every_k must be >= 1, "
                         f"got {policy.every_k}")
    rules = LabelRules.tied() if policy.tied else LabelRules()

    names = []
    for grp in LAYER_GROUPS:
        names.append(f"{grp}/grad_norm")
        if policy.colnorms:
            names += [f"{grp}/colnorm_max", f"{grp}/colnorm_med",
                      f"{grp}/colnorm_disp"]
        if policy.ratios:
            names += [f"{grp}/update_norm", f"{grp}/param_norm",
                      f"{grp}/update_ratio"]
        if policy.momentum:
            names.append(f"{grp}/momentum_norm")

    def stats_fn(step, grads, old_params, new_params, opt_state):
        flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
        paths = [path_str(kp) for kp, _ in flat]
        groups = [layer_group(p, tied=policy.tied) for p in paths]
        g_leaves = [g for _, g in flat]
        old_leaves = jax.tree_util.tree_leaves(old_params)
        new_leaves = jax.tree_util.tree_leaves(new_params)
        # first-moment buffers: every pipeline optimizer's state mirrors
        # the param treedef in `mu` (zero-size placeholders where a group
        # is stateless); non-pipeline transforms simply have no `mu`
        mu = getattr(opt_state, "mu", None)
        mu_leaves = None
        if mu is not None and \
                jax.tree_util.tree_structure(mu) == treedef:
            mu_leaves = jax.tree_util.tree_leaves(mu)

        def compute(_):
            out = {}
            for grp in LAYER_GROUPS:
                idx = [i for i, g in enumerate(groups) if g == grp]
                gsq = sum((jnp.sum(jnp.square(g_leaves[i].astype(_f32)))
                           for i in idx), jnp.zeros((), _f32))
                out[f"{grp}/grad_norm"] = jnp.sqrt(gsq)
                if policy.colnorms:
                    sq = [_col_sq_norms(
                              g_leaves[i],
                              rules.transposed(paths[i], g_leaves[i].ndim))
                          for i in idx if g_leaves[i].ndim >= 2]
                    if sq:
                        cn = jnp.sqrt(jnp.concatenate(sq))
                        mx, md = jnp.max(cn), jnp.median(cn)
                    else:
                        mx = md = jnp.zeros((), _f32)
                    out[f"{grp}/colnorm_max"] = mx
                    out[f"{grp}/colnorm_med"] = md
                    out[f"{grp}/colnorm_disp"] = mx / jnp.maximum(md, 1e-30)
                if policy.ratios:
                    usq = sum((jnp.sum(jnp.square(
                                   new_leaves[i].astype(_f32)
                                   - old_leaves[i].astype(_f32)))
                               for i in idx), jnp.zeros((), _f32))
                    psq = sum((jnp.sum(jnp.square(
                                   old_leaves[i].astype(_f32)))
                               for i in idx), jnp.zeros((), _f32))
                    un, pn = jnp.sqrt(usq), jnp.sqrt(psq)
                    out[f"{grp}/update_norm"] = un
                    out[f"{grp}/param_norm"] = pn
                    out[f"{grp}/update_ratio"] = un / jnp.maximum(pn, 1e-30)
                if policy.momentum:
                    if mu_leaves is not None:
                        msq = sum((jnp.sum(jnp.square(
                                       mu_leaves[i].astype(_f32)))
                                   for i in idx if mu_leaves[i].size),
                                  jnp.zeros((), _f32))
                    else:
                        msq = jnp.zeros((), _f32)
                    out[f"{grp}/momentum_norm"] = jnp.sqrt(msq)
            return tuple(out[n] for n in names)

        def skip(_):
            return tuple(jnp.zeros((), _f32) for _ in names)

        hit = (step % policy.every_k) == 0
        vals = jax.lax.cond(hit, compute, skip, None)
        out = {f"{policy.prefix}/{n}": v for n, v in zip(names, vals)}
        out[f"{policy.prefix}/valid"] = hit.astype(_f32)
        return out

    return stats_fn


def stats_keys(policy: StatsPolicy) -> list:
    """The metric keys a collector built from ``policy`` emits."""
    dummy = {"x": jnp.zeros((1, 1))}
    shape = jax.eval_shape(
        lambda: make_stats_fn(policy)(jnp.zeros((), jnp.int32), dummy,
                                      dummy, dummy, None))
    return sorted(shape)


def split_stats(metrics: dict, policy: Optional[StatsPolicy]) -> tuple:
    """Split a step's metrics dict into (plain, stats) by key prefix.

    ``stats`` is {} off the cadence step (``<prefix>/valid`` 0) or when no
    policy is active — the driver writes stats fields only when they were
    actually measured, keeping off-cadence JSONL records small.
    """
    if policy is None:
        return dict(metrics), {}
    pre = policy.prefix + "/"
    plain = {k: v for k, v in metrics.items() if not k.startswith(pre)}
    valid = metrics.get(pre + "valid")
    if valid is None or not float(valid):
        return plain, {}
    stats = {k: v for k, v in metrics.items()
             if k.startswith(pre) and k != pre + "valid"}
    return plain, stats
