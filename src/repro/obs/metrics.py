"""Structured metrics sinks + a non-blocking host-side MetricsLogger.

The training loop must never block on metrics IO: records are enqueued and
a daemon worker thread writes them to every attached sink, flushing on a
record cadence and on explicit :meth:`MetricsLogger.flush` (the driver
calls it on SIGTERM and on rollback, so the tail of a dying run is on
disk). Sinks:

  * :class:`JSONLSink` — the canonical format. One JSON object per line,
    every record schema-versioned (``schema = "repro_metrics/v1"``) and
    carrying ``kind`` / ``host`` / ``step`` / ``t``; non-finite floats are
    serialized as ``null`` so every line is strict JSON.
  * :class:`CSVSink`  — convenience tabular view. The header is fixed by
    the first record written; later records fill known columns (missing
    -> empty, unknown -> dropped). Use JSONL for anything programmatic.
  * :class:`MemorySink` — in-process list of records, for tests.

Record grammar (v1)
-------------------
Required keys on every record: ``schema`` (str, ``repro_metrics/v1``),
``kind`` (str: ``train_step`` | ``serve`` | ``event`` | ``run_header`` |
``run_end`` | free-form), ``host`` (int process index), ``step`` (int),
``t`` (float unix seconds). All other keys are metric fields: numbers
(finite or ``null``), strings, booleans, or flat lists/dicts thereof.
:func:`validate_record` / :func:`validate_jsonl` enforce exactly this and
are what the tests and the CI ``obs-smoke`` job run against the output of
a real training run.
"""
from __future__ import annotations

import json
import math
import os
import queue
import threading
import time
from typing import Optional, Sequence

import numpy as np

SCHEMA = "repro_metrics/v1"

_REQUIRED = ("schema", "kind", "host", "step", "t")


def jsonable(v):
    """Coerce a metric value to a JSON-serializable form.

    jnp/np scalars become Python numbers; non-finite floats become None
    (strict-JSON lines; the guard's ``bad_step`` flag carries the NaN
    signal explicitly). Arrays become (nested) lists.
    """
    if isinstance(v, bool) or v is None or isinstance(v, str):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        f = float(v)
        return f if math.isfinite(f) else None
    if isinstance(v, dict):
        return {str(k): jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [jsonable(x) for x in v]
    try:
        arr = np.asarray(v)
    except Exception:
        return str(v)
    if arr.ndim == 0:
        return jsonable(arr.item())
    return jsonable(arr.tolist())


def validate_record(rec: dict) -> None:
    """Raise ValueError unless ``rec`` is a well-formed v1 record."""
    if not isinstance(rec, dict):
        raise ValueError(f"record must be a dict, got {type(rec).__name__}")
    missing = [k for k in _REQUIRED if k not in rec]
    if missing:
        raise ValueError(f"record missing required keys {missing}: {rec}")
    if rec["schema"] != SCHEMA:
        raise ValueError(f"unknown schema {rec['schema']!r} (want {SCHEMA!r})")
    if not isinstance(rec["kind"], str) or not rec["kind"]:
        raise ValueError(f"kind must be a non-empty str: {rec['kind']!r}")
    for key in ("host", "step"):
        if not isinstance(rec[key], int) or isinstance(rec[key], bool):
            raise ValueError(f"{key} must be an int: {rec[key]!r}")
    if not isinstance(rec["t"], (int, float)) or isinstance(rec["t"], bool):
        raise ValueError(f"t must be a number: {rec['t']!r}")

    def ok_value(v, depth=0):
        if v is None or isinstance(v, (str, bool)):
            return True
        if isinstance(v, (int, float)):
            return not (isinstance(v, float) and not math.isfinite(v))
        if depth >= 2:
            return False
        if isinstance(v, dict):
            return all(isinstance(k, str) and ok_value(x, depth + 1)
                       for k, x in v.items())
        if isinstance(v, list):
            return all(ok_value(x, depth + 1) for x in v)
        return False

    for k, v in rec.items():
        if k in _REQUIRED:
            continue
        if not ok_value(v):
            raise ValueError(f"field {k!r} is not a valid metric value: {v!r}")


def validate_jsonl(path: str) -> int:
    """Validate every line of a JSONL metrics file; return the record count."""
    n = 0
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i + 1}: not JSON: {e}") from e
            validate_record(rec)
            n += 1
    return n


# --------------------------------------------------------------------------
# Sinks. Only the logger's worker thread touches a sink after attach, so
# sinks need no locking of their own.
# --------------------------------------------------------------------------

class MemorySink:
    """Keep records in a list (tests)."""

    def __init__(self):
        self.records: list = []
        self.flushes = 0

    def write(self, rec: dict) -> None:
        self.records.append(rec)

    def flush(self) -> None:
        self.flushes += 1

    def close(self) -> None:
        pass


class JSONLSink:
    """Canonical schema-versioned JSON-lines sink (append mode)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a", buffering=1 << 16)

    def write(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, separators=(",", ":")) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.flush()
        self._f.close()


class CSVSink:
    """Tabular convenience sink; header fixed by the first record."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a", buffering=1 << 16)
        self._cols: Optional[list] = None

    @staticmethod
    def _cell(v) -> str:
        if v is None:
            return ""
        s = str(v)
        if any(c in s for c in ",\"\n"):
            s = '"' + s.replace('"', '""') + '"'
        return s

    def write(self, rec: dict) -> None:
        if self._cols is None:
            self._cols = list(_REQUIRED) + sorted(
                k for k in rec if k not in _REQUIRED)
            self._f.write(",".join(self._cols) + "\n")
        self._f.write(",".join(self._cell(rec.get(c)) for c in self._cols)
                      + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.flush()
        self._f.close()


class _Flush:
    def __init__(self):
        self.done = threading.Event()


class MetricsLogger:
    """Buffered, thread-backed metrics fan-out.

    ``log(kind, step, **fields)`` stamps the record (schema, host, wall
    time) and enqueues it — the caller never blocks on sink IO. The worker
    writes to every sink and flushes them every ``flush_every`` records;
    :meth:`flush` is synchronous (enqueues a barrier and waits), which is
    what the driver calls on SIGTERM and rollback so those tails hit disk.

    ``console(text, step=...)`` is the multi-host-safe console line: only
    host 0 prints, always flushed, and the line carries the host and step.
    """

    def __init__(self, sinks: Sequence, host: int = 0, flush_every: int = 20,
                 console_stream=None):
        self.sinks = list(sinks)
        self.host = int(host)
        self.flush_every = max(1, int(flush_every))
        self._q: queue.Queue = queue.Queue()
        self._closed = False
        self._since_flush = 0
        self._stream = console_stream
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="metrics-logger")
        self._worker.start()

    # ----------------------------------------------------------- producer

    def log(self, kind: str, step: int, fields: Optional[dict] = None,
            **kw) -> dict:
        """Stamp + enqueue one record. Metric fields come either as a
        ``fields`` dict (keys may contain ``/``) or as keyword args."""
        rec = {"schema": SCHEMA, "kind": str(kind), "host": self.host,
               "step": int(step), "t": time.time()}
        for src in (fields or {}), kw:
            for k, v in src.items():
                if k in _REQUIRED:
                    raise ValueError(f"field {k!r} would shadow a required "
                                     "record key")
                rec[k] = jsonable(v)
        if not self._closed:
            self._q.put(rec)
        return rec

    def console(self, text: str, step: int = 0, raw: bool = False) -> None:
        """Host-0-only console line, flushed. ``raw=True`` keeps ``text``
        verbatim as the line start (the historical ``step N loss ...``
        format the greppable driver lines use) and appends the host tag;
        otherwise the line is prefixed ``[h<host> s<step>]``."""
        if self.host != 0:
            return
        line = f"{text} host {self.host}" if raw \
            else f"[h{self.host} s{int(step)}] {text}"
        print(line, flush=True, file=self._stream)

    def flush(self, timeout: float = 30.0) -> bool:
        """Block until everything enqueued so far is written + flushed."""
        if self._closed:
            return True
        req = _Flush()
        self._q.put(req)
        return req.done.wait(timeout)

    def close(self, timeout: float = 30.0) -> None:
        if self._closed:
            return
        self.flush(timeout)
        self._closed = True
        self._q.put(None)
        self._worker.join(timeout)
        for s in self.sinks:
            s.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------- worker

    def _flush_sinks(self) -> None:
        for s in self.sinks:
            try:
                s.flush()
            except Exception:
                pass
        self._since_flush = 0

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._flush_sinks()
                return
            if isinstance(item, _Flush):
                self._flush_sinks()
                item.done.set()
                continue
            for s in self.sinks:
                try:
                    s.write(item)
                except Exception:
                    pass
            self._since_flush += 1
            if self._since_flush >= self.flush_every:
                self._flush_sinks()
