"""Roofline analysis from compiled dry-run artifacts.

``collective_bytes`` is not exposed by ``cost_analysis()``; we parse the
SPMD-partitioned HLO (per-device view, so printed shapes are local shards)
and sum the moved bytes of every collective:

  all-gather          -> out_bytes                (received per device)
  reduce-scatter      -> out_bytes * (group - 1)  (ring sends n-1 shards)
  all-reduce          -> 2 * out_bytes * (g-1)/g  (RS + AG ring)
  all-to-all          -> out_bytes
  collective-permute  -> out_bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional


from .mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = f32[1024,512]{1,0} all-reduce(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9\[\],\s{}]*?)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)
_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP2_RE.search(line)
    if m:  # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by = {k: 0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    seen_start = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2).lower()
        # async ops appear as -start/-done pairs: count -start only
        if "-done(" in line:
            continue
        out_bytes = _shape_bytes(type_str)
        g = _group_size(line)
        if kind == "all-reduce":
            moved = int(2 * out_bytes * (g - 1) / max(g, 1))
        elif kind == "reduce-scatter":
            moved = out_bytes * (g - 1)
        else:
            moved = out_bytes
        bytes_by[kind] += moved
        count_by[kind] += 1
    return CollectiveStats(bytes_by, count_by)


def extract_cost(compiled) -> dict:
    """FLOPs / bytes-accessed from compiled.cost_analysis() (per device)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }


def extract_memory(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def roofline(cost: dict, coll: CollectiveStats, *, model_flops: float,
             n_chips: int, hw: Optional[dict] = None) -> dict:
    """The three roofline terms (seconds) + bottleneck + usefulness ratio.

    ``cost`` comes from the SPMD-partitioned module, i.e. per-device values.
    """
    hw = hw or HW
    compute_s = cost["flops"] / hw["peak_flops_bf16"]
    memory_s = cost["bytes_accessed"] / hw["hbm_bw"]
    collective_s = coll.total_bytes / hw["ici_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_s = max(terms.values())
    useful = model_flops / max(cost["flops"] * n_chips, 1.0)
    mfu = (model_flops / n_chips / max(step_s, 1e-30)) / hw["peak_flops_bf16"]
    return {
        **terms,
        "bottleneck": bottleneck,
        "model_flops_total": model_flops,
        "hlo_flops_per_chip": cost["flops"],
        "useful_flop_ratio": useful,
        "roofline_step_s": step_s,
        "mfu_at_roofline": mfu,
        "collective_bytes": coll.total_bytes,
        "collective_breakdown": coll.bytes_by_kind,
        "collective_counts": coll.count_by_kind,
    }


def model_flops_for(cfg, shape_kind: str, seq_len: int, global_batch: int) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (per step)."""
    from repro.models import count_params, param_shapes
    n_active = count_params(param_shapes(cfg), cfg=cfg, active_only=True)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * global_batch
