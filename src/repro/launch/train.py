"""Training driver CLI.

Runs a real training loop on whatever devices exist (CPU here, a TPU slice
in production), with sharding from the same rules table the dry-run uses,
deterministic resumable data, periodic checkpointing and auto-resume.

Resilience (PR 8): the step runs under the in-jit anomaly guard
(:mod:`repro.training.resilience`) — non-finite loss/grad-norm or a loss
spike skips the update bitwise; after ``--max-bad-steps`` consecutive bad
steps the driver rolls back to the last verifiable checkpoint and cuts the
learning rate by ``--rollback-lr-cut`` (recompiling the step with the new
peak LR). SIGTERM triggers a final synchronous checkpoint and a clean
exit, so a preempted run under ``--resume auto`` loses at most the current
step. ``REPRO_FAULTS`` (see :mod:`repro.training.faults`) injects
deterministic chaos into all of it.

Telemetry (PR 10, :mod:`repro.obs`): ``--log-dir`` attaches a JSONL
metrics sink (schema ``repro_metrics/v1``, one ``metrics.<host>.jsonl``
per process — never cross-host-written) behind a non-blocking background
logger; records at ``--metrics-every`` cadence carry loss/norm metrics,
step-time breakdown (data wait / blocked step / checkpoint IO), tokens/s,
per-device memory where the backend reports it, and kernel-fallback
*deltas* (``dispatch.fallback_delta``). ``--stats-every K`` weaves the
in-jit per-layer-group statistics collector into the step (the paper's
Fig. 4/10 quantities live — see :mod:`repro.obs.stats`); ``--profile-steps
A:B`` wraps those steps in ``jax.profiler`` traces. Console lines are
host-0-only and always flushed; the logger is flushed on SIGTERM and on
rollback so a dying run's tail reaches disk.

Example (end-to-end ~100M-param pretraining driver):
  PYTHONPATH=src python -m repro.launch.train --arch llama-130m \
      --optimizer scale --steps 200 --batch 16 --seq 256 \
      --ckpt-dir /tmp/ckpt --ckpt-every 50 --resume auto \
      --log-dir /tmp/run0 --stats-every 50
"""
from __future__ import annotations

import argparse
import os
import signal
import time

import jax

from repro.checkpoint import restore_latest, save, save_async
from repro.configs import get_arch
from repro.core import linear_warmup_cosine, make_optimizer
from repro.data import make_dataset
from repro.kernels import dispatch
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.models.sharding import Rules
from repro.obs import (JSONLSink, MetricsLogger, ProfileWindow, StatsPolicy,
                       StepTimer, device_memory, split_stats, trace_span)
from repro.training import (GuardPolicy, init_guard_state, init_state,
                            make_train_step, resolve_plan)


def build(args, lr_scale: float = 1.0):
    """(cfg, tx) for the run. ``lr_scale`` scales the peak LR (rollback cut)."""
    cfg = get_arch(args.arch, smoke=args.smoke)
    if args.seq and cfg.attn_kv_block > args.seq:
        cfg.attn_kv_block = cfg.attn_q_block = max(16, args.seq // 4)
    cfg.loss_chunk = min(cfg.loss_chunk, args.seq)
    if args.dtype:
        cfg.dtype = args.dtype
    if getattr(args, "tie_embeddings", False):
        cfg.tie_embeddings = True
    sched = linear_warmup_cosine(args.lr * lr_scale, args.steps)
    if cfg.tie_embeddings:
        # feature-detect rather than enumerate names (like the trainer's
        # shardings/grad_scale detection): any optimizer whose factory
        # takes LabelRules gets the tied embedding routed to the 'last'
        # group — scale would otherwise hard-error (a tied tree has no
        # lm_head to carry the momentum). Optimizers without a rules
        # kwarg treat every matrix alike, so there is nothing to route;
        # note only scale flips its col/row kind for the (V, D) storage —
        # the fixed-kind sgd_*norm ablations normalize along the storage
        # axis as defined.
        from repro.core import OPTIMIZER_REGISTRY
        from repro.core.labels import LabelRules
        spec = OPTIMIZER_REGISTRY.get(args.optimizer.lower())
        if spec is not None and "rules" in spec.valid_kwargs():
            return cfg, make_optimizer(args.optimizer, sched,
                                       rules=LabelRules.tied())
    tx = make_optimizer(args.optimizer, sched)
    return cfg, tx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--optimizer", default="scale")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--pack-documents", dest="pack_documents",
                    action="store_true",
                    help="first-fit pack variable-length documents into "
                         "each (batch, seq) row; batches gain segment_ids "
                         "/ positions / loss_weights and attention + loss "
                         "stay within document boundaries")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--dtype", default="")
    ap.add_argument("--tie-embeddings", dest="tie_embeddings",
                    action="store_true",
                    help="tie the LM head to the token embedding (no "
                         "lm_head params; SCALE momentum moves to the "
                         "tied matrix)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-dir", default="",
                    help="write schema-versioned JSONL metrics records "
                         "(metrics.<host>.jsonl) under this directory via "
                         "the non-blocking background logger")
    ap.add_argument("--metrics-every", type=int, default=10,
                    help="JSONL record cadence in steps (needs --log-dir)")
    ap.add_argument("--stats-every", type=int, default=0,
                    help="cadence of the in-jit per-layer-group gradient "
                         "statistics (Fig. 4/10 live: grad norms, column-"
                         "norm dispersion, update/param ratios, momentum "
                         "norms); 0 disables the collector entirely")
    ap.add_argument("--profile-steps", default="",
                    help="'A:B' (inclusive) or 'A': wrap those steps in a "
                         "jax.profiler trace written to --profile-dir")
    ap.add_argument("--profile-dir", default="",
                    help="profiler trace directory (default "
                         "<log-dir>/profile)")
    ap.add_argument("--no-guard", action="store_true",
                    help="disable the in-jit anomaly guard (finite checks "
                         "on loss/grad norm, step skipping, rollback)")
    ap.add_argument("--spike-factor", type=float, default=0.0,
                    help="skip steps whose loss exceeds this multiple of "
                         "the accepted-loss EMA (0 disables the spike "
                         "check; finite checks stay on)")
    ap.add_argument("--spike-warmup", type=int, default=20,
                    help="accepted steps before the spike check arms")
    ap.add_argument("--max-bad-steps", type=int, default=10,
                    help="consecutive guard-skipped steps before rolling "
                         "back to the last checkpoint with an LR cut "
                         "(0 = never roll back, skip forever)")
    ap.add_argument("--rollback-lr-cut", type=float, default=0.5,
                    help="multiply the peak LR by this on every rollback")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="abort the run after this many rollbacks (a "
                         "deterministic fault replays identically after "
                         "restore, so an unbounded loop would never "
                         "terminate)")
    args = ap.parse_args(argv)

    guard = None if args.no_guard else GuardPolicy(
        spike_factor=args.spike_factor, spike_warmup=args.spike_warmup,
        max_bad_steps=args.max_bad_steps)
    faults = resolve_plan()  # REPRO_FAULTS, read once, outside jit

    # ---- telemetry plane: every record/line carries (host, step); the
    # JSONL file is per-host (never cross-host-written) and only host 0
    # speaks on the console (multi-host log hygiene)
    host = jax.process_index()
    sinks = []
    if args.log_dir:
        os.makedirs(args.log_dir, exist_ok=True)
        sinks.append(JSONLSink(
            os.path.join(args.log_dir, f"metrics.{host}.jsonl")))
    logger = MetricsLogger(sinks, host=host)
    profile = ProfileWindow.parse(
        args.profile_steps,
        args.profile_dir or os.path.join(args.log_dir or ".", "profile"))

    if faults is not None:
        logger.console(f"fault injection active: {faults}")

    cfg, tx = build(args)
    rules = Rules(cfg.rule_overrides)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=n_dev)
    stats = StatsPolicy(every_k=args.stats_every,
                        tied=cfg.tie_embeddings) \
        if args.stats_every > 0 else None
    logger.console(f"arch={cfg.name} optimizer={args.optimizer} "
                   f"devices={n_dev} "
                   f"guard={'off' if guard is None else 'on'}"
                   + (f" stats_every={args.stats_every}" if stats else ""))
    logger.log("run_header", 0, arch=cfg.name, optimizer=args.optimizer,
               devices=n_dev, guard=guard is not None,
               stats_every=args.stats_every, steps=args.steps,
               batch=args.batch, seq=args.seq, lr=args.lr,
               grad_accum=args.grad_accum,
               pack_documents=bool(args.pack_documents),
               tie_embeddings=bool(cfg.tie_embeddings))

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if n_dev > 1:
        # place params per the rules table so the fused optimizer runs
        # sharded from step 0 (its kernels psum norm reductions over the
        # mesh — see repro.kernels.dispatch)
        from repro.models import param_logical_axes
        from repro.models.sharding import tree_shardings
        params = jax.device_put(
            params, tree_shardings(param_logical_axes(cfg), mesh, rules,
                                   params))
    state = init_state(params, tx, guard=guard is not None)
    start_step = 0
    if args.resume == "auto" and args.ckpt_dir:
        got = restore_latest(args.ckpt_dir, state)
        if got is not None:
            state, start_step = got
            logger.console(f"resumed from step {start_step}",
                           step=start_step)

    ds = make_dataset(cfg, seq_len=args.seq, global_batch=args.batch,
                      seed=args.seed, pack_documents=args.pack_documents)

    def make_step(tx):
        return make_train_step(cfg, tx, grad_accum=args.grad_accum,
                               clip_norm=args.clip_norm, rules=rules,
                               mesh=mesh if n_dev > 1 else None, donate=True,
                               guard=guard, faults=faults, stats=stats)

    step_fn = make_step(tx)

    # SIGTERM (preemption notice) -> finish the current step, write a final
    # synchronous checkpoint, exit cleanly; --resume auto picks it up
    stop = {"sigterm": False}

    def _on_sigterm(signum, frame):
        del signum, frame
        stop["sigterm"] = True

    try:
        prev_handler = signal.signal(signal.SIGTERM, _on_sigterm)
    except ValueError:  # not the main thread (embedded use): no handler
        prev_handler = None

    t0 = time.time()
    pending = None
    # packed rows carry fewer real tokens than batch*seq; the loss's token
    # weight is the honest numerator for tok/s there
    tokens_per_step = args.batch * args.seq
    eff_tokens = 0.0
    step, done_steps = start_step, 0
    lr_scale, rollbacks = 1.0, 0
    metrics = {"loss": float("nan")}
    timer = StepTimer()
    fb_prev = dispatch.fallback_snapshot()

    last_emitted = -1

    def emit_record(step, tput):
        """One train_step JSONL record: loss/norm metrics, on-cadence
        stats, step-time breakdown deltas, memory, fallback deltas."""
        nonlocal fb_prev, last_emitted
        last_emitted = step
        plain, stat_vals = split_stats(metrics, stats)
        rec = dict(plain)
        rec.update(stat_vals)
        rec.update(timer.snapshot())
        rec.update(device_memory())
        fb = dispatch.fallback_snapshot()
        delta = dispatch.fallback_delta(fb_prev, fb)
        fb_prev = fb
        if delta:
            rec["fallbacks"] = delta
        rec["tokens_per_s"] = tput
        rec["lr_scale"] = lr_scale
        rec["rollbacks"] = rollbacks
        logger.log("train_step", step, rec)

    try:
        while step < args.steps and not stop["sigterm"]:
            if profile is not None:
                profile.maybe_start(step)
            with timer.section("data"), trace_span("data_wait"):
                batch = ds.host_batch_at(step)
            with timer.section("step"), trace_span("train_step"):
                state, metrics = step_fn(state, batch)
            rollback_flag = False
            if guard is not None:
                with timer.section("sync"):
                    rollback_flag = bool(float(metrics["rollback"]))
            if profile is not None:
                profile.maybe_stop(step)
            if rollback_flag:
                # in-jit code flagged an unrecoverable streak; the host
                # takes the action jit cannot: restore + LR cut + retrace
                lr_scale *= args.rollback_lr_cut
                rollbacks += 1
                logger.log("event", step, event="rollback",
                           rollbacks=rollbacks, lr_scale=lr_scale,
                           skipped=metrics["skipped"])
                logger.flush()     # the tail of a sick run must hit disk
                if rollbacks > args.max_rollbacks:
                    raise RuntimeError(
                        f"giving up after {args.max_rollbacks} rollbacks: "
                        f"the run keeps hitting {args.max_bad_steps} "
                        f"consecutive bad steps")
                got = restore_latest(args.ckpt_dir, state) \
                    if args.ckpt_dir else None
                if got is not None:
                    state, step = got
                    logger.console(f"rollback #{rollbacks}: restored step "
                                   f"{step}, peak lr x{lr_scale:g}",
                                   step=step)
                else:
                    # nothing to roll back to: reset the streak and push on
                    # with the cut LR (the guard keeps skipping bad steps)
                    step += 1
                    logger.console(f"rollback #{rollbacks}: no checkpoint "
                                   f"in {args.ckpt_dir or '<none>'}; "
                                   f"continuing with peak lr x{lr_scale:g}",
                                   step=step)
                state = state._replace(guard=init_guard_state())
                _, tx = build(args, lr_scale)
                step_fn = make_step(tx)
                continue
            step += 1
            done_steps += 1
            eff_tokens += float(metrics.get("weight", tokens_per_step)) \
                if args.pack_documents else tokens_per_step
            tput = eff_tokens / max(time.time() - t0, 1e-9)
            if step % args.log_every == 0 or done_steps == 1:
                line = (f"step {step:6d} loss {float(metrics['loss']):.4f} "
                        f"|g| {float(metrics['grad_norm']):.3f} "
                        f"tok/s {tput:,.0f}")
                if guard is not None:
                    line += (f" skipped {int(metrics['skipped'])}"
                             f" rollbacks {rollbacks}")
                fb = dispatch.fallback_counts()
                if fb:
                    line += f" kernel-fallbacks {sum(fb.values())}"
                logger.console(line, step=step, raw=True)
            if args.log_dir and (step % args.metrics_every == 0
                                 or done_steps == 1):
                emit_record(step, tput)
            if args.ckpt_dir and step % args.ckpt_every == 0:
                with timer.section("ckpt"), trace_span("checkpoint"):
                    if pending is not None:
                        pending.wait()   # one checkpoint in flight at a time
                    pending = save_async(args.ckpt_dir, step, state)
        with timer.section("ckpt"), trace_span("checkpoint"):
            if pending is not None:
                pending.wait()
            if args.ckpt_dir:
                save(args.ckpt_dir, step, state)
        if stop["sigterm"]:
            logger.console(f"sigterm: checkpointed step {step}, exiting "
                           "cleanly", step=step)
        else:
            logger.console(f"done: final loss {float(metrics['loss']):.4f}",
                           step=step)
        if args.log_dir and done_steps and step != last_emitted:
            emit_record(step, eff_tokens / max(time.time() - t0, 1e-9))
        logger.log("run_end", step,
                   reason="sigterm" if stop["sigterm"] else "done",
                   loss=metrics["loss"], rollbacks=rollbacks,
                   fallbacks=dispatch.fallback_counts() or None)
    finally:
        if profile is not None:
            profile.finalize()
        logger.close()
        if prev_handler is not None:
            signal.signal(signal.SIGTERM, prev_handler)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
