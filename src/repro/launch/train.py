"""Training driver CLI.

Runs a real training loop on whatever devices exist (CPU here, a TPU slice
in production), with sharding from the same rules table the dry-run uses,
deterministic resumable data, periodic checkpointing and auto-resume.

Example (end-to-end ~100M-param pretraining driver):
  PYTHONPATH=src python -m repro.launch.train --arch llama-130m \
      --optimizer scale --steps 200 --batch 16 --seq 256 \
      --ckpt-dir /tmp/ckpt --ckpt-every 50 --resume auto
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import restore_latest, save, save_async
from repro.configs import get_arch
from repro.core import linear_warmup_cosine, make_optimizer
from repro.data import make_dataset
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.models.sharding import Rules
from repro.training import init_state, make_train_step


def build(args):
    cfg = get_arch(args.arch, smoke=args.smoke)
    if args.seq and cfg.attn_kv_block > args.seq:
        cfg.attn_kv_block = cfg.attn_q_block = max(16, args.seq // 4)
    cfg.loss_chunk = min(cfg.loss_chunk, args.seq)
    if args.dtype:
        cfg.dtype = args.dtype
    if getattr(args, "tie_embeddings", False):
        cfg.tie_embeddings = True
    sched = linear_warmup_cosine(args.lr, args.steps)
    if cfg.tie_embeddings:
        # feature-detect rather than enumerate names (like the trainer's
        # shardings/grad_scale detection): any optimizer whose factory
        # takes LabelRules gets the tied embedding routed to the 'last'
        # group — scale would otherwise hard-error (a tied tree has no
        # lm_head to carry the momentum). Optimizers without a rules
        # kwarg treat every matrix alike, so there is nothing to route;
        # note only scale flips its col/row kind for the (V, D) storage —
        # the fixed-kind sgd_*norm ablations normalize along the storage
        # axis as defined.
        from repro.core import OPTIMIZER_REGISTRY
        from repro.core.labels import LabelRules
        spec = OPTIMIZER_REGISTRY.get(args.optimizer.lower())
        if spec is not None and "rules" in spec.valid_kwargs():
            return cfg, make_optimizer(args.optimizer, sched,
                                       rules=LabelRules.tied())
    tx = make_optimizer(args.optimizer, sched)
    return cfg, tx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--optimizer", default="scale")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--dtype", default="")
    ap.add_argument("--tie-embeddings", dest="tie_embeddings",
                    action="store_true",
                    help="tie the LM head to the token embedding (no "
                         "lm_head params; SCALE momentum moves to the "
                         "tied matrix)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg, tx = build(args)
    rules = Rules(cfg.rule_overrides)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=n_dev)
    print(f"arch={cfg.name} optimizer={args.optimizer} devices={n_dev}")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if n_dev > 1:
        # place params per the rules table so the fused optimizer runs
        # sharded from step 0 (its kernels psum norm reductions over the
        # mesh — see repro.kernels.dispatch)
        from repro.models import param_logical_axes
        from repro.models.sharding import tree_shardings
        params = jax.device_put(
            params, tree_shardings(param_logical_axes(cfg), mesh, rules,
                                   params))
    state = init_state(params, tx)
    start_step = 0
    if args.resume == "auto" and args.ckpt_dir:
        got = restore_latest(args.ckpt_dir, state)
        if got is not None:
            state, start_step = got
            print(f"resumed from step {start_step}")

    ds = make_dataset(cfg, seq_len=args.seq, global_batch=args.batch,
                      seed=args.seed)
    step_fn = make_train_step(cfg, tx, grad_accum=args.grad_accum,
                              clip_norm=args.clip_norm, rules=rules,
                              mesh=mesh if n_dev > 1 else None, donate=True)

    t0 = time.time()
    pending = None
    tokens_per_step = args.batch * args.seq
    for step in range(start_step, args.steps):
        batch = ds.host_batch_at(step)
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == start_step:
            dt = time.time() - t0
            tput = tokens_per_step * (step + 1 - start_step) / max(dt, 1e-9)
            print(f"step {step+1:6d} loss {float(metrics['loss']):.4f} "
                  f"|g| {float(metrics['grad_norm']):.3f} "
                  f"tok/s {tput:,.0f}", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            if pending is not None:
                pending.wait()        # one checkpoint in flight at a time
            pending = save_async(args.ckpt_dir, step + 1, state)
    if pending is not None:
        pending.wait()
    if args.ckpt_dir:
        save(args.ckpt_dir, args.steps, state)
    print(f"done: final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
