"""Training driver CLI.

Runs a real training loop on whatever devices exist (CPU here, a TPU slice
in production), with sharding from the same rules table the dry-run uses,
deterministic resumable data, periodic checkpointing and auto-resume.

Resilience (PR 8): the step runs under the in-jit anomaly guard
(:mod:`repro.training.resilience`) — non-finite loss/grad-norm or a loss
spike skips the update bitwise; after ``--max-bad-steps`` consecutive bad
steps the driver rolls back to the last verifiable checkpoint and cuts the
learning rate by ``--rollback-lr-cut`` (recompiling the step with the new
peak LR). SIGTERM triggers a final synchronous checkpoint and a clean
exit, so a preempted run under ``--resume auto`` loses at most the current
step. ``REPRO_FAULTS`` (see :mod:`repro.training.faults`) injects
deterministic chaos into all of it.

Example (end-to-end ~100M-param pretraining driver):
  PYTHONPATH=src python -m repro.launch.train --arch llama-130m \
      --optimizer scale --steps 200 --batch 16 --seq 256 \
      --ckpt-dir /tmp/ckpt --ckpt-every 50 --resume auto
"""
from __future__ import annotations

import argparse
import signal
import time

import jax

from repro.checkpoint import restore_latest, save, save_async
from repro.configs import get_arch
from repro.core import linear_warmup_cosine, make_optimizer
from repro.data import make_dataset
from repro.kernels import dispatch
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.models.sharding import Rules
from repro.training import (GuardPolicy, init_guard_state, init_state,
                            make_train_step, resolve_plan)


def build(args, lr_scale: float = 1.0):
    """(cfg, tx) for the run. ``lr_scale`` scales the peak LR (rollback cut)."""
    cfg = get_arch(args.arch, smoke=args.smoke)
    if args.seq and cfg.attn_kv_block > args.seq:
        cfg.attn_kv_block = cfg.attn_q_block = max(16, args.seq // 4)
    cfg.loss_chunk = min(cfg.loss_chunk, args.seq)
    if args.dtype:
        cfg.dtype = args.dtype
    if getattr(args, "tie_embeddings", False):
        cfg.tie_embeddings = True
    sched = linear_warmup_cosine(args.lr * lr_scale, args.steps)
    if cfg.tie_embeddings:
        # feature-detect rather than enumerate names (like the trainer's
        # shardings/grad_scale detection): any optimizer whose factory
        # takes LabelRules gets the tied embedding routed to the 'last'
        # group — scale would otherwise hard-error (a tied tree has no
        # lm_head to carry the momentum). Optimizers without a rules
        # kwarg treat every matrix alike, so there is nothing to route;
        # note only scale flips its col/row kind for the (V, D) storage —
        # the fixed-kind sgd_*norm ablations normalize along the storage
        # axis as defined.
        from repro.core import OPTIMIZER_REGISTRY
        from repro.core.labels import LabelRules
        spec = OPTIMIZER_REGISTRY.get(args.optimizer.lower())
        if spec is not None and "rules" in spec.valid_kwargs():
            return cfg, make_optimizer(args.optimizer, sched,
                                       rules=LabelRules.tied())
    tx = make_optimizer(args.optimizer, sched)
    return cfg, tx


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config for --arch")
    ap.add_argument("--optimizer", default="scale")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--pack-documents", dest="pack_documents",
                    action="store_true",
                    help="first-fit pack variable-length documents into "
                         "each (batch, seq) row; batches gain segment_ids "
                         "/ positions / loss_weights and attention + loss "
                         "stay within document boundaries")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--clip-norm", type=float, default=1.0)
    ap.add_argument("--dtype", default="")
    ap.add_argument("--tie-embeddings", dest="tie_embeddings",
                    action="store_true",
                    help="tie the LM head to the token embedding (no "
                         "lm_head params; SCALE momentum moves to the "
                         "tied matrix)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-guard", action="store_true",
                    help="disable the in-jit anomaly guard (finite checks "
                         "on loss/grad norm, step skipping, rollback)")
    ap.add_argument("--spike-factor", type=float, default=0.0,
                    help="skip steps whose loss exceeds this multiple of "
                         "the accepted-loss EMA (0 disables the spike "
                         "check; finite checks stay on)")
    ap.add_argument("--spike-warmup", type=int, default=20,
                    help="accepted steps before the spike check arms")
    ap.add_argument("--max-bad-steps", type=int, default=10,
                    help="consecutive guard-skipped steps before rolling "
                         "back to the last checkpoint with an LR cut "
                         "(0 = never roll back, skip forever)")
    ap.add_argument("--rollback-lr-cut", type=float, default=0.5,
                    help="multiply the peak LR by this on every rollback")
    ap.add_argument("--max-rollbacks", type=int, default=3,
                    help="abort the run after this many rollbacks (a "
                         "deterministic fault replays identically after "
                         "restore, so an unbounded loop would never "
                         "terminate)")
    args = ap.parse_args(argv)

    guard = None if args.no_guard else GuardPolicy(
        spike_factor=args.spike_factor, spike_warmup=args.spike_warmup,
        max_bad_steps=args.max_bad_steps)
    faults = resolve_plan()  # REPRO_FAULTS, read once, outside jit
    if faults is not None:
        print(f"fault injection active: {faults}")

    cfg, tx = build(args)
    rules = Rules(cfg.rule_overrides)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=n_dev)
    print(f"arch={cfg.name} optimizer={args.optimizer} devices={n_dev} "
          f"guard={'off' if guard is None else 'on'}")

    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    if n_dev > 1:
        # place params per the rules table so the fused optimizer runs
        # sharded from step 0 (its kernels psum norm reductions over the
        # mesh — see repro.kernels.dispatch)
        from repro.models import param_logical_axes
        from repro.models.sharding import tree_shardings
        params = jax.device_put(
            params, tree_shardings(param_logical_axes(cfg), mesh, rules,
                                   params))
    state = init_state(params, tx, guard=guard is not None)
    start_step = 0
    if args.resume == "auto" and args.ckpt_dir:
        got = restore_latest(args.ckpt_dir, state)
        if got is not None:
            state, start_step = got
            print(f"resumed from step {start_step}")

    ds = make_dataset(cfg, seq_len=args.seq, global_batch=args.batch,
                      seed=args.seed, pack_documents=args.pack_documents)

    def make_step(tx):
        return make_train_step(cfg, tx, grad_accum=args.grad_accum,
                               clip_norm=args.clip_norm, rules=rules,
                               mesh=mesh if n_dev > 1 else None, donate=True,
                               guard=guard, faults=faults)

    step_fn = make_step(tx)

    # SIGTERM (preemption notice) -> finish the current step, write a final
    # synchronous checkpoint, exit cleanly; --resume auto picks it up
    stop = {"sigterm": False}

    def _on_sigterm(signum, frame):
        del signum, frame
        stop["sigterm"] = True

    try:
        prev_handler = signal.signal(signal.SIGTERM, _on_sigterm)
    except ValueError:  # not the main thread (embedded use): no handler
        prev_handler = None

    t0 = time.time()
    pending = None
    # packed rows carry fewer real tokens than batch*seq; the loss's token
    # weight is the honest numerator for tok/s there
    tokens_per_step = args.batch * args.seq
    eff_tokens = 0.0
    step, done_steps = start_step, 0
    lr_scale, rollbacks = 1.0, 0
    metrics = {"loss": float("nan")}
    try:
        while step < args.steps and not stop["sigterm"]:
            batch = ds.host_batch_at(step)
            state, metrics = step_fn(state, batch)
            if guard is not None and float(metrics["rollback"]):
                # in-jit code flagged an unrecoverable streak; the host
                # takes the action jit cannot: restore + LR cut + retrace
                lr_scale *= args.rollback_lr_cut
                rollbacks += 1
                if rollbacks > args.max_rollbacks:
                    raise RuntimeError(
                        f"giving up after {args.max_rollbacks} rollbacks: "
                        f"the run keeps hitting {args.max_bad_steps} "
                        f"consecutive bad steps")
                got = restore_latest(args.ckpt_dir, state) \
                    if args.ckpt_dir else None
                if got is not None:
                    state, step = got
                    print(f"rollback #{rollbacks}: restored step {step}, "
                          f"peak lr x{lr_scale:g}", flush=True)
                else:
                    # nothing to roll back to: reset the streak and push on
                    # with the cut LR (the guard keeps skipping bad steps)
                    step += 1
                    print(f"rollback #{rollbacks}: no checkpoint in "
                          f"{args.ckpt_dir or '<none>'}; continuing with "
                          f"peak lr x{lr_scale:g}", flush=True)
                state = state._replace(guard=init_guard_state())
                _, tx = build(args, lr_scale)
                step_fn = make_step(tx)
                continue
            step += 1
            done_steps += 1
            eff_tokens += float(metrics.get("weight", tokens_per_step)) \
                if args.pack_documents else tokens_per_step
            if step % args.log_every == 0 or done_steps == 1:
                dt = time.time() - t0
                tput = eff_tokens / max(dt, 1e-9)
                line = (f"step {step:6d} loss {float(metrics['loss']):.4f} "
                        f"|g| {float(metrics['grad_norm']):.3f} "
                        f"tok/s {tput:,.0f}")
                if guard is not None:
                    line += (f" skipped {int(metrics['skipped'])}"
                             f" rollbacks {rollbacks}")
                fb = dispatch.fallback_counts()
                if fb:
                    line += f" kernel-fallbacks {sum(fb.values())}"
                print(line, flush=True)
            if args.ckpt_dir and step % args.ckpt_every == 0:
                if pending is not None:
                    pending.wait()        # one checkpoint in flight at a time
                pending = save_async(args.ckpt_dir, step, state)
        if pending is not None:
            pending.wait()
        if args.ckpt_dir:
            save(args.ckpt_dir, step, state)
        if stop["sigterm"]:
            print(f"sigterm: checkpointed step {step}, exiting cleanly",
                  flush=True)
        else:
            print(f"done: final loss {float(metrics['loss']):.4f}")
    finally:
        if prev_handler is not None:
            signal.signal(signal.SIGTERM, prev_handler)
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
