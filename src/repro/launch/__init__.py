"""repro.launch — production meshes, dry-run, roofline, training CLI."""
