"""Recursive HLO cost analyzer with while-loop trip-count awareness.

``compiled.cost_analysis()`` counts each ``while`` body **once**, which
undercounts scanned-layer models by orders of magnitude. The compiled HLO
text, however, annotates loops with ``known_trip_count``; this module parses
the post-optimization module and accumulates, per device:

  * flops            — 2*prod(out)*prod(contracted) for dot/conv (descending
                       into fusions), + 1 flop/elem for elementwise arithmetic
  * bytes_accessed   — operands + outputs at fusion/instruction granularity
                       (fusion internals are VMEM-resident, XLA's own model)
  * collective bytes — ring-model moved bytes per collective kind

Every quantity is multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128|token)"
    r"\[([0-9,]*)\]")

_COMP_HDR = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
# result name = <type...> <opcode>(  — the type never contains '(', so the
# first lowercase-word-followed-by-paren is the opcode.
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([a-z][\w\-]*)\((.*)$")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "and", "or", "xor", "negate", "abs", "sign",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "cosine", "sine", "expm1", "log1p", "erf"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "copy-start", "copy-done"}
# Ops that touch only a window of their (possibly huge) operands: count the
# actually-moved bytes, not the whole buffer (a dynamic-slice of the stacked
# layer params inside a scan reads one layer, not all L).
_WINDOW_READS = {"dynamic-slice", "slice", "gather", "broadcast", "reshape",
                 "convert", "copy", "transpose", "reverse", "pad"}


def _shapes_in(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        shape = [int(d) for d in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, shape in _shapes_in(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _elems_of(type_str: str) -> int:
    total = 0
    for _, shape in _shapes_in(type_str):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Inst:
    name: str
    type_str: str
    op: str
    rest: str              # everything after the opening paren
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    insts: List[Inst]
    by_name: Dict[str, Inst]


def _parse_operands(rest: str) -> List[str]:
    # operand list = %names inside the first balanced (...) chunk
    depth, end = 1, len(rest)
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return re.findall(r"%([\w\.\-]+)", rest[:end])


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR.match(stripped)
                if m:
                    cur = Computation(m.group(1), [], {})
                    if stripped.startswith("ENTRY"):
                        entry_name = m.group(1)
            continue
        if stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, type_str, op, rest = m.groups()
        inst = Inst(name, type_str, op, rest, _parse_operands(rest))
        cur.insts.append(inst)
        cur.by_name[name] = inst
    if entry_name is not None and entry_name in comps:
        comps["__entry__"] = comps[entry_name]
    return comps


def _group_size(rest: str, default: int = 2) -> int:
    m = _GROUP_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP2_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(inst: Inst, comp: Computation) -> float:
    out_elems = _elems_of(inst.type_str)
    m = _CDIM_RE.search(inst.rest)
    contracted = 1
    if m and inst.operands:
        lhs = comp.by_name.get(inst.operands[0])
        if lhs is not None:
            shapes = _shapes_in(lhs.type_str)
            if shapes:
                lshape = shapes[0][1]
                for d in m.group(1).split(","):
                    if d != "" and int(d) < len(lshape):
                        contracted *= lshape[int(d)]
    return 2.0 * out_elems * contracted


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _operand_bytes(inst: Inst, comp: Computation) -> int:
    total = 0
    for op_name in inst.operands:
        ref = comp.by_name.get(op_name)
        if ref is not None:
            total += _bytes_of(ref.type_str)
    return total


def _fusion_bytes(fcomp: Computation) -> int:
    """HBM traffic of one fusion: window-aware parameter reads + output.

    A parameter consumed only by slice-like ops contributes the window size;
    otherwise the full parameter (once). Output = the root's size (in-place
    dynamic-update-slice roots count the update window instead).
    """
    total = 0
    counted = set()
    for inst in fcomp.insts:
        for i, opn in enumerate(inst.operands):
            ref = fcomp.by_name.get(opn)
            if ref is None or ref.op != "parameter" or opn in counted:
                continue
            if inst.op in _WINDOW_READS:
                total += _bytes_of(inst.type_str)
                counted.add(opn)
            elif inst.op == "dynamic-update-slice" and i == 0:
                counted.add(opn)  # in-place target: written region counted via root
            else:
                total += _bytes_of(ref.type_str)
                counted.add(opn)
    if fcomp.insts:
        root = fcomp.insts[-1]
        if root.op == "dynamic-update-slice" and len(root.operands) > 1:
            upd = fcomp.by_name.get(root.operands[1])
            total += 2 * (_bytes_of(upd.type_str) if upd is not None
                          else _bytes_of(root.type_str))
        else:
            total += _bytes_of(root.type_str)
    return total


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, Cost], flops_only: bool = False) -> Cost:
    key = comp.name + ("#f" if flops_only else "")
    if key in memo:
        return memo[key]
    cost = Cost()
    memo[key] = cost  # break cycles defensively
    for inst in comp.insts:
        op = inst.op
        base_kind = op[:-6] if op.endswith("-start") else op
        if base_kind in _COLLECTIVES:
            if op.endswith("-done"):
                continue
            out_b = _bytes_of(inst.type_str)
            g = _group_size(inst.rest)
            if base_kind == "all-reduce":
                moved = 2.0 * out_b * (g - 1) / max(g, 1)
            elif base_kind == "reduce-scatter":
                moved = float(out_b * (g - 1))
            else:
                moved = float(out_b)
            cost.coll_bytes[base_kind] += moved
            cost.coll_counts[base_kind] += 1
            if not flops_only:
                cost.bytes_accessed += out_b + _operand_bytes(inst, comp)
            continue
        if op == "while":
            m = _TRIP_RE.search(inst.rest)
            trips = int(m.group(1)) if m else 1
            called = _CALL_RE.search(inst.rest)
            body_names = re.findall(r"body=%?([\w\.\-]+)", inst.rest)
            for bn in body_names:
                body = comps.get(bn)
                if body is not None:
                    cost.add(_comp_cost(body, comps, memo, flops_only), trips)
            continue
        if op in ("fusion", "call", "async-start"):
            m = _CALL_RE.search(inst.rest)
            inner_comp = comps.get(m.group(1)) if m else None
            if inner_comp is not None:
                inner = _comp_cost(inner_comp, comps, memo, flops_only=True)
                cost.flops += inner.flops
                cost.transcendentals += inner.transcendentals
                for k in _COLLECTIVES:
                    cost.coll_bytes[k] += inner.coll_bytes[k]
                    cost.coll_counts[k] += inner.coll_counts[k]
            if not flops_only:
                if inner_comp is not None and op == "fusion":
                    cost.bytes_accessed += _fusion_bytes(inner_comp)
                else:
                    cost.bytes_accessed += _bytes_of(inst.type_str) + \
                        _operand_bytes(inst, comp)
            continue
        if op == "conditional":
            m = _COND_BRANCHES_RE.search(inst.rest)
            names = re.findall(r"%?([\w\.\-]+)",
                               m.group(1)) if m else []
            names += re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)",
                                inst.rest)
            for bn in names:
                if bn in comps:
                    cost.add(_comp_cost(comps[bn], comps, memo, flops_only), 1.0)
            continue
        if op in ("dot", "convolution"):
            cost.flops += _dot_flops(inst, comp)
            if not flops_only:
                cost.bytes_accessed += _bytes_of(inst.type_str) + \
                    _operand_bytes(inst, comp)
            continue
        if op in _ELEMENTWISE:
            cost.flops += _elems_of(inst.type_str)
        elif op in _TRANSCENDENTAL:
            cost.transcendentals += _elems_of(inst.type_str)
        if not flops_only and op not in _SKIP_BYTES:
            if op in _WINDOW_READS:
                b = 2 * _bytes_of(inst.type_str)
            elif op == "dynamic-update-slice":
                upd = (comp.by_name.get(inst.operands[1])
                       if len(inst.operands) > 1 else None)
                b = 2 * (_bytes_of(upd.type_str) if upd is not None
                         else _bytes_of(inst.type_str))
            else:
                b = _bytes_of(inst.type_str) + _operand_bytes(inst, comp)
            cost.bytes_accessed += b
    memo[key] = cost
    return cost


def analyze(hlo_text: str) -> Cost:
    comps = parse_module(hlo_text)
    entry = comps.get("__entry__")
    if entry is None:
        # fall back: largest computation
        entry = max(comps.values(), key=lambda c: len(c.insts))
    return _comp_cost(entry, comps, {})
