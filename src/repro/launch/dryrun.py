import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production mesh and extract memory / cost / collective stats.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the dry-run needs 512 host-platform placeholder devices
to build the (pod=2, data=16, model=16) mesh. Smoke tests and benchmarks
import repro without this module and see the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_cell, iter_cells
from repro.core import make_optimizer
from repro.launch import hlo_analysis as H
from repro.launch.mesh import HW, make_production_mesh
from repro.models import (cache_logical_axes, count_params, init_cache,
                          init_params, param_logical_axes, param_shapes)
from repro.models.sharding import Rules, tree_shardings
from repro.training import (ServeState, make_decode_step, make_prefill_step,
                            make_train_step)
from repro.training.trainer import TrainState


def _abstract(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(cfg, shape, mesh, rules):
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    tok_shape = ((B, cfg.n_codebooks, S) if cfg.family == "audio" else (B, S))
    tok_axes = (("act_batch", None, "act_seq") if cfg.family == "audio"
                else ("act_batch", "act_seq"))
    if shape.kind == "decode":
        tok_shape = tok_shape[:-1] + (1,)
    specs = {"tokens": jax.ShapeDtypeStruct(tok_shape, i32)}
    sh = {"tokens": rules.sharding(tok_axes, mesh, tok_shape)}
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(tok_shape, i32)
        sh["labels"] = sh["tokens"]
    if cfg.family == "vlm" and shape.kind != "decode":
        im = (B, cfg.n_image_tokens, cfg.d_model)
        specs["image_embeds"] = jax.ShapeDtypeStruct(im, cfg.jdtype)
        sh["image_embeds"] = rules.sharding(("act_batch", None, "act_embed"),
                                            mesh, im)
    return specs, sh


def _param_shardings(cfg, mesh, rules, params_abs):
    return tree_shardings(param_logical_axes(cfg), mesh, rules, params_abs)


def opt_state_shardings(mesh, params_abs, params_sh, opt_abs):
    """Shard optimizer state: leaves structured like params inherit the
    param sharding; everything else (counters, EMA scalars, low-rank
    projections) replicates."""
    rep = NamedSharding(mesh, P())
    p_leaves = jax.tree_util.tree_leaves(params_abs)
    p_sh = jax.tree_util.tree_leaves(params_sh)
    shape_to_sh = {}
    for pl_, ps in zip(p_leaves, p_sh):
        shape_to_sh.setdefault((tuple(pl_.shape), str(pl_.dtype)), ps)
    shape_only = {tuple(pl_.shape): ps for pl_, ps in zip(p_leaves, p_sh)}

    def pick(leaf):
        key = (tuple(leaf.shape), str(leaf.dtype))
        if key in shape_to_sh:
            return shape_to_sh[key]
        if tuple(leaf.shape) in shape_only:
            return shape_only[tuple(leaf.shape)]
        return rep

    return jax.tree_util.tree_map(pick, opt_abs)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               optimizer: str = "scale", accum: str = "auto",
               extra_overrides=()):
    """Lower + compile one cell; return the result record."""
    cfg, shape = get_cell(arch, shape_name)
    cfg.rule_overrides = tuple(cfg.rule_overrides) + tuple(extra_overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = Rules(cfg.rule_overrides)

    t0 = time.time()
    params_abs = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    params_sh = _param_shardings(cfg, mesh, rules, params_abs)
    specs, specs_sh = input_specs(cfg, shape, mesh, rules)

    if shape.kind == "train":
        data_extent = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        # per-arch local microbatch: 4 sequences amortizes per-microbatch
        # FSDP weight gathers and grad reductions 4x vs microbatch=1 (§Perf
        # iteration 5); mistral-large drops to 2 to stay inside HBM.
        local_mb = {"mistral-large-123b": 2}.get(arch, 4)
        if accum == "auto":
            n_accum = max(1, shape.global_batch // (data_extent * local_mb))
        else:
            n_accum = int(accum)
        n_total = count_params(param_shapes(cfg))
        accum_dtype = "bfloat16" if n_total > 150e9 else "float32"
        tx = make_optimizer(optimizer, 1e-3)
        step = make_train_step(cfg, tx, grad_accum=n_accum, rules=rules,
                               accum_dtype=accum_dtype, norm_metrics=False)
        opt_abs = jax.eval_shape(lambda: tx.init(params_abs))
        opt_sh = opt_state_shardings(mesh, params_abs, params_sh, opt_abs)
        rep = NamedSharding(mesh, P())
        state_abs = TrainState(jax.ShapeDtypeStruct((), jnp.int32),
                               params_abs, opt_abs)
        state_sh = TrainState(rep, params_sh, opt_sh)
        with mesh:
            jitted = jax.jit(step, in_shardings=(state_sh, specs_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, specs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, max_seq=shape.seq_len, rules=rules)
        with mesh:
            jitted = jax.jit(step, in_shardings=(params_sh, specs_sh["tokens"])
                             if cfg.family != "vlm" else
                             (params_sh, specs_sh["tokens"],
                              specs_sh["image_embeds"]))
            args = ((params_abs, specs["tokens"]) if cfg.family != "vlm" else
                    (params_abs, specs["tokens"], specs["image_embeds"]))
            lowered = jitted.lower(*args)
        n_accum = 1
    else:  # decode
        B = shape.global_batch
        cache_abs = jax.eval_shape(
            lambda: init_cache(cfg, B, shape.seq_len))
        cache_sh = tree_shardings(cache_logical_axes(cfg), mesh, rules,
                                  cache_abs)
        rep = NamedSharding(mesh, P())
        st_abs = ServeState(cache_abs, jax.ShapeDtypeStruct((), jnp.int32))
        st_sh = ServeState(cache_sh, rep)
        step = make_decode_step(cfg, rules=rules)
        with mesh:
            jitted = jax.jit(step, in_shardings=(params_sh, st_sh,
                                                 specs_sh["tokens"]),
                             out_shardings=(st_sh, None),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, st_abs, specs["tokens"])
        n_accum = 1

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # trip-count-aware recursive analysis of the partitioned module
    # (compiled.cost_analysis() counts while bodies once — useless for
    # scanned-layer models; see hlo_cost.py)
    from repro.launch import hlo_cost as HC
    c = HC.analyze(compiled.as_text())
    cost = {"flops": c.flops, "bytes_accessed": c.bytes_accessed,
            "transcendentals": c.transcendentals}
    coll = H.CollectiveStats(
        {k: int(v) for k, v in c.coll_bytes.items()},
        {k: int(v) for k, v in c.coll_counts.items()})
    xla_cost = H.extract_cost(compiled)  # raw, kept for reference
    mem = H.extract_memory(compiled)
    mf = H.model_flops_for(cfg, shape.kind, shape.seq_len, shape.global_batch)
    roof = H.roofline(cost, coll, model_flops=mf, n_chips=n_chips)
    cost["xla_flops_raw"] = xla_cost["flops"]

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": n_chips, "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "optimizer": optimizer, "grad_accum": n_accum,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "cost": cost, "memory": mem, "roofline": roof,
        "hbm_ok": mem.get("temp_size_in_bytes", 0) +
                  mem.get("argument_size_in_bytes", 0) < HW["hbm_bytes"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--optimizer", default="scale")
    ap.add_argument("--accum", default="auto")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = (list(iter_cells()) if args.all
             else [(args.arch, None, True)])

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape, _ in cells:
        shape_name = args.shape if shape is None else shape.name
        for mp in meshes:
            tag = f"{arch}__{shape_name}__{'multi' if mp else 'single'}"
            out_path = os.path.join(args.out, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip] {tag} (exists)")
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                rec = lower_cell(arch, shape_name, mp,
                                 optimizer=args.optimizer, accum=args.accum)
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"  ok: compile={rec['compile_s']}s "
                      f"bottleneck={r['bottleneck']} "
                      f"compute={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                      f"coll={r['collective_s']:.3e}s "
                      f"useful={r['useful_flop_ratio']:.2f}", flush=True)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
                if args.fail_fast:
                    raise
    if failures:
        print(f"\n{len(failures)} failures:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall cells compiled OK")


if __name__ == "__main__":
    main()
