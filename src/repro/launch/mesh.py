"""Production meshes. Functions only — importing this module never touches
jax device state (required so smoke tests see 1 device while the dry-run
sees 512 host-platform placeholders).

Mesh semantics (TPU v5e pod = 16x16 = 256 chips):
  * ``data``  — FSDP/ZeRO-3 parameter sharding + batch data parallelism.
  * ``model`` — tensor parallelism (TP) + expert parallelism (EP).
  * ``pod``   — pod-level data parallelism (gradient all-reduce crosses DCN);
    multi-pod meshes prepend it.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs).

    ``data`` must divide the device count exactly: the old ``n // data``
    truncation silently dropped devices and could hand back a smaller mesh
    than requested — a mesh bug that surfaces much later as wrong collective
    sizes. ``model`` is still clamped (it is a per-host convenience knob),
    but never below 1 and never beyond what the remaining devices allow.
    """
    n = len(jax.devices())
    if data < 1 or n % data != 0:
        raise ValueError(
            f"make_host_mesh: data={data} must be a positive divisor of the "
            f"device count ({n} device{'s' if n != 1 else ''} available); "
            f"got remainder {n % data if data >= 1 else data}")
    model = max(1, min(model, n // data))
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"))


# TPU v5e hardware constants for the roofline model.
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link (~per chip per direction)
    "hbm_bytes": 16 * 1024**3,   # 16 GiB per chip
}
