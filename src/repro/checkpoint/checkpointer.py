"""Fault-tolerant checkpointing: (optionally zstd-compressed) msgpack shards
with atomic renames, manifest checksums, latest-k retention, and auto-resume.

Layout:  <dir>/step_<N>/shard_<host>.mpk.zst (or .mpk when uncompressed)
+ manifest.json (+ COMMITTED marker written last — a crash mid-save never
yields a readable-but-corrupt checkpoint, and restore_latest skips
uncommitted steps).

``zstandard`` is an optional dependency: saves default to zstd when the
module is importable and fall back to uncompressed shards otherwise; a clear
ImportError is raised only when zstd is explicitly requested (or needed to
read an existing ``.zst`` shard).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: only required for zstd compression
    zstandard = None

PyTree = Any
_SEP = "/"


def _require_zstd(why: str):
    if zstandard is None:
        raise ImportError(
            f"zstd compression requested ({why}) but the optional "
            "'zstandard' package is not installed; pip install zstandard "
            "or save with compression='none'")
    return zstandard


def _flatten(tree: PyTree) -> dict:
    out = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        from repro.core.labels import path_str
        out[path_str(kp)] = np.asarray(leaf)
    return out


def _pack_array(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def save(directory: str, step: int, tree: PyTree, host_id: int = 0,
         n_hosts: int = 1, keep: int = 3, compression: str = "auto") -> str:
    """Atomically save ``tree`` for ``step``. Returns the checkpoint path.

    ``compression``: "auto" (zstd when available, else uncompressed),
    "zstd" (required; clear error when the module is missing), or "none".
    """
    if compression not in ("auto", "zstd", "none"):
        raise ValueError(f"compression must be auto|zstd|none, got {compression!r}")
    use_zstd = (compression == "zstd"
                or (compression == "auto" and zstandard is not None))
    step_dir = os.path.join(directory, f"step_{step:010d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    # a crashed earlier save may have left this host's shard (possibly with
    # a different compression/extension) in the tmp dir; remove only our
    # own stale files — other hosts may be writing their shards to the same
    # tmp dir concurrently
    for name in os.listdir(tmp_dir):
        if name.startswith(f"shard_{host_id:05d}"):
            os.remove(os.path.join(tmp_dir, name))

    flat = _flatten(tree)
    payload = msgpack.packb({k: _pack_array(v) for k, v in flat.items()},
                            use_bin_type=True)
    if use_zstd:
        zstd = _require_zstd("compression='zstd'")
        comp = zstd.ZstdCompressor(level=3).compress(payload)
        shard = os.path.join(tmp_dir, f"shard_{host_id:05d}.mpk.zst")
    else:
        comp = payload
        shard = os.path.join(tmp_dir, f"shard_{host_id:05d}.mpk")
    with open(shard + ".part", "wb") as f:
        f.write(comp)
    os.replace(shard + ".part", shard)

    # the manifest is authoritative for restore, so it must list every
    # host's shard. Merge checksums from (a) hosts that already wrote into
    # this tmp dir and (b) a step dir another host already committed — and
    # adopt (b)'s shard files into our tmp so the rename below doesn't
    # destroy them. Best-effort for shared-filesystem multi-host saves; a
    # true multi-host deployment wants per-host manifests (see ROADMAP).
    checksums = {os.path.basename(shard): zlib.crc32(comp)}
    manifest_path = os.path.join(tmp_dir, "manifest.json")
    # tmp-dir entries (fresher, in-flight) take precedence over a previously
    # committed step's
    for src_dir in (tmp_dir, step_dir):
        src_manifest = os.path.join(src_dir, "manifest.json")
        if not os.path.exists(src_manifest):
            continue
        try:
            with open(src_manifest) as f:
                old = json.load(f).get("checksums", {})
        except (OSError, ValueError):
            continue  # partial write from a crashed save; our entry stands
        for name, crc in old.items():
            # skip this host's entries: stale tmp files were removed above
            # and our fresh shard supersedes any committed one
            if name.startswith(f"shard_{host_id:05d}") or name in checksums:
                continue
            if src_dir is step_dir:
                src_shard = os.path.join(src_dir, name)
                if not os.path.exists(src_shard):
                    continue  # manifest lists a shard that never landed
                # overwrite any same-named tmp file: reaching here means no
                # tmp manifest vouched for it, so it is debris from a
                # crashed save — the committed shard matches this CRC
                shutil.copy2(src_shard, os.path.join(tmp_dir, name))
            checksums[name] = crc
    manifest = {
        "step": step, "n_hosts": n_hosts,
        "compression": "zstd" if use_zstd else "none",
        "checksums": checksums,
        "leaves": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                   for k, v in flat.items()},
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp_dir, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)

    _retain_latest(directory, keep)
    return step_dir


def _retain_latest(directory: str, keep: int) -> None:
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMITTED")):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree, host_id: int = 0) -> PyTree:
    """Restore ``step`` into the structure/dtypes of ``like``."""
    step_dir = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    # the manifest names the shard this save actually wrote (extension
    # depends on compression), so it is authoritative over directory listing
    prefix = f"shard_{host_id:05d}"
    names = [n for n in manifest["checksums"] if n.startswith(prefix)]
    if not names:
        raise IOError(f"no shard for host {host_id} in {step_dir}/manifest.json")
    shard = os.path.join(step_dir, names[0])
    with open(shard, "rb") as f:
        comp = f.read()
    want = zlib.crc32(comp)
    have = manifest["checksums"][names[0]]
    if have != want:
        raise IOError(f"checksum mismatch in {shard}: {have} != {want}")
    if shard.endswith(".zst"):
        payload = _require_zstd(f"reading {shard}").ZstdDecompressor() \
            .decompress(comp)
    else:
        payload = comp
    raw = msgpack.unpackb(payload, raw=False)
    flat = {k: _unpack_array(v) for k, v in raw.items()}

    from repro.core.labels import path_str
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for kp, leaf in leaves_with_path:
        key = path_str(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {np.shape(leaf)}")
        restored.append(np.asarray(arr).astype(np.asarray(leaf).dtype
                                                if hasattr(leaf, "dtype") else arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(directory: str, like: PyTree,
                   host_id: int = 0) -> Optional[Tuple[PyTree, int]]:
    """Auto-resume: (tree, step) of the newest committed checkpoint, or None."""
    step = latest_step(directory)
    if step is None:
        return None
    return restore(directory, step, like, host_id), step


class AsyncSave:
    """Handle for an in-flight asynchronous checkpoint."""

    def __init__(self, thread: threading.Thread):
        self._thread = thread
        self.path: Optional[str] = None
        self.error: Optional[BaseException] = None

    def wait(self, timeout: Optional[float] = None) -> str:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("checkpoint save still in flight")
        if self.error is not None:
            raise self.error
        return self.path

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()


def save_async(directory: str, step: int, tree: PyTree, host_id: int = 0,
               n_hosts: int = 1, keep: int = 3,
               compression: str = "auto") -> AsyncSave:
    """Checkpoint without blocking the training loop.

    Device arrays are snapshotted to host memory synchronously (cheap; the
    training step can immediately donate/overwrite them), then serialization,
    compression and the atomic commit run on a background thread — the
    standard overlap-checkpoint-with-compute pattern.
    """
    snapshot = _flatten(tree)          # device->host copy happens here
    treedef = jax.tree_util.tree_structure(tree)
    del tree

    handle: AsyncSave

    def work():
        try:
            flat_tree = jax.tree_util.tree_unflatten(
                treedef, list(snapshot.values()))
            handle.path = save(directory, step, flat_tree,
                               host_id=host_id, n_hosts=n_hosts, keep=keep,
                               compression=compression)
        except BaseException as e:  # surfaced on wait()
            handle.error = e

    t = threading.Thread(target=work, daemon=True)
    handle = AsyncSave(t)
    t.start()
    return handle
