"""Fault-tolerant checkpointing: (optionally zstd-compressed) msgpack shards
with atomic renames, per-host manifest checksums, a multi-host commit
barrier, latest-k retention, and auto-resume.

Layout:  <dir>/step_<N>/shard_<host>.mpk.zst (or .mpk when uncompressed)
+ manifest.<host>.json per host + a merged manifest.json (+ COMMITTED
marker written last — a crash mid-save never yields a
readable-but-corrupt checkpoint, and restore_latest skips uncommitted
steps).

Multi-host protocol: each host writes its shard and its **own**
``manifest.<host>.json`` (atomic rename) — no host ever rewrites another
host's manifest, which removes the last-manifest-writer-wins race the old
best-effort merge had. Committing is a **barrier**: the step is renamed
into place and marked COMMITTED only once per-host manifests for all
``n_hosts`` are present in the tmp dir, by whichever host observes
completeness first (racing committers are tolerated — the loser verifies
the winner's COMMITTED marker). The merged ``manifest.json`` is derived
from the per-host manifests at commit time (single writer) and kept for
legacy readers.

``zstandard`` is an optional dependency: saves default to zstd when the
module is importable and fall back to uncompressed shards otherwise; a clear
ImportError is raised only when zstd is explicitly requested (or needed to
read an existing ``.zst`` shard).

Resilience (PR 8):

* per-host manifests additionally record **per-leaf checksums**
  (``leaf_checksums``: crc32 of each array's raw bytes), verified on
  ``restore`` — a flipped bit is named down to the leaf, not just the
  shard;
* any corruption-class failure (missing/unreadable shard, shard or leaf
  checksum mismatch, truncated msgpack/zstd payload, malformed manifest)
  raises :class:`CorruptCheckpointError`, and :func:`restore_latest`
  **degrades** across it: the newest *verifiable* committed step wins,
  with a warning naming what was skipped — an unreadable latest
  checkpoint must cost one checkpoint interval, not the run;
* save/commit IO runs under **bounded retry with backoff**
  (``io_retries`` / ``io_backoff``) — transient filesystem errors are
  absorbed, persistent ones still raise;
* :class:`AsyncSave` re-raises worker-thread exceptions from ``wait()``
  *and* ``done`` — a failed background checkpoint can no longer be
  mistaken for a slow one;
* the ``REPRO_FAULTS`` chaos hooks (:mod:`repro.training.faults`) can
  inject IO errors and mid-commit kills at the exact points the atomicity
  argument depends on (no-ops unless the env var is set).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import uuid
import warnings
import zlib
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: only required for zstd compression
    zstandard = None

PyTree = Any
_SEP = "/"


class CorruptCheckpointError(IOError):
    """A committed checkpoint failed verification (checksum / truncation /
    malformed manifest). ``restore_latest`` degrades across these to the
    newest verifiable step; a direct ``restore`` propagates them."""


def _fault_gate(kind: str, site: str) -> None:
    """Chaos hook: inject an IO error or a simulated kill at ``site``.

    A no-op unless ``REPRO_FAULTS`` is set (the env check keeps the
    checkpoint module free of the training-package import on the normal
    path; see :mod:`repro.training.faults` for the spec grammar).
    """
    if not os.environ.get("REPRO_FAULTS"):
        return
    from repro.training import faults
    (faults.io_gate if kind == "io" else faults.kill_gate)(site)


def _retry_io(fn, what: str, retries: int, backoff: float):
    """Run ``fn`` with bounded retry-with-backoff on OSError.

    Only OSError (the transient-filesystem class) is retried — a
    simulated kill is a BaseException and anything else is a bug. The
    final failure propagates with the attempt count in a warning trail.
    """
    for attempt in range(max(retries, 0) + 1):
        try:
            return fn()
        except OSError as e:
            if attempt >= retries:
                raise
            warnings.warn(
                f"checkpoint {what} failed ({e}); retry "
                f"{attempt + 1}/{retries} in {backoff * (2 ** attempt):.2f}s")
            time.sleep(backoff * (2 ** attempt))


def _require_zstd(why: str):
    if zstandard is None:
        raise ImportError(
            f"zstd compression requested ({why}) but the optional "
            "'zstandard' package is not installed; pip install zstandard "
            "or save with compression='none'")
    return zstandard


def _flatten(tree: PyTree) -> dict:
    out = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        from repro.core.labels import path_str
        out[path_str(kp)] = np.asarray(leaf)
    return out


def _pack_array(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def _manifest_name(host_id: int) -> str:
    return f"manifest.{host_id:05d}.json"


def _read_json(path: str):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None  # missing or partial write from a crashed save


def _write_json_atomic(path: str, obj) -> None:
    # unique part name: racing committers both derive the merged manifest
    # (identical content) in one shared tmp dir — a common ".part" would
    # let one writer's rename steal the other's in-flight temp file. The
    # token must be unique *across hosts* (pid/tid collide between
    # machines on a shared filesystem), hence uuid.
    part = f"{path}.part.{uuid.uuid4().hex}"
    with open(part, "w") as f:
        json.dump(obj, f)
    os.replace(part, path)


def _adopt_committed(step_dir: str, tmp_dir: str, host_id: int,
                     n_hosts: int) -> None:
    """Copy already-committed hosts' shards + manifests into the tmp dir.

    Re-saving a committed step must not destroy the other hosts' shards
    when the tmp dir is renamed over the step dir. A host's tmp manifest
    (fresher, in-flight) always wins over its committed one; a tmp shard
    with no vouching tmp manifest is debris from a crashed save and is
    overwritten by the committed copy. Legacy committed dirs (merged
    manifest only) get per-host manifests synthesized from the merged
    checksums.
    """
    for h in range(n_hosts):
        if h == host_id:
            continue  # our fresh shard supersedes any committed one
        if os.path.exists(os.path.join(tmp_dir, _manifest_name(h))):
            continue  # host h is mid-save into this tmp dir: fresher
        man = _read_json(os.path.join(step_dir, _manifest_name(h)))
        if man is None:
            # legacy layout: carve host h's entries out of the merged one
            merged = _read_json(os.path.join(step_dir, "manifest.json"))
            if merged is None:
                continue
            checksums = {n: c for n, c in merged.get("checksums", {}).items()
                         if n.startswith(f"shard_{h:05d}")}
            if not checksums:
                continue
            man = {"step": merged.get("step"), "host": h, "n_hosts": n_hosts,
                   "compression": merged.get("compression", "none"),
                   "checksums": checksums}
        ok = True
        for name in man.get("checksums", {}):
            src = os.path.join(step_dir, name)
            if not os.path.exists(src):
                ok = False  # manifest lists a shard that never landed
                continue
            shutil.copy2(src, os.path.join(tmp_dir, name))
        if ok:
            _write_json_atomic(os.path.join(tmp_dir, _manifest_name(h)), man)


def _commit(directory: str, step: int, tmp_dir: str, step_dir: str,
            keep: int) -> None:
    """Merge per-host manifests, mark COMMITTED, rename into place.

    Tolerates racing committers on the shared tmp dir: if another host
    renamed it away at any point, success is verified via the winner's
    COMMITTED marker instead of propagating the lost race. Crucially the
    rename is attempted *before* any removal of an existing step dir, so a
    losing committer can never delete the step the winner just committed.
    """
    def _won_by_other() -> bool:
        return (not os.path.exists(tmp_dir)
                and os.path.exists(os.path.join(step_dir, "COMMITTED")))

    try:
        _fault_gate("io", "commit")
        checksums, leaves, leaf_sums, compression, n_hosts = \
            {}, {}, {}, "none", 1
        for name in sorted(os.listdir(tmp_dir)):
            if not (name.startswith("manifest.") and name.endswith(".json")
                    and name != "manifest.json"):
                continue
            man = _read_json(os.path.join(tmp_dir, name))
            if man is None:
                continue
            checksums.update(man.get("checksums", {}))
            leaves.update(man.get("leaves", {}))
            leaf_sums.update(man.get("leaf_checksums", {}))
            compression = man.get("compression", compression)
            n_hosts = max(n_hosts, man.get("n_hosts", 1))
        # the merged manifest is written once per committer, from manifests
        # no other host will ever rewrite — identical content, no race
        _write_json_atomic(os.path.join(tmp_dir, "manifest.json"),
                           {"step": step, "n_hosts": n_hosts,
                            "compression": compression,
                            "checksums": checksums, "leaves": leaves,
                            "leaf_checksums": leaf_sums})
        # a kill here — every shard and manifest on disk, COMMITTED not
        # yet written — must leave an uncommitted .tmp dir that
        # restore_latest skips and a later save completes or replaces
        _fault_gate("kill", "commit")
        with open(os.path.join(tmp_dir, "COMMITTED"), "w") as f:
            f.write("ok")
    except OSError:
        if _won_by_other():
            _retain_latest(directory, keep)
            return
        raise
    for attempt in range(100):
        try:
            os.replace(tmp_dir, step_dir)
            break
        except FileNotFoundError:
            if _won_by_other():
                break  # a racing committer renamed our shared tmp dir
            raise
        except OSError:
            # step_dir exists (re-save of a committed step). Remove it and
            # retry; if a racer steals the rename meanwhile the next
            # iteration lands in the FileNotFoundError arm above. Never
            # remove the step after losing the tmp dir — that would delete
            # the winner's commit.
            if _won_by_other():
                break
            if not os.path.exists(tmp_dir):
                raise
            shutil.rmtree(step_dir, ignore_errors=True)
    else:
        raise IOError(f"could not commit {step_dir}: rename kept failing")
    _retain_latest(directory, keep)


def save(directory: str, step: int, tree: PyTree, host_id: int = 0,
         n_hosts: int = 1, keep: int = 3, compression: str = "auto",
         barrier_timeout: float = 0.0, io_retries: int = 3,
         io_backoff: float = 0.05) -> str:
    """Atomically save ``tree`` for ``step``. Returns the checkpoint path.

    ``compression``: "auto" (zstd when available, else uncompressed),
    "zstd" (required; clear error when the module is missing), or "none".

    Multi-host (``n_hosts > 1``, shared filesystem): this host writes its
    shard plus its own ``manifest.<host>.json`` and then hits the commit
    barrier — the step is only renamed into place and marked COMMITTED
    once all hosts' manifests are present, by whichever host sees
    completeness first. ``barrier_timeout`` seconds are spent polling for
    the stragglers; with the default 0 a host that arrives early returns
    immediately (path not yet committed — the last host to arrive commits
    for everyone, which is the fast path for sequential test saves and
    for launchers that already sequence their hosts).

    Shard/manifest writes and the commit run under bounded
    retry-with-backoff (``io_retries`` attempts beyond the first,
    ``io_backoff`` seconds doubling per attempt): transient IO errors are
    absorbed, persistent ones raise after the last attempt.
    """
    if compression not in ("auto", "zstd", "none"):
        raise ValueError(f"compression must be auto|zstd|none, got {compression!r}")
    use_zstd = (compression == "zstd"
                or (compression == "auto" and zstandard is not None))
    step_dir = os.path.join(directory, f"step_{step:010d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    # a crashed earlier save may have left this host's shard (possibly with
    # a different compression/extension) or manifest in the tmp dir; remove
    # only our own stale files — other hosts may be writing theirs to the
    # same tmp dir concurrently
    for name in os.listdir(tmp_dir):
        if (name.startswith(f"shard_{host_id:05d}")
                or name == _manifest_name(host_id)):
            os.remove(os.path.join(tmp_dir, name))

    flat = _flatten(tree)
    payload = msgpack.packb({k: _pack_array(v) for k, v in flat.items()},
                            use_bin_type=True)
    if use_zstd:
        zstd = _require_zstd("compression='zstd'")
        comp = zstd.ZstdCompressor(level=3).compress(payload)
        shard = os.path.join(tmp_dir, f"shard_{host_id:05d}.mpk.zst")
    else:
        comp = payload
        shard = os.path.join(tmp_dir, f"shard_{host_id:05d}.mpk")

    def write_shard():
        _fault_gate("io", "save")
        with open(shard + ".part", "wb") as f:
            f.write(comp)
        os.replace(shard + ".part", shard)

    _retry_io(write_shard, f"shard write ({os.path.basename(shard)})",
              io_retries, io_backoff)
    # a kill here (shard on disk, manifest not) leaves an unvouched shard
    # that the next save overwrites — never a committed step
    _fault_gate("kill", "save")

    # this host's manifest: never touched by any other host (atomic rename
    # makes readers see either nothing or a complete document). Per-leaf
    # crc32s let restore name a corrupted leaf, not just a corrupted shard.
    manifest = {
        "step": step, "host": host_id, "n_hosts": n_hosts,
        "compression": "zstd" if use_zstd else "none",
        "checksums": {os.path.basename(shard): zlib.crc32(comp)},
        "leaf_checksums": {k: zlib.crc32(v.tobytes())
                           for k, v in flat.items()},
        "leaves": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                   for k, v in flat.items()}}
    _retry_io(
        lambda: _write_json_atomic(
            os.path.join(tmp_dir, _manifest_name(host_id)), manifest),
        "manifest write", io_retries, io_backoff)
    if os.path.exists(step_dir):
        _adopt_committed(step_dir, tmp_dir, host_id, n_hosts)

    # commit barrier: rename + COMMITTED only when every host's manifest
    # is present; the observer of completeness commits for everyone
    deadline = time.monotonic() + max(barrier_timeout, 0.0)
    while True:
        present = all(
            os.path.exists(os.path.join(tmp_dir, _manifest_name(h)))
            for h in range(n_hosts))
        if present:
            _retry_io(
                lambda: _commit(directory, step, tmp_dir, step_dir, keep),
                "commit", io_retries, io_backoff)
            break
        if os.path.exists(os.path.join(step_dir, "COMMITTED")):
            break  # another host committed while we polled
        if time.monotonic() >= deadline:
            break  # a later host completes the barrier and commits
        time.sleep(0.05)
    return step_dir


def _retain_latest(directory: str, keep: int) -> None:
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMITTED")):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def _read_verified(step_dir: str, host_id: int) -> dict:
    """Read + verify this host's shard -> {leaf key: np.ndarray}.

    Every corruption-class failure — missing/malformed manifest, missing
    shard, shard or per-leaf checksum mismatch, truncated payload — raises
    :class:`CorruptCheckpointError` (an IOError), so ``restore_latest``
    can degrade across it uniformly. A missing zstandard module stays an
    ImportError: that is an environment problem, not a bad checkpoint.
    """
    # per-host manifests are authoritative (no cross-host writer existed);
    # fall back to the merged manifest for checkpoints from older saves
    manifest = _read_json(os.path.join(step_dir, _manifest_name(host_id)))
    if manifest is None:
        manifest = _read_json(os.path.join(step_dir, "manifest.json"))
    if manifest is None or "checksums" not in manifest:
        raise CorruptCheckpointError(
            f"no readable manifest for host {host_id} in {step_dir}")
    # the manifest names the shard this save actually wrote (extension
    # depends on compression), so it is authoritative over directory listing
    prefix = f"shard_{host_id:05d}"
    names = [n for n in manifest["checksums"] if n.startswith(prefix)]
    if not names:
        raise CorruptCheckpointError(
            f"no shard for host {host_id} in {step_dir} manifests")
    shard = os.path.join(step_dir, names[0])
    try:
        with open(shard, "rb") as f:
            comp = f.read()
    except OSError as e:
        raise CorruptCheckpointError(
            f"shard {shard} unreadable: {e}") from e
    want = zlib.crc32(comp)
    have = manifest["checksums"][names[0]]
    if have != want:
        raise CorruptCheckpointError(
            f"checksum mismatch in {shard}: {have} != {want}")
    try:
        if shard.endswith(".zst"):
            payload = _require_zstd(f"reading {shard}").ZstdDecompressor() \
                .decompress(comp)
        else:
            payload = comp
        raw = msgpack.unpackb(payload, raw=False)
        flat = {k: _unpack_array(v) for k, v in raw.items()}
    except ImportError:
        raise  # missing optional dep, not corruption
    except Exception as e:  # truncated/garbled payload classes vary by lib
        raise CorruptCheckpointError(
            f"shard {shard} failed to decode: {e}") from e
    # per-leaf verification (manifests from before PR 8 lack the field)
    for key, crc in manifest.get("leaf_checksums", {}).items():
        if key not in flat:
            raise CorruptCheckpointError(
                f"shard {shard} is missing leaf {key!r} named by its "
                "manifest")
        got = zlib.crc32(flat[key].tobytes())
        if got != crc:
            raise CorruptCheckpointError(
                f"leaf checksum mismatch for {key!r} in {shard}: "
                f"{crc} != {got}")
    return flat


def restore(directory: str, step: int, like: PyTree, host_id: int = 0) -> PyTree:
    """Restore ``step`` into the structure/dtypes of ``like``.

    A tied/untied mismatch is a hard, named error: restoring a
    ``tie_embeddings=True`` model (no ``lm_head`` leaves) from an untied
    checkpoint — or the reverse — raises a ValueError that says which
    ``lm_head`` entries are extra/missing and why, instead of a bare
    missing-leaf failure. Corruption raises
    :class:`CorruptCheckpointError` (shard and per-leaf checksums are
    verified against the per-host manifest).
    """
    step_dir = os.path.join(directory, f"step_{step:010d}")
    flat = _read_verified(step_dir, host_id)

    from repro.core.labels import path_str
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    want_keys = [path_str(kp) for kp, _ in leaves_with_path]
    missing = [k for k in want_keys if k not in flat]
    if missing:
        head_missing = [k for k in missing if "lm_head" in k]
        if head_missing:
            raise ValueError(
                f"checkpoint {step_dir} has no {head_missing} leaves: it "
                "was saved from a tie_embeddings=True model (the head is "
                "the tied tok_embed). Restore into a tied model "
                "(tie_embeddings=True), or re-export with an explicit "
                "lm_head.")
        raise ValueError(
            f"checkpoint {step_dir} is missing leaves {missing} required "
            "by the target tree")
    extra_head = [k for k in flat if "lm_head" in k and k not in want_keys]
    if extra_head and not any("lm_head" in k for k in want_keys):
        raise ValueError(
            f"checkpoint {step_dir} contains {extra_head} but the target "
            "tree has no lm_head: the checkpoint was saved from an untied "
            "model and cannot restore into a tie_embeddings=True model "
            "(the tied head would silently ignore the trained lm_head). "
            "Restore into an untied model, or fold lm_head into tok_embed "
            "explicitly.")
    restored = []
    for kp, leaf in leaves_with_path:
        arr = flat[path_str(kp)]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {path_str(kp)}: ckpt {arr.shape} vs "
                f"model {np.shape(leaf)}")
        restored.append(np.asarray(arr).astype(np.asarray(leaf).dtype
                                                if hasattr(leaf, "dtype") else arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(directory: str, like: PyTree,
                   host_id: int = 0) -> Optional[Tuple[PyTree, int]]:
    """Auto-resume: (tree, step) of the newest **verifiable** committed
    checkpoint, or None.

    Uncommitted step dirs never enter the candidate list (no COMMITTED
    marker). A committed-but-unusable candidate — corrupted shard, failed
    shard/leaf checksum, missing shard for this host, unreadable manifest
    — is skipped with a warning and the next-newest committed step is
    tried: an unreadable latest checkpoint costs one checkpoint interval,
    not the run. Structural mismatches against ``like`` (tied/untied,
    missing leaves, shape changes) still raise: they would fail
    identically at every step, so degrading across them only hides a
    caller bug.
    """
    for step in sorted(_list_steps(directory), reverse=True):
        try:
            return restore(directory, step, like, host_id), step
        except (CorruptCheckpointError, OSError) as e:
            warnings.warn(
                f"checkpoint step {step} in {directory} failed "
                f"verification ({e}); falling back to the previous "
                "committed step")
    return None


class AsyncSave:
    """Handle for an in-flight asynchronous checkpoint.

    Worker-thread exceptions are captured and **re-raised** from both
    ``wait()`` and the ``done`` property — a failed background save must
    surface at the next touch of the handle, never be mistaken for a save
    that is merely still in flight (the old failure mode: the error sat
    silently in ``self.error`` until a caller happened to ``wait()``,
    while ``done`` reported a clean True).
    """

    def __init__(self, thread: threading.Thread):
        self._thread = thread
        self.path: Optional[str] = None
        self.error: Optional[BaseException] = None

    def wait(self, timeout: Optional[float] = None) -> str:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("checkpoint save still in flight")
        if self.error is not None:
            raise self.error
        return self.path

    @property
    def done(self) -> bool:
        """True once the save finished **successfully**; raises the
        worker's exception if it failed (False while still in flight)."""
        if self._thread.is_alive():
            return False
        if self.error is not None:
            raise self.error
        return True


def save_async(directory: str, step: int, tree: PyTree, host_id: int = 0,
               n_hosts: int = 1, keep: int = 3, compression: str = "auto",
               barrier_timeout: float = 0.0, io_retries: int = 3,
               io_backoff: float = 0.05) -> AsyncSave:
    """Checkpoint without blocking the training loop.

    Device arrays are snapshotted to host memory synchronously (cheap; the
    training step can immediately donate/overwrite them), then serialization,
    compression and the atomic commit run on a background thread — the
    standard overlap-checkpoint-with-compute pattern.
    """
    snapshot = _flatten(tree)          # device->host copy happens here
    treedef = jax.tree_util.tree_structure(tree)
    del tree

    handle: AsyncSave

    def work():
        try:
            flat_tree = jax.tree_util.tree_unflatten(
                treedef, list(snapshot.values()))
            handle.path = save(directory, step, flat_tree,
                               host_id=host_id, n_hosts=n_hosts, keep=keep,
                               compression=compression,
                               barrier_timeout=barrier_timeout,
                               io_retries=io_retries, io_backoff=io_backoff)
        except BaseException as e:  # surfaced on wait()
            handle.error = e

    t = threading.Thread(target=work, daemon=True)
    handle = AsyncSave(t)
    t.start()
    return handle
