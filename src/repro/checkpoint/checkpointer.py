"""Fault-tolerant checkpointing: zstd-compressed msgpack shards with atomic
renames, manifest checksums, latest-k retention, and auto-resume.

Layout:  <dir>/step_<N>/shard_<host>.mpk.zst + manifest.json (+ COMMITTED
marker written last — a crash mid-save never yields a readable-but-corrupt
checkpoint, and restore_latest skips uncommitted steps).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional, Tuple

import jax
import msgpack
import numpy as np
import zstandard

PyTree = Any
_SEP = "/"


def _flatten(tree: PyTree) -> dict:
    out = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        from repro.core.labels import path_str
        out[path_str(kp)] = np.asarray(leaf)
    return out


def _pack_array(a: np.ndarray) -> dict:
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "data": a.tobytes()}


def _unpack_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=d["dtype"]).reshape(d["shape"])


def save(directory: str, step: int, tree: PyTree, host_id: int = 0,
         n_hosts: int = 1, keep: int = 3) -> str:
    """Atomically save ``tree`` for ``step``. Returns the checkpoint path."""
    step_dir = os.path.join(directory, f"step_{step:010d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    flat = _flatten(tree)
    payload = msgpack.packb({k: _pack_array(v) for k, v in flat.items()},
                            use_bin_type=True)
    comp = zstandard.ZstdCompressor(level=3).compress(payload)
    shard = os.path.join(tmp_dir, f"shard_{host_id:05d}.mpk.zst")
    with open(shard + ".part", "wb") as f:
        f.write(comp)
    os.replace(shard + ".part", shard)

    manifest = {
        "step": step, "n_hosts": n_hosts,
        "checksums": {os.path.basename(shard): zlib.crc32(comp)},
        "leaves": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                   for k, v in flat.items()},
    }
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp_dir, "COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(step_dir):
        shutil.rmtree(step_dir)
    os.replace(tmp_dir, step_dir)

    _retain_latest(directory, keep)
    return step_dir


def _retain_latest(directory: str, keep: int) -> None:
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "COMMITTED")):
                out.append(int(name[5:]))
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, step: int, like: PyTree, host_id: int = 0) -> PyTree:
    """Restore ``step`` into the structure/dtypes of ``like``."""
    step_dir = os.path.join(directory, f"step_{step:010d}")
    shard = os.path.join(step_dir, f"shard_{host_id:05d}.mpk.zst")
    with open(shard, "rb") as f:
        comp = f.read()
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    want = zlib.crc32(comp)
    have = manifest["checksums"].get(os.path.basename(shard))
    if have != want:
        raise IOError(f"checksum mismatch in {shard}: {have} != {want}")
    raw = msgpack.unpackb(zstandard.ZstdDecompressor().decompress(comp),
                          raw=False)
    flat = {k: _unpack_array(v) for k, v in raw.items()}

    from repro.core.labels import path_str
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for kp, leaf in leaves_with_path:
        key = path_str(kp)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {np.shape(leaf)}")
        restored.append(np.asarray(arr).astype(np.asarray(leaf).dtype
                                                if hasattr(leaf, "dtype") else arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, restored)


def restore_latest(directory: str, like: PyTree,
                   host_id: int = 0) -> Optional[Tuple[PyTree, int]]:
    """Auto-resume: (tree, step) of the newest committed checkpoint, or None."""
    step = latest_step(directory)
    if step is None:
        return None
    return restore(directory, step, like, host_id), step


class AsyncSave:
    """Handle for an in-flight asynchronous checkpoint."""

    def __init__(self, thread: threading.Thread):
        self._thread = thread
        self.path: Optional[str] = None
        self.error: Optional[BaseException] = None

    def wait(self, timeout: Optional[float] = None) -> str:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("checkpoint save still in flight")
        if self.error is not None:
            raise self.error
        return self.path

    @property
    def done(self) -> bool:
        return not self._thread.is_alive()


def save_async(directory: str, step: int, tree: PyTree, host_id: int = 0,
               n_hosts: int = 1, keep: int = 3) -> AsyncSave:
    """Checkpoint without blocking the training loop.

    Device arrays are snapshotted to host memory synchronously (cheap; the
    training step can immediately donate/overwrite them), then serialization,
    compression and the atomic commit run on a background thread — the
    standard overlap-checkpoint-with-compute pattern.
    """
    snapshot = _flatten(tree)          # device->host copy happens here
    treedef = jax.tree_util.tree_structure(tree)
    del tree

    handle: AsyncSave

    def work():
        try:
            flat_tree = jax.tree_util.tree_unflatten(
                treedef, list(snapshot.values()))
            handle.path = save(directory, step, flat_tree,
                               host_id=host_id, n_hosts=n_hosts, keep=keep)
        except BaseException as e:  # surfaced on wait()
            handle.error = e

    t = threading.Thread(target=work, daemon=True)
    handle = AsyncSave(t)
    t.start()
    return handle
