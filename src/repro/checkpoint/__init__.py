from .checkpointer import (AsyncSave, latest_step, restore, restore_latest,
                           save, save_async)
__all__ = ["AsyncSave", "latest_step", "restore", "restore_latest", "save",
           "save_async"]
