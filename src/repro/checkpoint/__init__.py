from .checkpointer import (AsyncSave, CorruptCheckpointError, latest_step,
                           restore, restore_latest, save, save_async)
__all__ = ["AsyncSave", "CorruptCheckpointError", "latest_step", "restore",
           "restore_latest", "save", "save_async"]
