"""repro — production-grade JAX framework reproducing SCALE
("Memory-Efficient LLM Pretraining via Minimalist Optimizer Design").

Subpackages:
  core       — SCALE + baseline optimizers, memory accounting
  models     — transformer / SSM / hybrid model zoo with sharding annotations
  data       — deterministic shard-aware token pipeline
  training   — train/serve step factories, grad accumulation, remat
  checkpoint — sharded zstd checkpoints with auto-resume
  launch     — production meshes, multi-pod dry-run, roofline analysis
  configs    — assigned architecture configs (``--arch <id>``)
  kernels    — Pallas TPU kernels for the optimizer hot path
"""
__version__ = "1.0.0"
