"""Backend-aware dispatch for the fused optimizer-update kernels.

This is the single place that decides, per (op, shape, norm kind), whether a
SCALE update runs through the Pallas kernels and in which mode:

  * on TPU the kernels run **compiled** (the real fused, 3-HBM-pass path);
  * on CPU/GPU they run in **interpret** mode, which executes the same
    kernel bodies through the Pallas interpreter — a slow but exact oracle
    that keeps parity tests meaningful on any machine. The interpreter is
    a correctness tool, not a performance path: for actual off-TPU
    *training* with ``impl="fused"``, set ``REPRO_FUSED=off`` to take the
    compiled-XLA jnp path (the benchmarks do this automatically);
  * shapes/kinds outside the coverage matrix fall back to the jnp reference.

Coverage matrix (``supported``): ndim in {2, 3} x kind in {col, row, larger}
x any dtype (math is f32 internally) x arbitrary shapes (remainder tiles are
masked inside the kernels). ``larger`` resolves to col/row per shape at trace
time. sign/ns/svd norms and >3-D params are not fused.

The ``REPRO_FUSED`` environment variable overrides the mode: ``auto``
(default), ``interpret``, ``compiled``, or ``off`` (always use the jnp
reference — an escape hatch if a backend miscompiles). It is read at trace
time and jit caches are not keyed on it, so set it before the first
training step; changing it mid-process does not retrace already-compiled
shapes.

Entry points (all jitted, scalar lr/beta may be traced schedule outputs).
HBM passes count every full-matrix read/write, jnp-path counts in
parentheses; the per-slice norm vector is negligible (see the accounting
note in :mod:`repro.kernels.colnorm.colnorm`):

  ========================  =======================================  ======
  op                        computes                                 passes
  ========================  =======================================  ======
  ``normalize``             g / (||slice|| + eps)                    3  (4)
  ``norm_update``           theta - lr * normalize(g)                4  (6)
  ``momentum_norm``         m' = EMA(m, g); (m', normalize(m'))      5  (6)
  ``momentum_norm_update``  m' = EMA(m, g); theta - lr*normalize(m') 6  (9)
  ========================  =======================================  ======
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .colnorm import colnorm as _ck
from .colnorm import ref as _cref
from .colnorm.colnorm import _canon3 as _c3
from .scale_head import ref as _href
from .scale_head import scale_head as _hk

FUSED_KINDS = ("col", "row", "larger")
FUSED_NDIMS = (2, 3)


def _mode() -> str:
    m = os.environ.get("REPRO_FUSED", "auto")
    if m not in ("auto", "interpret", "compiled", "off"):
        raise ValueError(f"REPRO_FUSED must be auto|interpret|compiled|off, got {m!r}")
    return m


def backend() -> str:
    return jax.devices()[0].platform


def use_interpret() -> bool:
    """Compiled on TPU, interpret oracle elsewhere (unless overridden)."""
    mode = _mode()
    if mode == "interpret":
        return True
    if mode == "compiled":
        return False
    return backend() != "tpu"


def resolve_kind(kind: str, shape) -> str:
    """Resolve ``larger`` to col/row by shape (Table 13 row 4; static).

    Delegates to :func:`repro.core.normalization.resolve_larger` so the
    jnp impl and the kernel dispatch share one tie-break for square shapes.
    """
    from repro.core.normalization import resolve_larger
    return resolve_larger(kind, shape)


def _ref_norm(g: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    """jnp fallback for any norm kind (col/row honor eps; others delegate
    to repro.core.normalization, whose kinds have no eps knob)."""
    kind = resolve_kind(kind, g.shape)
    if kind in ("col", "row"):
        return _cref.normalize(g, kind, eps)
    from repro.core.normalization import normalize as _core_normalize
    return _core_normalize(g, kind)


def supported(shape, kind: str) -> bool:
    """True when (shape, kind) is covered by the fused kernels."""
    if _mode() == "off":
        return False
    if len(shape) not in FUSED_NDIMS or kind not in FUSED_KINDS:
        return False
    return all(d >= 1 for d in shape)


@functools.partial(jax.jit, static_argnames=("kind", "eps"))
def normalize(g: jnp.ndarray, kind: str = "col",
              eps: float = 1e-8) -> jnp.ndarray:
    """Fused g / (||slice||+eps); falls back to the jnp oracle off-matrix."""
    if not supported(g.shape, kind):
        return _ref_norm(g, kind, eps)
    axis = resolve_kind(kind, g.shape)
    interp = use_interpret()
    g3 = _c3(g)
    ss = _ck.norm_sumsq(g3, axis, interpret=interp)
    return _ck.norm_apply(g3, ss, axis, eps=eps,
                          interpret=interp).reshape(g.shape)


@functools.partial(jax.jit, static_argnames=("kind", "eps"))
def norm_update(theta: jnp.ndarray, g: jnp.ndarray, lr, kind: str = "col",
                eps: float = 1e-8) -> jnp.ndarray:
    """Fused theta - lr*normalize(g); 3-pass apply stage (th r, g r, th w)."""
    if not supported(theta.shape, kind):
        return (theta.astype(jnp.float32)
                - jnp.asarray(lr, jnp.float32)
                * _ref_norm(g, kind, eps).astype(jnp.float32)
                ).astype(theta.dtype)
    axis = resolve_kind(kind, theta.shape)
    interp = use_interpret()
    t3, g3 = _c3(theta), _c3(g)
    ss = _ck.norm_sumsq(g3, axis, interpret=interp)
    return _ck.update_apply(t3, g3, ss, lr, axis, eps=eps,
                            interpret=interp).reshape(theta.shape)


@functools.partial(jax.jit, static_argnames=("kind", "eps"))
def momentum_norm(m: jnp.ndarray, g: jnp.ndarray, beta, kind: str = "col",
                  eps: float = 1e-8):
    """(m', normalize(m')) with the EMA and sumsq fused into one kernel."""
    if not supported(m.shape, kind):
        m_new = (jnp.asarray(beta, jnp.float32) * m.astype(jnp.float32)
                 + (1.0 - jnp.asarray(beta, jnp.float32))
                 * g.astype(jnp.float32))
        return m_new, _ref_norm(m_new, kind, eps)
    axis = resolve_kind(kind, m.shape)
    interp = use_interpret()
    m3, g3 = _c3(m), _c3(g)
    m_new, ss = _hk.momentum_sumsq(m3, g3, beta, axis, interpret=interp)
    d = _ck.norm_apply(m_new, ss, axis, eps=eps, interpret=interp)
    return m_new.reshape(m.shape), d.reshape(m.shape)


@functools.partial(jax.jit, static_argnames=("kind", "eps"))
def momentum_norm_update(theta: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray,
                         beta, lr, kind: str = "col", eps: float = 1e-8):
    """Fully fused stateful step: (theta', m') in two kernel launches."""
    if not supported(theta.shape, kind):
        m_new, d = momentum_norm(m, g, beta, kind, eps)
        theta_new = (theta.astype(jnp.float32)
                     - jnp.asarray(lr, jnp.float32) * d.astype(jnp.float32)
                     ).astype(theta.dtype)
        return theta_new, m_new
    axis = resolve_kind(kind, theta.shape)
    interp = use_interpret()
    t3, m3, g3 = _c3(theta), _c3(m), _c3(g)
    m_new, ss = _hk.momentum_sumsq(m3, g3, beta, axis, interpret=interp)
    theta_new = _hk.head_update_apply(t3, m_new, ss, lr, axis, eps=eps,
                                      interpret=interp)
    return theta_new.reshape(theta.shape), m_new.reshape(m.shape)


# Introspection: op name -> (fused entry point, jnp reference). Tests iterate
# this to keep the parity matrix and the dispatch table in sync.
REGISTRY = {
    "normalize": (normalize, _cref.normalize),
    "norm_update": (norm_update, _cref.norm_update),
    "momentum_norm": (momentum_norm, _href.momentum_norm),
    "momentum_norm_update": (momentum_norm_update, _href.momentum_norm_update),
}
