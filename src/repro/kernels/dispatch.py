"""Backend- and mesh-aware dispatch for the fused optimizer-update kernels.

This is the single place that decides, per (op, shape, norm kind, sharding),
whether a SCALE update runs through the Pallas kernels and in which mode:

  * on TPU the kernels run **compiled** (the real fused, 3-HBM-pass path);
  * on CPU/GPU they run in **interpret** mode, which executes the same
    kernel bodies through the Pallas interpreter — a slow but exact oracle
    that keeps parity tests meaningful on any machine. The interpreter is
    a correctness tool, not a performance path: for actual off-TPU
    *training* with ``impl="fused"``, set ``REPRO_FUSED=off`` to take the
    compiled-XLA jnp path (the benchmarks do this automatically);
  * shapes/kinds outside the coverage matrix fall back to the jnp reference.

Coverage matrix (``supported`` / ``xent_supported``):

  ==================  =====================================================
  op family           covered
  ==================  =====================================================
  optimizer updates   ndim in {2, 3} x kind in {col, row, larger} x any
                      dtype (math is f32 internally) x arbitrary shapes
                      (remainder tiles are masked inside the kernels).
                      ``larger`` resolves to col/row per shape at trace
                      time. sign/ns/svd norms and >3-D params are not
                      fused.
  xent (LM head)      h (N, D) or (B, S, D) x w (D, V) x any dtype x
                      arbitrary shapes (padded vocab and remainder tiles
                      masked via the tile iota) x masked (-1) labels. One
                      head at a time — the audio multi-codebook head
                      dispatches per codebook (its 4-D (B, C, S, D) case
                      never reaches dispatch directly).
  xent, weighted      optional per-token ``weights`` (labels.shape, f32):
                      zero-weight tokens demote to label -1 before the
                      kernel (no gradient work), fractional weights scale
                      loss and grads linearly. Composes *outside* the
                      custom_vjp, so fused and reference routes stay
                      weight-oblivious and weighting never changes the
                      route. Used by packed-document batches, where the
                      per-token weight doubles as the loss mask.
  xent, transposed w  ``transposed=True``: w is a **tied embedding** in
                      (V, D) storage — blocks index ``w[vocab_tile, d]``,
                      dW is emitted in (V, D) so the gradient lands on the
                      embedding, and the shard plan reads the vocab axes
                      off w's dim 0 (dim 1 — FSDP embed — is gathered).
                      Same shape/dtype/masking coverage as the (D, V) row.
  flash attention     q (B, S, H, hd) x k (B, T, K, hd) x v (B, T, K, hdv)
                      with H % K == 0 — native GQA: kv blocks are indexed
                      by ``q_head // group``, the H/K repeat is never
                      materialized (dK/dV reduce the group in VMEM and
                      land in the (B, T, K, *) storage layout). Causal is
                      rectangular (T >= S, query i sees keys <= T-S+i) or
                      off (cross-attention); a traced ``kv_len`` bounds
                      the key positions (decode over a partially filled
                      cache — tiles past the fill, like tiles above the
                      causal diagonal, skip their compute; kv_len is
                      non-causal only, the combination raises). Any dtype
                      (softmax statistics in f32), arbitrary/ragged S and
                      T (remainder tiles masked via the tile iota).
                      Uncovered: v whose (B, T, K) disagrees with k, and
                      causal T < S.
  attn, segment mask  packed-document masking: a ((B, S), (B, T)) int32
                      ``segments`` pair (one :class:`MaskSpec` clause —
                      see :mod:`repro.kernels.attention.mask`) restricts
                      every query to keys of its own document; pad id 0
                      is its own island. Tile pairs whose segment-id
                      ranges cannot overlap skip their compute like
                      above-diagonal causal tiles. The shard plan carries
                      the id arrays batch-sharded alongside q/kv; ids get
                      float0 cotangents (index data, like kv_len and
                      xent's labels). Mutually exclusive with ``kv_len``
                      (packing is a train-time format).
  ==================  =====================================================

Per-optimizer lowering (registry names, via ``core/pipeline.build_pipeline``
with ``impl="fused"``): a pipeline stage composition lowers to these kernels
iff it is a bare {col,row,larger}-norm, optionally with a plain momentum EMA
(no nesterov, no projection, no standardize, no Adam on that leaf). All
registry optimizers still provide ``update_params`` via the pipeline's jnp
write path (bitwise-equal to update+apply) even when never fused.

.. lowering-table-begin
(generated from core.api.OPTIMIZER_REGISTRY — edit the specs'
``lowering`` text and run ``python -m repro.analysis --fix``)

  ==================  =====  ==================================================
  registry optimizer  fused  lowering
  ==================  =====  ==================================================
  scale               yes    stateless matrices -> normalize / norm_update;
                             momentum groups (LM head) -> momentum_norm /
                             momentum_norm_update; Adam vectors stay jnp
  scale_fused         yes    as scale, built with impl="fused" by default
  sgd                 no     never fused: plain SGD has no norm stage; jnp
                             write path only
  sgd_momentum        no     never fused: a bare momentum EMA without a col/row
                             norm has no kernel composition
  adam                no     never fused: Adam moments have no kernel
                             composition; jnp write path only
  adamw               no     as adam (decoupled weight decay folds into the
                             Adam stage)
  adams               no     never fused: the synthesized AdamS denominator
                             (sqrt(b2*m^2 + (1-b2)*g^2)) has no kernel
                             composition; jnp write path only
  adapm               yes    as scale with momentum on the embedding and the LM
                             head (partial momentum); hidden matrices stay
                             stateless normalize / norm_update
  stable_spam         no     never fused: AdaClip/AdaGN run as the tree-level
                             pre hook; the Adam stage stays jnp
  muon                no     never fused: nesterov EMA + Newton-Schulz
                             orthogonalization sit outside kernel coverage
  swan                no     never fused: standardize (GradNorm) precedes the
                             norm stage
  galore              no     never fused: the low-rank projection stage has no
                             kernel composition
  fira                no     as galore (adds the full-rank residual)
  apollo              no     as galore (random projector, channel-wise scaling)
  apollo_mini         no     as apollo (rank-1 projector, tensor-wise scaling)
  sgd_colnorm         yes    all matrix groups -> normalize / norm_update when
                             built with impl="fused"; vectors stay jnp
  sgd_rownorm         yes    as sgd_colnorm with the row kind
  sgd_signnorm        no     never fused: sign norm is outside kernel coverage
  sgd_nsnorm          no     never fused: Newton-Schulz norm is outside kernel
                             coverage
  sgd_svdnorm         no     never fused: SVD norm is outside kernel coverage
  ==================  =====  ==================================================
.. lowering-table-end

Sharded dispatch (pjit meshes)
------------------------------
A bare ``pallas_call`` has no SPMD partitioning rule: under a ``("data",
"model")`` mesh the kernel would see only its local shard and compute the
per-column sums-of-squares over a *fraction* of the rows — silently
normalizing by the wrong norm. Entry points therefore accept the array's
``NamedSharding`` (derived by the trainer from ``models/sharding.Rules``)
and, when any dim is actually sharded, wrap the kernels in ``shard_map``:

  * every kernel runs on its **local shard** (per-shard HBM passes only);
  * the sum-of-squares reduction emits a **partial** per-slice result which
    is ``lax.psum``-ed over exactly the mesh axes that shard the *reduce*
    dim — for ``col`` norms the axes sharding the row dim (``d_in``, e.g.
    the FSDP ``"data"`` axis under the default rules), for ``row`` norms
    the axes sharding the column dim (``d_out``, e.g. ``"model"``). The
    psum moves one per-slice vector (~1/256 of a matrix) over ICI, not the
    matrix itself;
  * the apply stage then consumes the now-global norms shard-locally.

Shardings whose reduce/batch dims do not divide the mesh axes (shard_map
requires exact divisibility) and non-NamedSharding layouts fall back to the
jnp reference, which GSPMD partitions correctly on its own. A replicated
NamedSharding (no mesh axes mapped) takes the ordinary single-device path.

The ``REPRO_FUSED`` environment variable overrides the mode: ``auto``
(default), ``interpret``, ``compiled``, or ``off`` (always use the jnp
reference — an escape hatch if a backend miscompiles). It is re-read on
every entry-point call and threaded through as a **static argument**, so it
participates in the jit cache key: flipping it mid-process takes effect on
the next call instead of serving stale compilations. (Inside an outer
``jax.jit`` — e.g. a jitted train step — the read still happens at the
outer trace time; the outer cache is not keyed on it.)

Every kernel route additionally runs under a **failure guard**: a kernel
path that raises (a backend that cannot lower the Pallas call, a driver
regression, or a ``REPRO_FAULTS`` ``dispatch_fail`` injection) degrades to
the jnp reference instead of killing the run — warned once per (op,
exception type), counted per op in :func:`fallback_counts` so the training
driver can surface degradations in its step logs. Kernel failures surface
at trace/lower time (host-side), which is exactly where the guard sits.

Entry points (scalar lr/beta/gscale may be traced schedule outputs). All
accept ``gscale`` — a scalar multiplied into the gradient at read time
inside the kernels, used by the trainer to fold the global-norm clip factor
into the fused step without a separate full grad read+write. HBM passes
count every full-matrix read/write, jnp-path counts in parentheses; the
per-slice norm vector is negligible (see the accounting note in
:mod:`repro.kernels.colnorm.colnorm`):

  ========================  =======================================  ======
  op                        computes                                 passes
  ========================  =======================================  ======
  ``normalize``             g / (||slice|| + eps)                    3  (4)
  ``norm_update``           theta - lr * normalize(g)                4  (6)
  ``momentum_norm``         m' = EMA(m, g); (m', normalize(m'))      5  (6)
  ``momentum_norm_update``  m' = EMA(m, g); theta - lr*normalize(m') 6  (9)
  ========================  =======================================  ======

Under a mesh the same counts hold *per shard* (each device streams only its
1/N of every matrix). The theta writes in ``norm_update`` and
``momentum_norm_update`` alias theta to the output, so with buffer donation
(``donate_argnums`` on the train step) the apply stage allocates no fresh
theta.

Fused cross-entropy (``xent_loss``)
-----------------------------------
The LM-head loss is registered through the same machinery: ``xent_loss``
is a ``custom_vjp`` whose forward/backward run the blockwise Pallas
kernels in :mod:`repro.kernels.xent` (logits never materialize beyond a
(token-tile, vocab-tile) VMEM block). Routing mirrors the update ops —
compiled on TPU, interpret oracle elsewhere, ``REPRO_FUSED=off`` or an
uncovered shape/sharding routes to the reference (callers that must stay
memory-safe check ``xent_route`` first: the in-dispatch fallback is the
*full-logit* test-scale oracle, while ``models.model.lm_loss`` keeps the
chunked scan as the production jnp path). Sharded dispatch takes the
hidden/head ``NamedSharding`` pair: tokens shard over the axes sharding
h's leading (batch) dim, the vocab dim over w's column axes — each shard
runs the kernels on its local (N/k, D) x (D, V/m) problem with a global
column offset, then the per-shard (lse, ll) combine via ``pmax``/``psum``
over the vocab axes exactly as the norm kernels psum column sums; dH
psums over the vocab axes, dW over the token axes. w's embed-dim sharding
is gathered at shard_map entry (the same all-gather GSPMD inserts for the
unfused head matmul).

Fused flash attention (``flash_attention``)
-------------------------------------------
The attention hot path is registered the same way: ``flash_attention`` is
a ``custom_vjp`` over the blockwise Pallas kernels in
:mod:`repro.kernels.attention` (score tiles never leave VMEM; the
backward recomputes them from the saved ``lse`` exactly like the jnp
scan's custom_vjp). Routing mirrors the other ops — compiled on TPU,
interpret oracle elsewhere, ``REPRO_FUSED=off`` or an uncovered
shape/sharding routes to the reference. Callers that own a memory-safe
jnp path check ``attn_route`` first and keep it (``models.layers`` keeps
the blockwise ``lax.scan`` as the bitwise reference and
``chunked_q_attention`` for the decode cache); the in-dispatch fallback
delegates to those same layer implementations. The shard plan covers the
**activation batch and head** mesh axes (the dims a
``_plan_sharding``-style shard_map can express exactly): q and kv must
shard batch/heads over identical axes — each device then runs its
(B/n, S, H/m, hd) x (B/n, T, K/m, hd) problem with **no collectives at
all** (the softmax reduces over the unsharded T, and the GQA group ratio
is preserved per shard). Sequence- or head_dim-sharded layouts (e.g. the
``cache_seq -> "model"`` decode cache) and GQA layouts where kv cannot
shard like q (K not divisible by the head axes) fall back to the jnp
path, which GSPMD partitions with its small lse all-reduces.
"""
from __future__ import annotations

import functools
import os
import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .attention import attention as _ak
from .attention.mask import MaskSpec, mask_spec
from .colnorm import colnorm as _ck
from .colnorm import ref as _cref
from .colnorm.colnorm import _canon3 as _c3
from .scale_head import ref as _href
from .scale_head import scale_head as _hk
from .xent import ref as _xref
from .xent import xent as _xk

FUSED_KINDS = ("col", "row", "larger")
FUSED_NDIMS = (2, 3)

_MODES = ("auto", "interpret", "compiled", "off")


def resolve_mode() -> str:
    """Read REPRO_FUSED now (never cached — see the module docstring)."""
    m = os.environ.get("REPRO_FUSED", "auto")
    if m not in _MODES:
        raise ValueError(f"REPRO_FUSED must be auto|interpret|compiled|off, "
                         f"got {m!r}")
    return m


def backend() -> str:
    return jax.devices()[0].platform


def use_interpret(mode: str | None = None) -> bool:
    """Compiled on TPU, interpret oracle elsewhere (unless overridden)."""
    mode = resolve_mode() if mode is None else mode
    if mode == "interpret":
        return True
    if mode == "compiled":
        return False
    return backend() != "tpu"


def resolve_kind(kind: str, shape) -> str:
    """Resolve ``larger`` to col/row by shape (Table 13 row 4; static).

    Delegates to :func:`repro.core.normalization.resolve_larger` so the
    jnp impl and the kernel dispatch share one tie-break for square shapes.
    Always resolved on the **global** shape, before any shard_map: a shard
    of a tall matrix can be wide, and the two impls must agree.
    """
    from repro.core.normalization import resolve_larger
    return resolve_larger(kind, shape)


def _ref_norm(g: jnp.ndarray, kind: str, eps: float) -> jnp.ndarray:
    """jnp fallback for any norm kind (col/row honor eps; others delegate
    to repro.core.normalization, whose kinds have no eps knob)."""
    kind = resolve_kind(kind, g.shape)
    if kind in ("col", "row"):
        return _cref.normalize(g, kind, eps)
    from repro.core.normalization import normalize as _core_normalize
    return _core_normalize(g, kind)


def supported(shape, kind: str, mode: str | None = None) -> bool:
    """True when (shape, kind) is covered by the fused kernels."""
    if (resolve_mode() if mode is None else mode) == "off":
        return False
    if len(shape) not in FUSED_NDIMS or kind not in FUSED_KINDS:
        return False
    return all(d >= 1 for d in shape)


# --------------------------------------------------------------------------
# Sharding plans
# --------------------------------------------------------------------------

class ShardPlan(NamedTuple):
    """Static (hashable) shard_map recipe for one canonical (L, m, n) array.

    ``spec3[d]`` is the tuple of mesh axis names sharding canon3 dim ``d``.
    """
    mesh: Mesh
    spec3: tuple


def _plan_sharding(sharding, shape):
    """-> None (single-device path) | "ref" (GSPMD jnp fallback) | ShardPlan.

    "ref" is returned for shardings shard_map cannot express exactly
    (non-NamedSharding layouts, dims not divisible by their mesh axes): the
    jnp reference is partitioned correctly by GSPMD, whereas running the
    kernels shard-locally would reduce over partial slices — the exact bug
    this module exists to prevent.
    """
    if sharding is None:
        return None
    if not isinstance(sharding, NamedSharding):
        return "ref"
    from repro.models.sharding import spec_mesh_axes
    per_dim = spec_mesh_axes(sharding.spec, len(shape))
    if len(shape) == 2:
        per_dim = ((),) + per_dim
    if all(not axs for axs in per_dim):
        return None  # replicated: plain single-device semantics are exact
    mesh = sharding.mesh
    shape3 = (1,) + tuple(shape) if len(shape) == 2 else tuple(shape)
    for dim, axs in zip(shape3, per_dim):
        k = 1
        for a in axs:
            if a not in mesh.shape:
                return "ref"
            k *= mesh.shape[a]
        if dim % k:
            return "ref"
    return ShardPlan(mesh, per_dim)


def _route(shape, kind, mode, sharding):
    """-> ("ref", None) | ("kernel", None | ShardPlan)."""
    if not supported(shape, kind, mode):
        return "ref", None
    plan = _plan_sharding(sharding, shape)
    if plan == "ref":
        return "ref", None
    return "kernel", plan


def _pspec(spec3) -> P:
    return P(*[axs if axs else None for axs in spec3])


def _red_axes(plan: ShardPlan, axis: str):
    """Mesh axes the per-slice sums-of-squares must psum over."""
    return plan.spec3[1 if axis == "col" else 2]


def _psum_ss(ss, plan, axis):
    axes = _red_axes(plan, axis)
    return jax.lax.psum(ss, axes) if axes else ss


def _mapped(body, plan, n_arrays, n_outs=1):
    """Wrap ``body`` in shard_map per ``plan`` (identity when plan is None).

    The first ``n_arrays`` args are (L, m, n) canon3 arrays sharded per
    ``plan.spec3``; the rest are replicated scalars.
    """
    if plan is None:
        return body
    sp = _pspec(plan.spec3)

    def wrapped(*args):
        in_specs = (sp,) * n_arrays + (P(),) * (len(args) - n_arrays)
        return shard_map(body, mesh=plan.mesh, in_specs=in_specs,
                         out_specs=(sp,) * n_outs if n_outs > 1 else sp,
                         check_rep=False)(*args)

    return wrapped


# --------------------------------------------------------------------------
# Graceful degradation: kernel-route failure capture. A kernel path that
# fails on some backend surfaces its error at trace/lower time — host-side
# Python, exactly where these wrappers sit — so a failing kernel route
# degrades to the jnp reference instead of killing the run. Each (op,
# exception type) is warned once per process; per-op counts are exposed so
# the training driver can log degradations at its metrics cadence.
# --------------------------------------------------------------------------

_FALLBACK_COUNTS: dict = {}       # op -> kernel->reference degradations
_FALLBACK_LOGGED: set = set()     # (op, exc type): warn once per process


def fallback_counts() -> dict:
    """Per-op count of kernel-route failures degraded to the reference."""
    return dict(_FALLBACK_COUNTS)


def reset_fallbacks() -> None:
    """Forget recorded degradations (tests isolate cases with this)."""
    _FALLBACK_COUNTS.clear()
    _FALLBACK_LOGGED.clear()


def fallback_snapshot() -> dict:
    """Immutable copy of the cumulative per-op fallback counters.

    Pair with :func:`fallback_delta` for per-interval metric emission:
    the training driver snapshots at each metrics record and logs the
    delta since the previous one — cumulative counters stay untouched, so
    the chaos tests' whole-run assertions (which read
    :func:`fallback_counts`) never race a metrics-cadence reset.
    """
    return dict(_FALLBACK_COUNTS)


def fallback_delta(prev: dict, cur: dict | None = None) -> dict:
    """Per-op fallback increments since ``prev`` (a prior snapshot).

    ``cur`` defaults to a fresh snapshot. Ops with no new degradations are
    omitted, so an all-healthy interval is ``{}`` (nothing to log).
    """
    if cur is None:
        cur = fallback_snapshot()
    return {op: n - prev.get(op, 0) for op, n in cur.items()
            if n - prev.get(op, 0)}


def _dispatch_fault_gate(op: str) -> None:
    # chaos hook (REPRO_FAULTS dispatch_fail@op): no-op unless set; the
    # env check keeps the training package off dispatch's import path
    if not os.environ.get("REPRO_FAULTS"):
        return
    from repro.training import faults
    faults.dispatch_gate(op)


def _guarded(op: str, kernel_thunk, ref_thunk):
    """Run the kernel route; degrade to the reference on any failure.

    Catches Exception only: a KeyboardInterrupt or a SimulatedKill
    (BaseException) must never be absorbed into a silent fallback. The
    degradation is baked into whatever jit trace is being built, so a
    compiled train step that hit a failing kernel route keeps running the
    reference until retraced.
    """
    try:
        _dispatch_fault_gate(op)
        return kernel_thunk()
    except Exception as e:
        _FALLBACK_COUNTS[op] = _FALLBACK_COUNTS.get(op, 0) + 1
        key = (op, type(e).__name__)
        if key not in _FALLBACK_LOGGED:
            _FALLBACK_LOGGED.add(key)
            warnings.warn(
                f"dispatch: kernel route for {op!r} failed "
                f"({type(e).__name__}: {e}); degrading to the jnp "
                "reference path")
        return ref_thunk()


# --------------------------------------------------------------------------
# Entry points. Thin Python wrappers resolve REPRO_FUSED and the sharding
# plan per call; the jitted impls take both as static args (cache-keyed).
# --------------------------------------------------------------------------

def _gs_arg(gscale):
    return (gscale is not None,
            jnp.asarray(1.0 if gscale is None else gscale, jnp.float32))


def _scaled_ref(g, gs, has_gs):
    # mirrors the trainer's clip tree-map (g * scale in g's promoted dtype)
    return g * gs if has_gs else g


@functools.partial(jax.jit, static_argnames=("kind", "eps", "mode", "plan",
                                             "has_gs"))
def _normalize_impl(g, gs, *, kind, eps, mode, plan, has_gs):
    if plan == "ref":
        return _ref_norm(_scaled_ref(g, gs, has_gs), kind, eps)
    axis = resolve_kind(kind, g.shape)
    interp = use_interpret(mode)

    def body(g3, gs):
        ss = _ck.norm_sumsq(g3, axis, interpret=interp, gscale=gs)
        if plan is not None:
            ss = _psum_ss(ss, plan, axis)
        return _ck.norm_apply(g3, ss, axis, eps=eps, interpret=interp,
                              gscale=gs)

    return _mapped(body, plan, 1)(_c3(g), gs).reshape(g.shape)


def normalize(g: jnp.ndarray, kind: str = "col", eps: float = 1e-8, *,
              gscale=None, sharding=None, mode: str | None = None):
    """Fused gscale*g / (||slice||+eps); jnp oracle off-matrix."""
    mode = resolve_mode() if mode is None else mode
    route, plan = _route(g.shape, kind, mode, sharding)
    has_gs, gs = _gs_arg(gscale)
    kw = dict(kind=kind, eps=eps, mode=mode, has_gs=has_gs)
    if route == "kernel":
        return _guarded("normalize",
                        lambda: _normalize_impl(g, gs, plan=plan, **kw),
                        lambda: _normalize_impl(g, gs, plan="ref", **kw))
    return _normalize_impl(g, gs, plan="ref", **kw)


@functools.partial(jax.jit, static_argnames=("kind", "eps", "mode", "plan",
                                             "has_gs"))
def _norm_update_impl(theta, g, lr, gs, *, kind, eps, mode, plan, has_gs):
    if plan == "ref":
        g = _scaled_ref(g, gs, has_gs)
        return (theta.astype(jnp.float32)
                - jnp.asarray(lr, jnp.float32)
                * _ref_norm(g, kind, eps).astype(jnp.float32)
                ).astype(theta.dtype)
    axis = resolve_kind(kind, theta.shape)
    interp = use_interpret(mode)

    def body(t3, g3, gs, lr):
        ss = _ck.norm_sumsq(g3, axis, interpret=interp, gscale=gs)
        if plan is not None:
            ss = _psum_ss(ss, plan, axis)
        return _ck.update_apply(t3, g3, ss, lr, axis, eps=eps,
                                interpret=interp, gscale=gs)

    lr = jnp.asarray(lr, jnp.float32)
    return _mapped(body, plan, 2)(_c3(theta), _c3(g), gs,
                                  lr).reshape(theta.shape)


def norm_update(theta: jnp.ndarray, g: jnp.ndarray, lr, kind: str = "col",
                eps: float = 1e-8, *, gscale=None, sharding=None,
                mode: str | None = None):
    """Fused theta - lr*normalize(gscale*g); 3-pass per-shard apply stage."""
    mode = resolve_mode() if mode is None else mode
    route, plan = _route(theta.shape, kind, mode, sharding)
    has_gs, gs = _gs_arg(gscale)
    kw = dict(kind=kind, eps=eps, mode=mode, has_gs=has_gs)
    if route == "kernel":
        return _guarded(
            "norm_update",
            lambda: _norm_update_impl(theta, g, lr, gs, plan=plan, **kw),
            lambda: _norm_update_impl(theta, g, lr, gs, plan="ref", **kw))
    return _norm_update_impl(theta, g, lr, gs, plan="ref", **kw)


@functools.partial(jax.jit, static_argnames=("kind", "eps", "mode", "plan",
                                             "has_gs"))
def _momentum_norm_impl(m, g, beta, gs, *, kind, eps, mode, plan, has_gs):
    if plan == "ref":
        g = _scaled_ref(g, gs, has_gs)
        m_new = (jnp.asarray(beta, jnp.float32) * m.astype(jnp.float32)
                 + (1.0 - jnp.asarray(beta, jnp.float32))
                 * g.astype(jnp.float32))
        # momentum storage dtype is m's dtype (cast-on-write; the norm is
        # computed from the pre-cast f32 EMA, matching the kernel)
        return m_new.astype(m.dtype), _ref_norm(m_new, kind, eps)
    axis = resolve_kind(kind, m.shape)
    interp = use_interpret(mode)

    def body(m3, g3, gs, beta):
        m_new, ss = _hk.momentum_sumsq(m3, g3, beta, axis, interpret=interp,
                                       gscale=gs)
        if plan is not None:
            ss = _psum_ss(ss, plan, axis)
        # d is emitted f32 even when the stored momentum is bf16 (the
        # update tree must not inherit the storage quantization). Its
        # numerator is the *stored* m' — re-emitting a f32 copy for the
        # apply would double the momentum traffic — so under bf16 storage
        # the direction differs from the jnp route's pre-cast-EMA norm by
        # bf16 rounding (see the momentum_dtype note in core/scale.py).
        d = _ck.norm_apply(m_new, ss, axis, eps=eps, interpret=interp,
                           out_dtype=jnp.float32)
        return m_new, d

    beta = jnp.asarray(beta, jnp.float32)
    m_new, d = _mapped(body, plan, 2, n_outs=2)(_c3(m), _c3(g), gs, beta)
    return m_new.reshape(m.shape), d.reshape(m.shape)


def momentum_norm(m: jnp.ndarray, g: jnp.ndarray, beta, kind: str = "col",
                  eps: float = 1e-8, *, gscale=None, sharding=None,
                  mode: str | None = None):
    """(m', normalize(m')) with the EMA and sumsq fused into one kernel."""
    mode = resolve_mode() if mode is None else mode
    route, plan = _route(m.shape, kind, mode, sharding)
    has_gs, gs = _gs_arg(gscale)
    kw = dict(kind=kind, eps=eps, mode=mode, has_gs=has_gs)
    if route == "kernel":
        return _guarded(
            "momentum_norm",
            lambda: _momentum_norm_impl(m, g, beta, gs, plan=plan, **kw),
            lambda: _momentum_norm_impl(m, g, beta, gs, plan="ref", **kw))
    return _momentum_norm_impl(m, g, beta, gs, plan="ref", **kw)


@functools.partial(jax.jit, static_argnames=("kind", "eps", "mode", "plan",
                                             "has_gs"))
def _momentum_norm_update_impl(theta, m, g, beta, lr, gs, *, kind, eps, mode,
                               plan, has_gs):
    if plan == "ref":
        m_new, d = _momentum_norm_impl(m, g, beta, gs, kind=kind, eps=eps,
                                       mode=mode, plan="ref", has_gs=has_gs)
        theta_new = (theta.astype(jnp.float32)
                     - jnp.asarray(lr, jnp.float32) * d.astype(jnp.float32)
                     ).astype(theta.dtype)
        return theta_new, m_new
    axis = resolve_kind(kind, theta.shape)
    interp = use_interpret(mode)

    def body(t3, m3, g3, gs, beta, lr):
        m_new, ss = _hk.momentum_sumsq(m3, g3, beta, axis, interpret=interp,
                                       gscale=gs)
        if plan is not None:
            ss = _psum_ss(ss, plan, axis)
        theta_new = _hk.head_update_apply(t3, m_new, ss, lr, axis, eps=eps,
                                          interpret=interp)
        return theta_new, m_new

    beta = jnp.asarray(beta, jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)
    t_new, m_new = _mapped(body, plan, 3, n_outs=2)(
        _c3(theta), _c3(m), _c3(g), gs, beta, lr)
    return t_new.reshape(theta.shape), m_new.reshape(m.shape)


def momentum_norm_update(theta: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray,
                         beta, lr, kind: str = "col", eps: float = 1e-8, *,
                         gscale=None, sharding=None, mode: str | None = None):
    """Fully fused stateful step: (theta', m') in two kernel launches."""
    mode = resolve_mode() if mode is None else mode
    route, plan = _route(theta.shape, kind, mode, sharding)
    has_gs, gs = _gs_arg(gscale)
    kw = dict(kind=kind, eps=eps, mode=mode, has_gs=has_gs)
    if route == "kernel":
        return _guarded(
            "momentum_norm_update",
            lambda: _momentum_norm_update_impl(theta, m, g, beta, lr, gs,
                                               plan=plan, **kw),
            lambda: _momentum_norm_update_impl(theta, m, g, beta, lr, gs,
                                               plan="ref", **kw))
    return _momentum_norm_update_impl(theta, m, g, beta, lr, gs, plan="ref",
                                      **kw)


# --------------------------------------------------------------------------
# Fused LM-head cross-entropy
# --------------------------------------------------------------------------

class XentPlan(NamedTuple):
    """Static shard_map recipe for the fused xent.

    ``tok_axes``: mesh axes sharding the leading (batch) dim of h/labels.
    ``voc_axes``: mesh axes sharding w's vocab dim (dim 1; dim 0 for a
    transposed/tied w). w's embed dim is always gathered inside the
    shard_map (in_spec ``None``).
    """
    mesh: Mesh
    tok_axes: tuple
    voc_axes: tuple


def xent_supported(h_shape, w_shape, mode: str | None = None,
                   transposed: bool = False) -> bool:
    """True when (h, w) shapes are covered by the fused xent kernels.

    ``transposed``: w is a tied embedding stored (V, D) — the contraction
    dim is then w's dim 1 instead of dim 0.
    """
    if (resolve_mode() if mode is None else mode) == "off":
        return False
    if len(h_shape) not in (2, 3) or len(w_shape) != 2:
        return False
    if h_shape[-1] != w_shape[1 if transposed else 0]:
        return False
    return all(d >= 1 for d in tuple(h_shape) + tuple(w_shape))


def _axes_prod(mesh: Mesh, axes) -> int | None:
    k = 1
    for a in axes:
        if a not in mesh.shape:
            return None
        k *= mesh.shape[a]
    return k


def _plan_xent(h_sharding, w_sharding, h_shape, w_shape,
               transposed: bool = False):
    """-> None (single-device) | "ref" | XentPlan.

    "ref" for layouts shard_map cannot express exactly: non-NamedSharding,
    mismatched meshes, h sharded on a non-leading dim (seq/embed), or
    token/vocab dims not divisible by their mesh axes. The jnp chunked
    path partitions those correctly through GSPMD. For a transposed (tied)
    w the vocab dim is w's dim 0 and the gathered embed dim is dim 1.
    """
    if h_sharding is None and w_sharding is None:
        return None
    mesh = None
    for sh in (h_sharding, w_sharding):
        if sh is None:
            continue
        if not isinstance(sh, NamedSharding):
            return "ref"
        if mesh is not None and sh.mesh != mesh:
            return "ref"
        mesh = sh.mesh
    from repro.models.sharding import spec_mesh_axes
    voc_dim = 0 if transposed else 1
    tok_axes = voc_axes = ()
    if h_sharding is not None:
        per = spec_mesh_axes(h_sharding.spec, len(h_shape))
        if any(per[1:]):
            return "ref"  # seq- or embed-sharded hidden: GSPMD handles it
        tok_axes = per[0]
    if w_sharding is not None:
        voc_axes = spec_mesh_axes(w_sharding.spec, 2)[voc_dim]
    if not tok_axes and not voc_axes:
        return None  # replicated (or only w's gathered embed dim sharded)
    if set(tok_axes) & set(voc_axes):
        # one mesh axis sharding both tokens and vocab: each device holds
        # a *different* token block AND vocab block, so the lse/ll psum
        # over that axis would mix statistics across token shards —
        # silently wrong, exactly what the ref fallback exists to prevent
        return "ref"
    kt = _axes_prod(mesh, tok_axes)
    kv = _axes_prod(mesh, voc_axes)
    if kt is None or kv is None or h_shape[0] % kt or w_shape[voc_dim] % kv:
        return "ref"
    return XentPlan(mesh, tuple(tok_axes), tuple(voc_axes))


def xent_route(h_shape, w_shape, mode: str | None = None, h_sharding=None,
               w_sharding=None, transposed: bool = False):
    """-> ("ref", None) | ("kernel", None | XentPlan).

    Callers that must never materialize full logits (the model's loss)
    take their own chunked path on "ref"; ``xent_loss``'s built-in ref is
    the full-logit test-scale oracle. ``transposed``: w is the tied (V, D)
    embedding (see the coverage matrix).
    """
    if not xent_supported(h_shape, w_shape, mode, transposed):
        return "ref", None
    plan = _plan_xent(h_sharding, w_sharding, h_shape, w_shape, transposed)
    if plan == "ref":
        return "ref", None
    return "kernel", plan


@functools.lru_cache(maxsize=None)
def _xent_fused(vocab_size: int, interp: bool, plan, block,
                transposed: bool = False):
    """Build the custom_vjp'd fused xent for one static configuration.

    Cached so repeated traces reuse one custom_vjp object (and its jit
    caches). ``plan`` is an XentPlan or None; ``block`` a (bn, bv) tuple
    or None; ``transposed`` selects the tied (V, D) w layout — dW then
    comes back in (V, D), landing directly on the embedding cotangent.
    """
    mesh = plan.mesh if plan is not None else None
    tok_axes = plan.tok_axes if plan is not None else ()
    voc_axes = plan.voc_axes if plan is not None else ()
    _v_local = (lambda wb: wb.shape[0]) if transposed \
        else (lambda wb: wb.shape[1])

    def _voffset(v_local: int):
        """Global column id of this shard's first w column (0 off-mesh)."""
        if not voc_axes:
            return 0
        idx = jnp.int32(0)
        for a in voc_axes:  # major-to-minor, matching GSPMD's dim split
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        return idx * v_local

    def _specs(h_ndim, lab_ndim):
        tok = tuple(tok_axes) or None
        hspec = P(*(tok,) + (None,) * (h_ndim - 1))
        lspec = P(*(tok,) + (None,) * (lab_ndim - 1))
        voc = tuple(voc_axes) or None
        wspec = P(voc, None) if transposed else P(None, voc)
        return hspec, wspec, lspec

    def _fwd_parts(h, w, labels):
        def body(hb, wb, lab):
            lse, ll = _xk.xent_fwd(
                hb.reshape(-1, hb.shape[-1]), wb, lab.reshape(-1),
                vocab_size=vocab_size, col_offset=_voffset(_v_local(wb)),
                block=block, interpret=interp, transposed=transposed)
            if voc_axes:
                m = jax.lax.pmax(lse, voc_axes)
                lse = m + jnp.log(jax.lax.psum(jnp.exp(lse - m), voc_axes))
                ll = jax.lax.psum(ll, voc_axes)
            return lse.reshape(lab.shape), ll.reshape(lab.shape)

        if plan is None:
            return body(h, w, labels)
        hspec, wspec, lspec = _specs(h.ndim, labels.ndim)
        return shard_map(body, mesh=mesh, in_specs=(hspec, wspec, lspec),
                         out_specs=(lspec, lspec), check_rep=False)(
                             h, w, labels)

    def _bwd_parts(h, w, labels, lse, gl):
        def body(hb, wb, lab, lse_, gl_):
            h2 = hb.reshape(-1, hb.shape[-1])
            args = (h2, wb, lab.reshape(-1), lse_.reshape(-1),
                    gl_.reshape(-1))
            kw = dict(vocab_size=vocab_size, block=block, interpret=interp,
                      col_offset=_voffset(_v_local(wb)),
                      transposed=transposed)
            # partial sums psum in f32, then round to the cotangent dtype
            dh = _xk.xent_bwd_dh(
                *args, **kw,
                out_dtype=jnp.float32 if voc_axes else hb.dtype)
            dw = _xk.xent_bwd_dw(
                *args, **kw,
                out_dtype=jnp.float32 if tok_axes else wb.dtype)
            if voc_axes:
                dh = jax.lax.psum(dh, voc_axes).astype(hb.dtype)
            if tok_axes:
                dw = jax.lax.psum(dw, tok_axes).astype(wb.dtype)
            return dh.reshape(hb.shape), dw

        if plan is None:
            return body(h, w, labels, lse, gl)
        hspec, wspec, lspec = _specs(h.ndim, labels.ndim)
        return shard_map(body, mesh=mesh,
                         in_specs=(hspec, wspec, lspec, lspec, lspec),
                         out_specs=(hspec, wspec), check_rep=False)(
                             h, w, labels, lse, gl)

    @jax.custom_vjp
    def fused(h, w, labels):
        lse, ll = _fwd_parts(h, w, labels)
        return jnp.where(labels >= 0, lse - ll, 0.0)

    def fwd(h, w, labels):
        lse, ll = _fwd_parts(h, w, labels)
        return jnp.where(labels >= 0, lse - ll, 0.0), (h, w, labels, lse)

    def bwd(res, g):
        h, w, labels, lse = res
        gl = g.astype(jnp.float32) * (labels >= 0)
        dh, dw = _bwd_parts(h, w, labels, lse, gl)
        return dh, dw, np.zeros(labels.shape, jax.dtypes.float0)

    fused.defvjp(fwd, bwd)
    return fused


def _xent_ref(h, w, labels, *, vocab_size: int, transposed: bool = False):
    """Full-logit jnp oracle (test scale; see ``xent_route``).

    The transpose of a tied w is lazy (fused into the dot); grads flow
    back through it, so dW arrives in the (V, D) storage layout here too.
    """
    if transposed:
        w = jnp.swapaxes(w, -1, -2)
    return _xref.losses(h, w, labels, vocab_size)


def xent_loss(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray, *,
              vocab_size: int, weights=None, mode: str | None = None,
              h_sharding=None, w_sharding=None, block=None,
              transposed: bool = False):
    """Fused per-token LM-head cross-entropy (custom_vjp, see module doc).

    h (..., D), w (D, V) — or the tied (V, D) embedding with
    ``transposed=True`` — labels h.shape[:-1] int32 (-1 = masked).
    Returns f32 losses of labels.shape; masked tokens are 0 in both the
    value and the (h, w) gradients. ``weights`` (optional, labels.shape,
    f32) scales each token's loss *and* gradient: zero-weight tokens are
    additionally masked outright (their labels are demoted to -1 before
    the kernel, so they cost no gradient work), fractional weights scale
    linearly. The weighting wraps both routes identically — it composes
    outside the custom_vjp, so the fused kernels stay weight-oblivious.
    Padded vocab columns (>= vocab_size) never enter the logsumexp. dW
    always matches w's own layout.
    """
    if weights is not None:
        labels = jnp.where(weights > 0, labels, -1)
    mode = resolve_mode() if mode is None else mode
    route, plan = xent_route(h.shape, w.shape, mode, h_sharding, w_sharding,
                             transposed)
    if route == "ref":
        losses = _xent_ref(h, w, labels, vocab_size=vocab_size,
                           transposed=transposed)
    else:
        losses = _guarded(
            "xent_loss",
            lambda: _xent_fused(vocab_size, use_interpret(mode), plan,
                                tuple(block) if block is not None else None,
                                transposed)(h, w, labels),
            lambda: _xent_ref(h, w, labels, vocab_size=vocab_size,
                              transposed=transposed))
    if weights is not None:
        losses = losses * weights.astype(losses.dtype)
    return losses


# --------------------------------------------------------------------------
# Fused flash attention
# --------------------------------------------------------------------------

class AttnPlan(NamedTuple):
    """Static shard_map recipe for the fused attention.

    ``batch_axes``: mesh axes sharding the leading (batch) dim of q *and*
    kv. ``head_axes``: mesh axes sharding q's H and kv's K head dims (both
    must divide, so the GQA group ratio is preserved per shard). There are
    no cross-shard reductions: every (batch, head) pair is device-local.
    """
    mesh: Mesh
    batch_axes: tuple
    head_axes: tuple


def attn_supported(q_shape, kv_shape, causal: bool = True,
                   mode: str | None = None) -> bool:
    """True when (q, kv) shapes are covered by the fused attention kernels.

    ``kv_shape`` is k's (B, T, K, hd); v may differ only in its last dim.
    Causal needs T >= S (the rectangular offset T - S would otherwise put
    queries past the last key).
    """
    if (resolve_mode() if mode is None else mode) == "off":
        return False
    if len(q_shape) != 4 or len(kv_shape) != 4:
        return False
    B, S, H, hd = q_shape
    if kv_shape[0] != B or kv_shape[3] != hd:
        return False
    K = kv_shape[2]
    if K < 1 or H % K:
        return False
    if causal and kv_shape[1] < S:
        return False
    return all(d >= 1 for d in tuple(q_shape) + tuple(kv_shape))


def _plan_attn(q_sharding, kv_sharding, q_shape, kv_shape):
    """-> None (single-device) | "ref" | AttnPlan.

    "ref" for layouts the batch/head shard_map cannot express exactly:
    non-NamedSharding, mismatched meshes, sequence- or head_dim-sharded
    operands (the seq-sharded decode cache), batch/head axes that differ
    between q and kv (e.g. MQA kv left replicated by the divisibility
    guard while q heads are TP-sharded — the kernel's ``q_head // group``
    indexing assumes aligned shards), or dims not divisible by their mesh
    axes. The jnp scan partitions those correctly through GSPMD.
    """
    if q_sharding is None and kv_sharding is None:
        return None
    mesh = None
    for sh in (q_sharding, kv_sharding):
        if sh is None:
            continue
        if not isinstance(sh, NamedSharding):
            return "ref"
        if mesh is not None and sh.mesh != mesh:
            return "ref"
        mesh = sh.mesh
    from repro.models.sharding import spec_mesh_axes
    qper = spec_mesh_axes(q_sharding.spec, 4) if q_sharding is not None \
        else ((),) * 4
    kper = spec_mesh_axes(kv_sharding.spec, 4) if kv_sharding is not None \
        else ((),) * 4
    if any(qper[1]) or any(qper[3]) or any(kper[1]) or any(kper[3]):
        return "ref"  # seq- or head_dim-sharded: GSPMD handles it
    if qper[0] != kper[0] or qper[2] != kper[2]:
        return "ref"  # q and kv must shard batch/heads identically
    batch_axes, head_axes = tuple(qper[0]), tuple(qper[2])
    if not batch_axes and not head_axes:
        return None  # replicated: plain single-device semantics are exact
    kb = _axes_prod(mesh, batch_axes)
    kh = _axes_prod(mesh, head_axes)
    if kb is None or kh is None:
        return "ref"
    if q_shape[0] % kb or q_shape[2] % kh or kv_shape[2] % kh:
        return "ref"
    return AttnPlan(mesh, batch_axes, head_axes)


def attn_route(q_shape, kv_shape, causal: bool = True,
               mode: str | None = None, q_sharding=None, kv_sharding=None):
    """-> ("ref", None) | ("kernel", None | AttnPlan).

    Callers with their own memory-safe jnp path (``models.layers``) take
    it on "ref"; ``flash_attention``'s built-in ref delegates back to the
    layer-level scan/chunked implementations.
    """
    if not attn_supported(q_shape, kv_shape, causal, mode):
        return "ref", None
    plan = _plan_attn(q_sharding, kv_sharding, q_shape, kv_shape)
    if plan == "ref":
        return "ref", None
    return "kernel", plan


def _check_kv_len(causal: bool, kv_len):
    if causal and kv_len is not None:
        raise ValueError(
            "flash_attention: kv_len requires causal=False — the decode "
            "window is non-causal within the filled cache (neither the "
            "kernels nor the reference implement a causal-over-fill mask, "
            "and silently picking one would differ between routes)")


def _attn_ref(q, k, v, *, scale, causal: bool = True, kv_len=None,
              segments=None):
    """jnp fallback: the layer-level reference implementations.

    The blockwise ``lax.scan`` (bitwise pre-kernel path) for plain
    causal/cross attention — segment-masked through the same scan's
    ``MaskSpec`` when packed segment ids are live — and
    ``chunked_q_attention`` when a ``kv_len`` cache bound is involved.
    GQA kv is repeated here — exactly what the kernels avoid.
    """
    from repro.models import layers as L  # lazy: avoids an import cycle
    _check_kv_len(causal, kv_len)
    if kv_len is not None:
        return L.chunked_q_attention(
            q, k, v, L.largest_divisor(q.shape[1], 128), scale,
            kv_len=kv_len)
    H, K = q.shape[2], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    if segments is not None:
        spec = mask_spec(q.shape[1], k.shape[1], causal=causal,
                         segments=segments)
        block = L.largest_divisor(q.shape[1], 128)
        return L.masked_flash_attention(q, k, v, segments[0], segments[1],
                                        block, scale, spec)
    return L.flash_attention(q, k, v, 128, scale, causal)


@functools.lru_cache(maxsize=None)
def _attn_fused(scale: float, spec: MaskSpec, interp: bool, plan, block):
    """Build the custom_vjp'd fused attention for one static configuration.

    Cached so repeated traces reuse one custom_vjp object. ``spec`` is the
    (hashable) :class:`MaskSpec`; ``plan`` an AttnPlan or None; ``block``
    a (bq, bk) tuple or None. The traced mask operands — the ``kv_len``
    scalar and the (B, S)/(B, T) segment ids — ride along as custom_vjp
    arguments with float0 cotangents (index data, like xent's labels);
    when the spec declares no segments the pair is a zero-size dummy the
    kernels never read.
    """
    mesh = plan.mesh if plan is not None else None
    if plan is not None:
        bt = tuple(plan.batch_axes) or None
        hx = tuple(plan.head_axes) or None
        qspec = P(bt, None, hx, None)   # (B, S|T, H|K, hd) operand layout
        lspec = P(bt, hx, None)         # (B, H, S) lse layout
        sspec = P(bt, None)             # (B, S)/(B, T) segment-id layout

    def _segs(qs, ks):
        return (qs, ks) if spec.has_segments else None

    def _fwd_parts(q, k, v, kl, qs, ks):
        def body(qb, kb, vb, kl_, qsb, ksb):
            return _ak.mha_fwd(qb, kb, vb, kl_, scale=scale, spec=spec,
                               segments=_segs(qsb, ksb), block=block,
                               interpret=interp)

        if plan is None:
            return body(q, k, v, kl, qs, ks)
        return shard_map(body, mesh=mesh,
                         in_specs=(qspec, qspec, qspec, P(), sspec, sspec),
                         out_specs=(qspec, lspec), check_rep=False)(
                             q, k, v, kl, qs, ks)

    def _bwd_parts(q, k, v, kl, qs, ks, out, lse, do):
        def body(qb, kb, vb, kl_, qsb, ksb, ob, lseb, dob):
            delta = jnp.swapaxes(
                jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32),
                        -1), 1, 2)
            segs = _segs(qsb, ksb)
            dq = _ak.mha_bwd_dq(qb, kb, vb, dob, lseb, delta, kl_,
                                scale=scale, spec=spec, segments=segs,
                                block=block, interpret=interp)
            dk, dv = _ak.mha_bwd_dkv(qb, kb, vb, dob, lseb, delta, kl_,
                                     scale=scale, spec=spec, segments=segs,
                                     block=block, interpret=interp)
            return dq, dk, dv

        if plan is None:
            return body(q, k, v, kl, qs, ks, out, lse, do)
        return shard_map(body, mesh=mesh,
                         in_specs=(qspec, qspec, qspec, P(), sspec, sspec,
                                   qspec, lspec, qspec),
                         out_specs=(qspec, qspec, qspec),
                         check_rep=False)(q, k, v, kl, qs, ks, out, lse, do)

    @jax.custom_vjp
    def fused(q, k, v, kl, qs, ks):
        return _fwd_parts(q, k, v, kl, qs, ks)[0]

    def fwd(q, k, v, kl, qs, ks):
        out, lse = _fwd_parts(q, k, v, kl, qs, ks)
        return out, (q, k, v, kl, qs, ks, out, lse)

    def bwd(res, do):
        q, k, v, kl, qs, ks, out, lse = res
        dq, dk, dv = _bwd_parts(q, k, v, kl, qs, ks, out, lse, do)
        f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
        return dq, dk, dv, f0(kl), f0(qs), f0(ks)

    fused.defvjp(fwd, bwd)
    return fused


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    scale: float, causal: bool = True, kv_len=None,
                    segments=None, block=None, q_sharding=None,
                    kv_sharding=None, mode: str | None = None):
    """Fused blockwise attention (custom_vjp, see module doc).

    q (B, S, H, hd); k (B, T, K, hd), v (B, T, K, hdv) with H % K == 0 —
    the GQA repeat is never materialized (dK/dV come back in kv's own
    (B, T, K, *) layout). ``causal`` masks rectangularly (query i sees
    keys <= T-S+i); ``kv_len`` (traced scalar) bounds the key positions
    for decode over a partially filled cache; ``segments`` — a
    ((B, S), (B, T)) int32 pair — forbids attention across packed-document
    boundaries (ids must match; pad id 0 is its own island). Returns
    (B, S, H, hdv) in q's dtype. ``kv_len`` is only meaningful without
    causal masking (causal + kv_len raises — no route implements that
    combination) and mutually exclusive with ``segments`` (packed batches
    have no cache-fill bound).
    """
    mode = resolve_mode() if mode is None else mode
    _check_kv_len(causal, kv_len)
    spec = mask_spec(q.shape[1], k.shape[1], causal=causal, kv_len=kv_len,
                     segments=segments)
    route, plan = attn_route(q.shape, k.shape, causal, mode, q_sharding,
                             kv_sharding)
    if route == "ref" or v.shape[:3] != k.shape[:3]:
        return _attn_ref(q, k, v, scale=scale, causal=causal, kv_len=kv_len,
                         segments=segments)
    kl = jnp.asarray(k.shape[1] if kv_len is None else kv_len, jnp.int32)
    if segments is not None:
        qs = segments[0].astype(jnp.int32)
        ks = segments[1].astype(jnp.int32)
    else:  # fixed custom_vjp arity: zero-size stand-ins, never read
        qs = ks = jnp.zeros((q.shape[0], 0), jnp.int32)
    return _guarded(
        "flash_attention",
        lambda: _attn_fused(float(scale), spec, use_interpret(mode), plan,
                            tuple(block) if block is not None else None)(
                                q, k, v, kl, qs, ks),
        lambda: _attn_ref(q, k, v, scale=scale, causal=causal,
                          kv_len=kv_len, segments=segments))


# Introspection: op name -> (fused entry point, jnp reference). Tests iterate
# this to keep the parity matrix and the dispatch table in sync.
REGISTRY = {
    "normalize": (normalize, _cref.normalize),
    "norm_update": (norm_update, _cref.norm_update),
    "momentum_norm": (momentum_norm, _href.momentum_norm),
    "momentum_norm_update": (momentum_norm_update, _href.momentum_norm_update),
    "xent_loss": (xent_loss, _xent_ref),
    "flash_attention": (flash_attention, _attn_ref),
}
