"""Pallas TPU kernels for fused LM-head cross-entropy (logits -> loss/grad).

The head path ``loss = xent(h @ w, labels)`` is the activation-memory
hot-spot of a training step: the (tokens, vocab) logit matrix is V/D times
bigger than the hidden states that produce it. The jnp path bounds it by
chunking tokens (``models.model.lm_loss``) but still materializes a
(chunk, V) f32 logit block in HBM per scan step — and the backward scan
re-materializes it and streams a (D, V) f32 dW accumulator through HBM on
*every* chunk. These kernels never let logits leave VMEM:

  * ``xent_fwd`` — grid (token tiles, vocab tiles), vocab innermost. Each
    step computes one (bn, bv) logit tile on the MXU and folds it into a
    running online-logsumexp (max + scaled sum, flash-attention style) and
    the label-logit accumulator held in VMEM scratch; per-token ``lse`` and
    ``ll`` (each (N, 1) f32 — noise next to the matrices) are emitted once
    at the last vocab tile. Peak logit storage is one (bn, bv) VMEM tile,
    independent of V and S.
  * ``xent_bwd_dh`` — same tiling; recomputes the logit tile, forms
    ``dlogits = (softmax - onehot(label)) * g`` in registers and
    accumulates ``dlogits @ w_tile^T`` into a (bn, D) VMEM scratch, emitted
    once per token tile. dlogits never exists beyond a (bn, bv) tile.
  * ``xent_bwd_dw`` — transposed grid (vocab tiles outer, token tiles
    inner): the (D, bv) dW tile stays resident in scratch while all token
    tiles stream by, accumulating ``h_tile^T @ dlogits``; one dW write per
    vocab tile (vs the scan's read+write of the full f32 dW per chunk).

Masking folds three boundaries into the tile iota, mirroring the colnorm
kernels' remainder handling (out-of-bounds block regions are undefined —
NaN in interpret mode — and 0*NaN = NaN, so *both* operands of every
contraction are zeroed on padded positions):

  * padded vocab: global column id ``col_offset + j*bv + iota`` >=
    ``vocab_size`` contributes neither to the logsumexp nor to dW, and w is
    zeroed there before the dH contraction;
  * remainder vocab tiles (local V % bv): lanes past the local w width are
    undefined memory whose *global* ids can still be < ``vocab_size`` on a
    non-last vocab shard, so validity is the conjunction of the local
    bound and the global one (see ``_col_masks``) — and the label one-hot
    uses the same mask so a label owned by another shard cannot match an
    undefined local lane carrying its global id;
  * remainder token tiles (N % bn): forward/dH rows are independent and
    clipped on write; dW zeroes h rows and dlogits rows past N before the
    token contraction.

``col_offset`` is a traced SMEM scalar: under a vocab-sharded mesh the
dispatch layer passes ``shard_index * local_V`` so labels (global ids)
resolve against the local w shard; the per-shard (lse, ll) pair is then
combined with ``pmax``/``psum`` outside (see ``dispatch.xent_loss``).

Masked labels (-1) hit no column (col >= 0 always), so ``ll`` is 0 and the
wrapper's validity mask is the only special-casing they need. D is carried
whole per block (blocks are exact on D, never padded); ``_pick_blocks``
shrinks the token/vocab tile instead when bn*D or D*bv would crowd VMEM.

Transposed-w variants (``transposed=True`` on every entry point): the head
is a **tied embedding** stored (V, D) instead of the use layout (D, V).
Blocks then index ``w[vocab_tile, d]`` — the logit tile is the same
(bn, bv) MXU contraction with w's dims swapped, the column masks apply to
w's *rows*, and ``xent_bwd_dw`` emits dW in (V, D) layout so the gradient
lands directly on the embedding without a transpose pass. Tile sizes,
masking and the online-logsumexp recurrence are identical (one code path,
the ``wt`` static flag only swaps the w-side indexing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30  # finite -inf stand-in: keeps the running max
#                            NaN-free when a tile (or a whole vocab shard)
#                            is entirely padding


def _pick_blocks(n: int, d: int, v: int, block=None, *, el_bytes: int = 4,
                 row_acc: bool = False):
    """(bn, bv) tile for one kernel, clamped to the (padded) problem.

    The token tile bn is the HBM-reuse lever: w streams through HBM once
    per token tile (forward/dH), so bn grows until the (bn, D) h block —
    or, when ``row_acc``, the (bn, D) f32 dH accumulator — reaches ~4 MiB.
    bv likewise grows until the (D, bv) w tile / f32 dW accumulator
    reaches ~4 MiB, then shrinks while the (bn, bv) f32 logit tile
    exceeds ~8 MiB. Caps at 2048 (diminishing reuse returns), floors at
    the (32, 128) hardware tiling.
    """
    if block is not None:
        bn, bv = block
    else:
        bn = (4 << 20) // max(d * (4 if row_acc else el_bytes), 1)
        bn = max(32, min(2048, bn // 32 * 32))
        bv = max(128, min(2048, ((4 << 20) // max(d * 4, 1)) // 128 * 128))
        while bn * bv * 4 > (8 << 20) and bv > 128:
            bv //= 2
    bn = min(bn, -(-n // 32) * 32)
    bv = min(bv, -(-v // 128) * 128)
    return bn, bv


def _logit_tile(h_ref, w_ref, wt: bool):
    """(bn, bv) f32 logit tile; ``wt`` statically selects the w layout.

    Untied: w block (d, bv), plain ``h @ w``. Transposed (tied): w block
    (bv, d), contraction over each side's d dim — the same MXU shape, the
    systolic array just streams w row-major.
    """
    if wt:
        return jax.lax.dot_general(h_ref[...], w_ref[...],
                                   (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.float32)
    return jnp.dot(h_ref[...], w_ref[...],
                   preferred_element_type=jnp.float32)


def _w_spec(d, bv, wt: bool, transpose_grid: bool = False):
    """BlockSpec for the w operand: (d, bv) tiles or (bv, d) when ``wt``."""
    if transpose_grid:  # dW grid is (vocab, token): j is program_id(0)
        if wt:
            return pl.BlockSpec((bv, d), lambda j, i: (j, 0))
        return pl.BlockSpec((d, bv), lambda j, i: (0, j))
    if wt:
        return pl.BlockSpec((bv, d), lambda i, j: (j, 0))
    return pl.BlockSpec((d, bv), lambda i, j: (0, j))


def _col_masks(off, j, bv, v_local, vocab_size, shape, axis):
    """(global col ids, validity mask) for one vocab tile.

    A lane is valid only if it is inside the **local** w (lcol < v_local —
    remainder-tile lanes past it are undefined memory whose *global* ids
    can still be < vocab_size on any non-last vocab shard) AND its global
    id is a real vocab entry (col < vocab_size — padded-vocab columns).
    The mask guards the logsumexp/softmax contributions and the label
    one-hot (a label owned by another shard must not match an undefined
    local lane that happens to carry its global id).
    """
    lcol = jax.lax.broadcasted_iota(jnp.int32, shape, axis) + j * bv
    col = off + lcol
    return col, (lcol < v_local) & (col < vocab_size)


# --------------------------------------------------------------------------
# forward: blockwise logits -> online logsumexp + label logit
# --------------------------------------------------------------------------

def _fwd_kernel(h_ref, w_ref, lab_ref, off_ref, lse_ref, ll_ref,
                m_acc, s_acc, ll_acc, *, n_v_tiles, bv, v_local, vocab_size,
                wt):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, _NEG)
        s_acc[...] = jnp.zeros_like(s_acc)
        ll_acc[...] = jnp.zeros_like(ll_acc)

    logits = _logit_tile(h_ref, w_ref, wt)
    col, vmask = _col_masks(off_ref[0, 0], j, bv, v_local, vocab_size,
                            logits.shape, 1)
    logits = jnp.where(vmask, logits, _NEG)
    m_new = jnp.maximum(m_acc[...], jnp.max(logits, axis=1, keepdims=True))
    # explicit mask on the exp: with everything pinned at _NEG the
    # difference is 0 and exp would contribute 1 per padded column
    e = jnp.where(vmask, jnp.exp(logits - m_new), 0.0)
    s_acc[...] = (s_acc[...] * jnp.exp(m_acc[...] - m_new)
                  + jnp.sum(e, axis=1, keepdims=True))
    m_acc[...] = m_new
    ll_acc[...] += jnp.sum(
        jnp.where((col == lab_ref[...]) & vmask, logits, 0.0),
        axis=1, keepdims=True)

    @pl.when(j == n_v_tiles - 1)
    def _emit():
        lse_ref[...] = m_acc[...] + jnp.log(s_acc[...])
        ll_ref[...] = ll_acc[...]


def xent_fwd(h, w, labels, *, vocab_size: int, col_offset=0, block=None,
             interpret: bool = True, transposed: bool = False):
    """Per-token (lse, ll): h (N, D), w (D, V) — or (V, D) when
    ``transposed`` (tied embedding head) — labels (N,) int32.

    Returns two (N,) f32 vectors: the logsumexp over valid columns and the
    logit at the label (0 for labels outside [col_offset, col_offset+V) or
    masked -1 labels). ``loss = lse - ll`` for valid tokens.
    """
    n, d = h.shape
    v = w.shape[0] if transposed else w.shape[1]
    bn, bv = _pick_blocks(n, d, v, block, el_bytes=h.dtype.itemsize)
    grid = (pl.cdiv(n, bn), pl.cdiv(v, bv))
    off = jnp.asarray(col_offset, jnp.int32).reshape(1, 1)
    tok = pl.BlockSpec((bn, 1), lambda i, j: (i, 0))
    lse, ll = pl.pallas_call(
        functools.partial(_fwd_kernel, n_v_tiles=grid[1], bv=bv, v_local=v,
                          vocab_size=vocab_size, wt=transposed),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
                  _w_spec(d, bv, transposed),
                  tok,
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=[tok, tok],
        out_shape=[jax.ShapeDtypeStruct((n, 1), jnp.float32),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32)] * 3,
        interpret=interpret,
    )(h, w, labels.reshape(n, 1), off)
    return lse[:, 0], ll[:, 0]


# --------------------------------------------------------------------------
# backward: dH from (softmax - onehot) @ w^T, same tiling as forward
# --------------------------------------------------------------------------

def _dh_kernel(h_ref, w_ref, lab_ref, lse_ref, gl_ref, off_ref, dh_ref,
               acc_ref, *, n_v_tiles, bv, v_local, vocab_size, wt):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    logits = _logit_tile(h_ref, w_ref, wt)
    col, vmask = _col_masks(off_ref[0, 0], j, bv, v_local, vocab_size,
                            logits.shape, 1)
    p = jnp.where(vmask, jnp.exp(logits - lse_ref[...]), 0.0)
    dlog = (p - jnp.where((col == lab_ref[...]) & vmask, 1.0, 0.0)) \
        * gl_ref[...]
    # zero w on masked columns: dlog is exactly 0 there, but undefined w
    # lanes (remainder tiles) would still poison the product (0 * NaN).
    # Transposed layout: the masked vocab ids run along w's *rows*.
    if wt:
        _, wmask = _col_masks(off_ref[0, 0], j, bv, v_local, vocab_size,
                              (bv, w_ref.shape[1]), 0)
        w_eff = jnp.where(wmask, w_ref[...].astype(jnp.float32), 0.0)
        contract = (((1,), (0,)), ((), ()))
    else:
        _, wmask = _col_masks(off_ref[0, 0], j, bv, v_local, vocab_size,
                              (w_ref.shape[0], bv), 1)
        w_eff = jnp.where(wmask, w_ref[...].astype(jnp.float32), 0.0)
        contract = (((1,), (1,)), ((), ()))
    acc_ref[...] += jax.lax.dot_general(
        dlog, w_eff, contract, preferred_element_type=jnp.float32)

    @pl.when(j == n_v_tiles - 1)
    def _emit():
        dh_ref[...] = acc_ref[...].astype(dh_ref.dtype)


def xent_bwd_dh(h, w, labels, lse, gl, *, vocab_size: int, col_offset=0,
                block=None, interpret: bool = True, out_dtype=jnp.float32,
                transposed: bool = False):
    """dH (N, D): gl-weighted (softmax - onehot) contracted with w.

    ``gl`` (N,) f32 is the per-token upstream cotangent (already 0 for
    masked labels); ``lse`` the forward's (globally combined) logsumexp.
    Under vocab sharding the result is a partial sum over local columns —
    the caller psums it over the vocab mesh axes. ``transposed``: w is the
    tied (V, D) embedding.
    """
    n, d = h.shape
    v = w.shape[0] if transposed else w.shape[1]
    bn, bv = _pick_blocks(n, d, v, block, el_bytes=h.dtype.itemsize,
                          row_acc=True)
    grid = (pl.cdiv(n, bn), pl.cdiv(v, bv))
    off = jnp.asarray(col_offset, jnp.int32).reshape(1, 1)
    tok = pl.BlockSpec((bn, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_dh_kernel, n_v_tiles=grid[1], bv=bv, v_local=v,
                          vocab_size=vocab_size, wt=transposed),
        grid=grid,
        in_specs=[pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
                  _w_spec(d, bv, transposed),
                  tok, tok, tok,
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((bn, d), jnp.float32)],
        interpret=interpret,
    )(h, w, labels.reshape(n, 1), lse.reshape(n, 1), gl.reshape(n, 1), off)


# --------------------------------------------------------------------------
# backward: dW tile resident while token tiles stream (transposed grid)
# --------------------------------------------------------------------------

def _dw_kernel(w_ref, h_ref, lab_ref, lse_ref, gl_ref, off_ref, dw_ref,
               acc_ref, *, n_t_tiles, bn, bv, v_local, n_tokens, vocab_size,
               wt):
    j, i = pl.program_id(0), pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    logits = _logit_tile(h_ref, w_ref, wt)
    col, vmask = _col_masks(off_ref[0, 0], j, bv, v_local, vocab_size,
                            logits.shape, 1)
    row = i * bn + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    tokmask = row < n_tokens
    p = jnp.where(vmask & tokmask, jnp.exp(logits - lse_ref[...]), 0.0)
    # token-remainder rows carry undefined lse/gl; unlike forward/dH the
    # token axis is contracted here, so both operands are zeroed past N
    dlog = jnp.where(tokmask,
                     (p - jnp.where((col == lab_ref[...]) & vmask, 1.0, 0.0))
                     * gl_ref[...], 0.0)
    hrow = i * bn + jax.lax.broadcasted_iota(jnp.int32, h_ref.shape, 0)
    h_eff = jnp.where(hrow < n_tokens, h_ref[...].astype(jnp.float32), 0.0)
    if wt:
        # (V, D)-layout accumulator: dW[v, :] = sum_n dlog[n, v] * h[n, :]
        # — invalid vocab lanes have dlog exactly 0, so their rows stay 0
        acc_ref[...] += jax.lax.dot_general(
            dlog, h_eff, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        acc_ref[...] += jax.lax.dot_general(
            h_eff, dlog, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == n_t_tiles - 1)
    def _emit():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def xent_bwd_dw(h, w, labels, lse, gl, *, vocab_size: int, col_offset=0,
                block=None, interpret: bool = True, out_dtype=jnp.float32,
                transposed: bool = False):
    """dW: h^T contracted with the gl-weighted (softmax - onehot).

    Emitted in w's own layout — (D, V), or (V, D) when ``transposed`` so
    the tied head's gradient lands directly on the embedding storage.
    Under batch sharding the result is a partial sum over local tokens —
    the caller psums it over the token mesh axes.
    """
    n, d = h.shape
    v = w.shape[0] if transposed else w.shape[1]
    bn, bv = _pick_blocks(n, d, v, block, el_bytes=h.dtype.itemsize)
    grid = (pl.cdiv(v, bv), pl.cdiv(n, bn))
    off = jnp.asarray(col_offset, jnp.int32).reshape(1, 1)
    tok = pl.BlockSpec((bn, 1), lambda j, i: (i, 0))
    wspec = _w_spec(d, bv, transposed, transpose_grid=True)
    return pl.pallas_call(
        functools.partial(_dw_kernel, n_t_tiles=grid[1], bn=bn, bv=bv,
                          v_local=v, n_tokens=n, vocab_size=vocab_size,
                          wt=transposed),
        grid=grid,
        in_specs=[wspec,
                  pl.BlockSpec((bn, d), lambda j, i: (i, 0)),
                  tok, tok, tok,
                  pl.BlockSpec((1, 1), lambda j, i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=wspec,
        out_shape=jax.ShapeDtypeStruct((v, d) if transposed else (d, v),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((bv, d) if transposed else (d, bv),
                                   jnp.float32)],
        interpret=interpret,
    )(w, h, labels.reshape(n, 1), lse.reshape(n, 1), gl.reshape(n, 1), off)
