# Fused LM-head cross-entropy kernels. As with the optimizer-update
# packages, `xent.py` holds the Pallas kernels and `ref.py` the pure-jnp
# oracle; `repro.kernels.dispatch` owns routing (backend/mode selection,
# the coverage matrix, shard_map plans) — import that, not this.
