"""Pure-jnp oracle for the fused LM-head cross-entropy.

Materializes the full (N, V) logit matrix, so it is a *test-scale* oracle:
the memory-safe jnp fallback for training is the chunked scan in
``repro.models.model.lm_loss``, which stays the bitwise reference for
``REPRO_FUSED=off``. Padded vocab columns are masked to -1e9 exactly like
``models.model._mask_pad_vocab`` (exp(-1e9 - max) underflows to 0 in f32,
so "mask to -1e9 and include" equals the kernels' "exclude via iota mask").

Everything is differentiable: parity tests take ``jax.grad`` of these
functions to pin dH/dW for the backward kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = -1e9


def logits_masked(h: jnp.ndarray, w: jnp.ndarray,
                  vocab_size: int) -> jnp.ndarray:
    """f32 logits (..., V) with padded-vocab columns masked to -1e9."""
    logits = h.astype(jnp.float32) @ w.astype(jnp.float32)
    if vocab_size == w.shape[-1]:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < vocab_size, logits, jnp.float32(NEG))


def lse_ll(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
           vocab_size: int):
    """Per-token (logsumexp, label-logit); ll is 0 for masked (-1) labels.

    h (..., D), w (D, V), labels (...) int32 -> two f32 arrays of
    labels.shape. Matches what the forward kernel emits per vocab shard.
    """
    logits = logits_masked(h, w, vocab_size)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[..., None], axis=-1)[..., 0]
    return lse, jnp.where(labels >= 0, ll, 0.0)


def losses(h: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
           vocab_size: int) -> jnp.ndarray:
    """Per-token cross-entropy, 0 for masked (-1) labels; f32.

    Differentiable in (h, w): the value AND gradient contract the fused
    ``dispatch.xent_loss`` must reproduce (masked tokens contribute no
    gradient — the mask sits inside, not on a caller-side weight).
    """
    lse, ll = lse_ll(h, w, labels, vocab_size)
    return jnp.where(labels >= 0, lse - ll, 0.0)
