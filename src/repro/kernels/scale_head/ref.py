"""Pure-jnp oracle for the fused momentum + norm (LM-head) update."""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8

_RED = {"col": -2, "row": -1}


def momentum_norm(m: jnp.ndarray, g: jnp.ndarray, beta, axis: str = "col",
                  eps: float = EPS):
    """m' = beta*m + (1-beta)*g ; d = m'/(||m'||+eps). Returns (m', d)."""
    beta = jnp.asarray(beta, jnp.float32)
    m_new = beta * m.astype(jnp.float32) + (1.0 - beta) * g.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(m_new * m_new, axis=_RED[axis], keepdims=True))
    return m_new, m_new / (norms + eps)


def momentum_norm_update(theta: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray,
                         beta, lr, axis: str = "col", eps: float = EPS):
    """Full fused momentum step. Returns (theta', m')."""
    m_new, d = momentum_norm(m, g, beta, axis, eps)
    theta_new = (theta.astype(jnp.float32)
                 - jnp.asarray(lr, jnp.float32) * d).astype(theta.dtype)
    return theta_new, m_new


# Legacy column-wise names (tests / older call sites).

def momentum_colnorm(m, g, beta, eps: float = EPS):
    return momentum_norm(m, g, beta, "col", eps)


def head_update(theta, m, g, beta, lr, eps: float = EPS):
    return momentum_norm_update(theta, m, g, beta, lr, "col", eps)
