"""Pure-jnp oracle for the fused LM-head momentum + column-norm update."""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def momentum_colnorm(m: jnp.ndarray, g: jnp.ndarray, beta,
                     eps: float = EPS):
    """m_new = beta*m + (1-beta)*g ; d = colnorm(m_new). Returns (m_new, d)."""
    beta = jnp.asarray(beta, jnp.float32)
    m_new = beta * m.astype(jnp.float32) + (1.0 - beta) * g.astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(m_new * m_new, axis=0, keepdims=True))
    return m_new, m_new / (norms + eps)


def head_update(theta: jnp.ndarray, m: jnp.ndarray, g: jnp.ndarray, beta, lr,
                eps: float = EPS):
    """Full fused head step. Returns (theta_new, m_new)."""
    m_new, d = momentum_colnorm(m, g, beta, eps)
    theta_new = (theta.astype(jnp.float32)
                 - jnp.asarray(lr, jnp.float32) * d).astype(theta.dtype)
    return theta_new, m_new
