"""Pallas TPU kernels for the fused SCALE LM-head update.

The LM head is the only stateful matrix in SCALE (first-order momentum).
Its step streams four HBM tensors (theta, m, g -> theta', m'); the naive
sequence (EMA, colnorm, axpy) makes ~7 passes. Fused here into two:

  * ``momentum_sumsq`` — writes m' = beta*m + (1-beta)*g tile-by-tile while
    accumulating sum(m'^2) per column in VMEM scratch (rows innermost grid
    axis -> sequential accumulation), emitting (1, n) sums once per column
    tile. One read of m and g, one write of m'.
  * ``head_update_apply`` — theta' = theta - lr * m'/(||col m'||+eps):
    one read of theta and m', one write of theta'.

The vocab dimension of an LM head is always a multiple of 128 (configs pad),
so tiles stay MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 256)


def _momentum_sumsq_kernel(m_ref, g_ref, beta_ref, m_out_ref, ss_ref, acc_ref,
                           *, n_row_tiles: int):
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    beta = beta_ref[0, 0]
    m_new = beta * m_ref[...].astype(jnp.float32) + \
        (1.0 - beta) * g_ref[...].astype(jnp.float32)
    m_out_ref[...] = m_new.astype(m_out_ref.dtype)
    acc_ref[...] += jnp.sum(m_new * m_new, axis=0, keepdims=True)

    @pl.when(i == n_row_tiles - 1)
    def _emit():
        ss_ref[...] = acc_ref[...]


def momentum_sumsq(m, g, beta, block=DEFAULT_BLOCK, interpret: bool = True):
    mm, n = m.shape
    bm, bn = min(block[0], mm), min(block[1], n)
    assert mm % bm == 0 and n % bn == 0, (m.shape, block)
    grid = (n // bn, mm // bm)
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_momentum_sumsq_kernel, n_row_tiles=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((1, 1), lambda j, i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                   pl.BlockSpec((1, bn), lambda j, i: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((mm, n), jnp.float32),
                   jax.ShapeDtypeStruct((1, n), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
    )(m, g, beta_arr)


def _head_update_kernel(theta_ref, m_ref, ss_ref, lr_ref, out_ref, *, eps: float):
    norm = jnp.sqrt(ss_ref[...]) + eps
    upd = theta_ref[...].astype(jnp.float32) - \
        lr_ref[0, 0] * m_ref[...].astype(jnp.float32) / norm
    out_ref[...] = upd.astype(out_ref.dtype)


def head_update_apply(theta, m_new, ss, lr, block=DEFAULT_BLOCK,
                      eps: float = 1e-8, interpret: bool = True):
    mm, n = theta.shape
    bm, bn = min(block[0], mm), min(block[1], n)
    grid = (n // bn, mm // bm)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_head_update_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((1, bn), lambda j, i: (0, j)),
                  pl.BlockSpec((1, 1), lambda j, i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, n), theta.dtype),
        interpret=interpret,
    )(theta, m_new, ss, lr_arr)
