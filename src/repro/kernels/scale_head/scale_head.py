"""Pallas TPU kernels for the fused SCALE momentum (LM-head) update.

Momentum-carrying matrices (by default only the LM head) are the stateful
part of SCALE. The naive sequence (EMA, norm, axpy) makes ~7 HBM passes over
theta/m/g; fused here into two kernels:

  * ``momentum_sumsq`` — writes m' = beta*m + (1-beta)*g tile-by-tile while
    accumulating sum(m'^2) along the reduce axis in VMEM scratch (reduce
    axis innermost in the grid -> sequential accumulation), emitting the
    sums-of-squares once per output tile. One read of m and g, one write
    of m'.
  * the apply step reuses :func:`repro.kernels.colnorm.colnorm.update_apply`
    (theta' = theta - lr * m'/(||m'||+eps)): one read of theta and m', one
    write of theta'.

Same coverage as the colnorm kernels: 2-D or stacked 3-D params, ``col`` or
``row`` reduce axis, arbitrary (non-tile-divisible) shapes via cdiv grids +
iota remainder masks. Remainder masking matters twice here: padded lanes of
m'/g are undefined, so they are excluded from the accumulator (the m' write
itself is clipped by Pallas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..colnorm.colnorm import DEFAULT_BLOCK, _blocks, _red_mask, update_apply

__all__ = ["DEFAULT_BLOCK", "momentum_sumsq", "head_update_apply"]


def _momentum_sumsq_kernel(m_ref, g_ref, beta_ref, gs_ref, m_out_ref, ss_ref,
                           acc_ref, *, n_red_tiles, red_dim, red_block,
                           red_axis):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    beta = beta_ref[0, 0]
    m_new = beta * m_ref[0].astype(jnp.float32) + \
        (1.0 - beta) * (g_ref[0].astype(jnp.float32) * gs_ref[0, 0])
    m_out_ref[0] = m_new.astype(m_out_ref.dtype)
    masked = jnp.where(
        _red_mask(m_new.shape, i, red_block, red_dim, red_axis), m_new, 0.0)
    acc_ref[...] += jnp.sum(masked * masked, axis=red_axis, keepdims=True)

    @pl.when(i == n_red_tiles - 1)
    def _emit():
        ss_ref[0] = acc_ref[...]


def momentum_sumsq(m, g, beta, axis: str = "col", block=DEFAULT_BLOCK,
                   interpret: bool = True, gscale=1.0):
    """(m', ss): m' = beta*m + (1-beta)*gscale*g, ss = sumsq(m') along axis.

    m, g: (L, mm, n). Returns m' (L, mm, n) in **m's dtype** (the momentum
    storage dtype — bf16 under ``scale(momentum_dtype="bfloat16")``) and ss
    (L, 1, n) for col / (L, mm, 1) for row, f32. The EMA and the
    sums-of-squares are computed in f32; only the emitted m' is rounded
    (cast-on-write). ``gscale`` folds the trainer's grad-clip factor into
    the EMA read (see colnorm kernel docs). m is aliased to m' so the
    momentum write is in-place under buffer donation.
    """
    L, mm, n = m.shape
    bm, bn = _blocks(mm, n, block)
    tile = pl.BlockSpec((1, bm, bn), lambda l, j, i: (l, i, j))
    if axis == "col":
        grid = (L, pl.cdiv(n, bn), pl.cdiv(mm, bm))
        ss_spec = pl.BlockSpec((1, 1, bn), lambda l, j, i: (l, 0, j))
        ss_shape = jax.ShapeDtypeStruct((L, 1, n), jnp.float32)
        scratch = pltpu.VMEM((1, bn), jnp.float32)
        red_dim, red_block, red_axis = mm, bm, 0
    elif axis == "row":
        grid = (L, pl.cdiv(mm, bm), pl.cdiv(n, bn))
        tile = pl.BlockSpec((1, bm, bn), lambda l, j, i: (l, j, i))
        ss_spec = pl.BlockSpec((1, bm, 1), lambda l, j, i: (l, j, 0))
        ss_shape = jax.ShapeDtypeStruct((L, mm, 1), jnp.float32)
        scratch = pltpu.VMEM((bm, 1), jnp.float32)
        red_dim, red_block, red_axis = n, bn, 1
    else:
        raise ValueError(f"axis must be 'col' or 'row', got {axis!r}")
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1, 1)
    gs_arr = jnp.asarray(gscale, jnp.float32).reshape(1, 1)
    smem = pl.BlockSpec((1, 1), lambda l, j, i: (0, 0),
                        memory_space=pltpu.SMEM)
    return pl.pallas_call(
        functools.partial(_momentum_sumsq_kernel, n_red_tiles=grid[2],
                          red_dim=red_dim, red_block=red_block,
                          red_axis=red_axis),
        grid=grid,
        in_specs=[tile, tile, smem, smem],
        out_specs=[tile, ss_spec],
        out_shape=[jax.ShapeDtypeStruct((L, mm, n), m.dtype), ss_shape],
        input_output_aliases={0: 0},
        scratch_shapes=[scratch],
        interpret=interpret,
    )(m, g, beta_arr, gs_arr)


def head_update_apply(theta, m_new, ss, lr, axis: str = "col",
                      block=DEFAULT_BLOCK, eps: float = 1e-8,
                      interpret: bool = True):
    """theta - lr * m'/(sqrt(ss)+eps); shares the colnorm apply kernel
    (theta aliased in-place, no gscale — the clip factor already entered
    through the momentum EMA)."""
    return update_apply(theta, m_new, ss, lr, axis, block, eps, interpret)
