"""Fused momentum (LM-head) entry points, routed through
:mod:`repro.kernels.dispatch` (which owns backend selection and coverage
fallbacks). Kept as thin aliases for existing call sites.
"""
from __future__ import annotations

from .. import dispatch as _d


def momentum_colnorm(m, g, beta, eps: float = 1e-8):
    """(m', colnorm(m')) via the fused kernel."""
    return _d.momentum_norm(m, g, beta, "col", eps)


def head_update(theta, m, g, beta, lr, eps: float = 1e-8):
    """Fully fused LM-head step. Returns (theta', m')."""
    return _d.momentum_norm_update(theta, m, g, beta, lr, "col", eps)
