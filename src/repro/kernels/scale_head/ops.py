"""Jitted wrappers for the fused LM-head SCALE update."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from . import scale_head as K


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _tileable(shape) -> bool:
    if len(shape) != 2:
        return False
    m, n = shape
    return m % min(K.DEFAULT_BLOCK[0], m) == 0 and \
        n % min(K.DEFAULT_BLOCK[1], n) == 0 and m >= 8 and n >= 128


@functools.partial(jax.jit, static_argnames=("eps",))
def momentum_colnorm(m, g, beta, eps: float = 1e-8):
    """(m_new, colnorm(m_new)) via the fused kernel."""
    if not _tileable(m.shape):
        return ref.momentum_colnorm(m, g, beta, eps)
    interp = not _on_tpu()
    m_new, ss = K.momentum_sumsq(m, g, beta, interpret=interp)
    d = (m_new / (jnp.sqrt(ss) + eps))
    return m_new, d


@functools.partial(jax.jit, static_argnames=("eps",))
def head_update(theta, m, g, beta, lr, eps: float = 1e-8):
    """Fully fused LM-head step. Returns (theta_new, m_new)."""
    if not _tileable(theta.shape):
        return ref.head_update(theta, m, g, beta, lr, eps)
    interp = not _on_tpu()
    m_new, ss = K.momentum_sumsq(m, g, beta, interpret=interp)
    theta_new = K.head_update_apply(theta, m_new, ss, lr, eps=eps,
                                    interpret=interp)
    return theta_new, m_new
