"""Pallas TPU kernels for fused blockwise (flash) attention.

Attention is the last major hot path of a training step that still runs as
a jnp ``lax.scan`` over tile pairs (``models.layers.flash_attention``):
correct and memory-O(tile), but each scan step round-trips the f32
output/max/sum carries through HBM block slices and — for GQA — consumes a
kv tree *repeated* to the full query head count. These kernels keep the
flash algorithm and move everything tile-resident:

  * ``mha_fwd`` — grid (batch*heads, q tiles, kv tiles), kv innermost.
    Each step computes one (bq, bk) score tile on the MXU and folds it
    into the running online-softmax carries (max, sum, f32 output
    accumulator) held in VMEM scratch; the normalized output and per-row
    ``lse`` are emitted once at the last kv tile. The f32 carries never
    touch HBM, and peak score storage is one (bq, bk) VMEM tile —
    independent of S, T and the head count (the scan's einsum materializes
    the (B, H, bq, bk) tile across *all* heads at once).
  * ``mha_bwd_dq`` — same tiling; recomputes the score tile from
    (q, k, lse), forms ``ds = p * (dp - D) * scale`` in registers and
    accumulates ``ds @ k_tile`` into a (bq, hd) scratch, one dQ write per
    q tile.
  * ``mha_bwd_dkv`` — transposed grid (batch*kv_heads, kv tiles, group,
    q tiles): the (bk, hd)/(bk, hdv) dK/dV tiles stay resident in scratch
    while all q tiles *of every query head in the group* stream by — the
    GQA group reduction happens in VMEM, so dK/dV are emitted directly in
    the (B, T, K, hd) storage layout (the scan repeats kv up front and
    pays G-times the kv traffic in both directions).

GQA is native: kv BlockSpecs index the kv head as ``q_head // group``
(forward/dQ) or iterate the group on the grid (dK/dV) — the H/K repeat is
never materialized.

Masking is described by one :class:`~repro.kernels.attention.mask.MaskSpec`
(see that module): rectangular causal with the static offset ``T - S``
folded into the tile iota, the traced ``kv_len`` cache-fill bound, and —
for packed multi-document batches — per-position **segment ids**. Segment
ids ride as two int32 operands blocked alongside q and kv: the query tile
sees a (bq, 1) column and the key tile a (1, bk) row, whose broadcasted
equality intersects the causal/bound clauses elementwise. Tile pairs that
are fully masked skip their compute entirely via ``pl.when`` — above the
causal diagonal, past ``kv_len`` (decode over a mostly empty cache touches
only the filled tiles), or when the two tiles' segment-id *ranges* don't
overlap (packed documents are contiguous, so segment ids are sorted per
row and a min/max range test is exact for interior tiles).

Masking mirrors the xent kernels' conventions (out-of-bounds block regions
are undefined — NaN in interpret mode — and 0*NaN = NaN, so *both*
operands of every contraction are zeroed on padded positions):

  * remainder kv tiles (T % bk): score columns past T are masked to the
    finite ``_NEG`` stand-in and k/v rows past T are zeroed before any
    contraction that consumes them; segment-id lanes past the bounds are
    pushed out of the tile-skip min/max reductions;
  * remainder q tiles (S % bq): forward/dQ rows are independent and
    clipped on write; dK/dV zero q/dout rows and ``p``/``ds`` rows past S
    before the row contraction;
  * fully-masked rows (``kv_len`` 0, nothing valid, or — with segments —
    a pad row whose segment id appears nowhere in the keys) emit 0 output
    via the ``max(l, 1e-30)`` clamp — the same convention as the jnp scan
    — and a ~-1e30 ``lse``, which makes their backward contributions
    vanish.

Layout: the public entry points take the model's (B, S, H, hd) activation
layout and transpose to the kernels' (B, H, S, hd) so the sequence tile is
the sublane dimension (one XLA transpose each way; the grid then indexes
4-D blocks of shape (1, 1, tile, hd)). ``kv_len`` is a traced SMEM scalar;
segment ids are (B, S, 1)/(B, 1, T) int32 VMEM blocks (a zero-size dummy
pair keeps the kernel arity fixed when the spec has no segment clause).
All softmax statistics and accumulators are f32; probability tiles are
cast to the value dtype for the MXU contraction exactly like the scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .mask import MaskSpec, mask_spec

_NEG = -1e30  # finite -inf stand-in: keeps the running max NaN-free when a
#               tile (or a whole row) is entirely masked
_SEG_BIG = 2 ** 30  # out-of-bounds stand-in for segment-id min/max ranges


def _pick_tiles(S: int, T: int, hd: int, hdv: int, block=None, *,
                el_bytes: int = 4):
    """(bq, bk) tile for one kernel, clamped to the (padded) problem.

    Both tiles grow until the per-step VMEM working set — q/k/v blocks,
    the f32 (bq, bk) score tile, and the f32 output/dQ accumulator —
    reaches ~8 MiB, shrinking bk first (k/v stream per q tile; a bigger bq
    is the HBM-reuse lever). Caps at 512, floors at the (8, 128) hardware
    tiling; the clamp keeps tiny problems to a single tile.
    """
    if block is not None:
        bq, bk = block
    else:
        bq = bk = 512

        def cost(bq, bk):
            return ((bq + bk) * hd + bk * hdv) * el_bytes \
                + (bq * bk + bq * hdv + bq * hd) * 4

        while cost(bq, bk) > (8 << 20) and bk > 128:
            bk //= 2
        while cost(bq, bk) > (8 << 20) and bq > 128:
            bq //= 2
    bq = min(bq, -(-S // 8) * 8)
    bk = min(bk, -(-T // 128) * 128)
    return bq, bk


def _run_pair(i, j, bq, bk, spec: MaskSpec, kl, qseg, kseg, s_len: int,
              t_len: int):
    """Traced predicate: does tile pair (i, j) contain any valid position?

    False above the rectangular-causal diagonal (the last query row of
    tile i, at global position ``offset + (i+1)*bq - 1``, sits before the
    first key of tile j), entirely past the ``kv_len`` fill bound, or —
    with segments — when the two tiles' segment-id ranges don't intersect
    (ids are sorted within a packed row, so range overlap is exact for
    interior tiles and conservative on remainder tiles). Skipped pairs run
    no MXU work at all.
    """
    run = j * bk < kl
    if spec.causal:
        run &= spec.offset + (i + 1) * bq - 1 >= j * bk
    if spec.has_segments:
        # lanes past the real bounds hold undefined memory: push them out
        # of the min/max before reducing so the predicate stays sound
        qrows = i * bq + jax.lax.broadcasted_iota(jnp.int32, qseg.shape, 0)
        kcols = j * bk + jax.lax.broadcasted_iota(jnp.int32, kseg.shape, 1)
        q_lo = jnp.min(jnp.where(qrows < s_len, qseg, _SEG_BIG))
        q_hi = jnp.max(jnp.where(qrows < s_len, qseg, -_SEG_BIG))
        k_lo = jnp.min(jnp.where(kcols < t_len, kseg, _SEG_BIG))
        k_hi = jnp.max(jnp.where(kcols < t_len, kseg, -_SEG_BIG))
        run &= (q_lo <= k_hi) & (k_lo <= q_hi)
    return run


def _masks(i, j, bq, bk, spec: MaskSpec, kl, qseg, kseg, s_len: int,
           t_len: int):
    """(col validity, row validity) (bq, bk) masks for one score tile."""
    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    valid = (cols < t_len) & (cols < kl)
    if spec.causal:
        valid &= spec.offset + rows >= cols
    if spec.has_segments:
        valid &= qseg == kseg  # (bq, 1) == (1, bk) -> (bq, bk)
    return valid, rows < s_len


def _seg_blocks(spec: MaskSpec, segments, B, S, T, bq, bk, H_or_K, *,
                dkv: bool = False):
    """(q_seg array, kv_seg array, q BlockSpec, kv BlockSpec) operand pack.

    Segment ids enter as (B, S)/(B, T) int32 and are viewed as
    (B, S, 1)/(B, 1, T) so the query tile blocks to (1, bq, 1) — a column
    the score-tile mask broadcasts against the key tile's (1, 1, bk) row.
    Without segments a zero-size dummy pair keeps the pallas arity fixed
    (one int32 element of traffic, no reads).
    """
    if dkv:
        qmap = lambda b, j, g, i: (b // H_or_K, i, 0)
        kmap = lambda b, j, g, i: (b // H_or_K, 0, j)
        zmap = lambda b, j, g, i: (0, 0, 0)
    else:
        qmap = lambda b, i, j: (b // H_or_K, i, 0)
        kmap = lambda b, i, j: (b // H_or_K, 0, j)
        zmap = lambda b, i, j: (0, 0, 0)
    if not spec.has_segments:
        dummy = jnp.zeros((1, 1, 1), jnp.int32)
        return dummy, dummy, pl.BlockSpec((1, 1, 1), zmap), \
            pl.BlockSpec((1, 1, 1), zmap)
    q_seg, kv_seg = segments
    qs = q_seg.astype(jnp.int32).reshape(B, S, 1)
    ks = kv_seg.astype(jnp.int32).reshape(B, 1, T)
    return qs, ks, pl.BlockSpec((1, bq, 1), qmap), pl.BlockSpec((1, 1, bk),
                                                                kmap)


def _resolve_spec(spec, S, T, causal, kv_len, segments):
    # A trivial kv_len operand (the dispatch layer always threads the kl
    # scalar, defaulting it to T) is fine against has_kv_len=False — the
    # kernels treat kl as a universal key bound. The other direction, and
    # any segment mismatch, means the caller built the spec for different
    # operands.
    if spec is None:
        return mask_spec(S, T, causal=causal, kv_len=kv_len,
                         segments=segments)
    if (spec.has_kv_len and kv_len is None) or \
            spec.has_segments != (segments is not None):
        raise ValueError(f"traced operands do not match {spec}")
    return spec


def _zero_invalid_rows(ref, j, bk, t_len: int):
    """k/v block with undefined rows past T zeroed (remainder kv tiles)."""
    rows = j * bk + jax.lax.broadcasted_iota(jnp.int32, ref.shape[2:], 0)
    return jnp.where(rows < t_len, ref[0, 0], 0)


def _sdot(a, b):
    """(bq, d) x (bk, d) -> (bq, bk) f32 score-style contraction."""
    return jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _tdot(a, b):
    """(bq, bk) x (bq, d) -> (bk, d) f32 row (token) contraction."""
    return jax.lax.dot_general(a, b, (((0,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# forward: online softmax over kv tiles, carries in VMEM scratch
# --------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, kl_ref, qs_ref, ks_ref, o_ref, lse_ref,
                m_acc, l_acc, acc, *, scale, spec, bq, bk,
                n_k_tiles, s_len, t_len):
    i, j = pl.program_id(1), pl.program_id(2)
    kl = kl_ref[0, 0]
    qseg = qs_ref[0] if spec.has_segments else None
    kseg = ks_ref[0] if spec.has_segments else None

    @pl.when(j == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, _NEG)
        l_acc[...] = jnp.zeros_like(l_acc)
        acc[...] = jnp.zeros_like(acc)

    @pl.when(_run_pair(i, j, bq, bk, spec, kl, qseg, kseg, s_len, t_len))
    def _compute():
        s = _sdot(q_ref[0, 0], k_ref[0, 0]) * scale
        valid, _ = _masks(i, j, bq, bk, spec, kl, qseg, kseg, s_len, t_len)
        s = jnp.where(valid, s, _NEG)
        m_new = jnp.maximum(m_acc[...], jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_acc[...] - m_new)
        # explicit mask on the exp: with everything pinned at _NEG the
        # difference is 0 and exp would contribute 1 per masked column
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_acc[...] = l_acc[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_acc[...] = m_new
        v_eff = _zero_invalid_rows(v_ref, j, bk, t_len)
        acc[...] = acc[...] * alpha + jnp.dot(
            p.astype(v_eff.dtype), v_eff, preferred_element_type=jnp.float32)

    @pl.when(j == n_k_tiles - 1)
    def _emit():
        l = jnp.maximum(l_acc[...], 1e-30)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = m_acc[...] + jnp.log(l)


def mha_fwd(q, k, v, kv_len=None, *, scale: float, causal: bool = True,
            segments=None, spec: MaskSpec | None = None, block=None,
            interpret: bool = True):
    """(out, lse): q (B, S, H, hd); k (B, T, K, hd), v (B, T, K, hdv).

    H % K == 0 (kv blocks are indexed by ``q_head // group`` — the repeat
    is never materialized). Masking comes from ``spec`` (built from the
    ``causal``/``kv_len``/``segments`` operands when not given).
    ``kv_len`` (traced int, default T) bounds the valid key positions; at
    this layer it simply intersects whatever causal mask is active (the
    dispatch entry rejects causal + kv_len — the anchored-at-T causal
    offset is not the causal-over-fill a caller might expect).
    ``segments`` is a ((B, S), (B, T)) int32 pair; positions with
    differing ids never attend. Returns out (B, S, H, hdv) in q's dtype
    and lse (B, H, S) f32 — the combined max+log-sum the backward kernels
    (and a future cross-shard softmax combine) consume.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // K
    spec = _resolve_spec(spec, S, T, causal, kv_len, segments)
    bq, bk = _pick_tiles(S, T, hd, hdv, block, el_bytes=q.dtype.itemsize)
    grid = (B * H, pl.cdiv(S, bq), pl.cdiv(T, bk))
    kl = jnp.asarray(T if kv_len is None else kv_len,
                     jnp.int32).reshape(1, 1)
    qs, ks, qs_spec, ks_spec = _seg_blocks(spec, segments, B, S, T, bq, bk, H)
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, spec=spec,
                          bq=bq, bk=bk, n_k_tiles=grid[2],
                          s_len=S, t_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bh, i, j: (bh // H, bh % H, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda bh, i, j: (bh // H, (bh % H) // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hdv), lambda bh, i, j: (bh // H, (bh % H) // G, j, 0)),
            pl.BlockSpec((1, 1), lambda bh, i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            qs_spec, ks_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hdv), lambda bh, i, j: (bh // H, bh % H, i, 0)),
            pl.BlockSpec((1, 1, bq, 1), lambda bh, i, j: (bh // H, bh % H, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, H, S, hdv), q.dtype),
                   jax.ShapeDtypeStruct((B, H, S, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hdv), jnp.float32)],
        interpret=interpret,
    )(qt, kt, vt, kl, qs, ks)
    return jnp.swapaxes(out, 1, 2), lse[..., 0]


# --------------------------------------------------------------------------
# backward dQ: recompute score tiles, dQ accumulator resident per q tile
# --------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, kl_ref, qs_ref,
               ks_ref, dq_ref, acc, *, scale, spec, bq, bk, n_k_tiles,
               s_len, t_len):
    i, j = pl.program_id(1), pl.program_id(2)
    kl = kl_ref[0, 0]
    qseg = qs_ref[0] if spec.has_segments else None
    kseg = ks_ref[0] if spec.has_segments else None

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    @pl.when(_run_pair(i, j, bq, bk, spec, kl, qseg, kseg, s_len, t_len))
    def _compute():
        s = _sdot(q_ref[0, 0], k_ref[0, 0]) * scale
        valid, _ = _masks(i, j, bq, bk, spec, kl, qseg, kseg, s_len, t_len)
        p = jnp.where(valid, jnp.exp(s - lse_ref[0, 0]), 0.0)
        v_eff = _zero_invalid_rows(v_ref, j, bk, t_len)
        dp = _sdot(do_ref[0, 0], v_eff)
        ds = p * (dp - d_ref[0, 0]) * scale
        k_eff = _zero_invalid_rows(k_ref, j, bk, t_len)
        acc[...] += jnp.dot(ds.astype(k_eff.dtype), k_eff,
                            preferred_element_type=jnp.float32)

    @pl.when(j == n_k_tiles - 1)
    def _emit():
        dq_ref[0, 0] = acc[...].astype(dq_ref.dtype)


def mha_bwd_dq(q, k, v, dout, lse, delta, kv_len=None, *, scale: float,
               causal: bool = True, segments=None,
               spec: MaskSpec | None = None, block=None,
               interpret: bool = True):
    """dQ (B, S, H, hd) in q's dtype.

    ``lse`` (B, H, S) is the forward's log-sum-exp; ``delta`` (B, H, S)
    f32 is ``sum(dout * out, -1)`` — both row vectors stream as (bq, 1)
    blocks. Rows past S carry undefined statistics; their NaNs stay on
    independent rows and are clipped on write.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // K
    spec = _resolve_spec(spec, S, T, causal, kv_len, segments)
    bq, bk = _pick_tiles(S, T, hd, hdv, block, el_bytes=q.dtype.itemsize)
    grid = (B * H, pl.cdiv(S, bq), pl.cdiv(T, bk))
    kl = jnp.asarray(T if kv_len is None else kv_len,
                     jnp.int32).reshape(1, 1)
    qs, ks, qs_spec, ks_spec = _seg_blocks(spec, segments, B, S, T, bq, bk, H)
    row = pl.BlockSpec((1, 1, bq, 1), lambda bh, i, j: (bh // H, bh % H, i, 0))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, spec=spec,
                          bq=bq, bk=bk, n_k_tiles=grid[2],
                          s_len=S, t_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bh, i, j: (bh // H, bh % H, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda bh, i, j: (bh // H, (bh % H) // G, j, 0)),
            pl.BlockSpec((1, 1, bk, hdv), lambda bh, i, j: (bh // H, (bh % H) // G, j, 0)),
            pl.BlockSpec((1, 1, bq, hdv), lambda bh, i, j: (bh // H, bh % H, i, 0)),
            row, row,
            pl.BlockSpec((1, 1), lambda bh, i, j: (0, 0),
                         memory_space=pltpu.SMEM),
            qs_spec, ks_spec,
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda bh, i, j: (bh // H, bh % H, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
      jnp.swapaxes(dout, 1, 2), lse[..., None], delta[..., None], kl, qs, ks)
    return jnp.swapaxes(dq, 1, 2)


# --------------------------------------------------------------------------
# backward dK/dV: kv tile resident while (group x q) tiles stream
# --------------------------------------------------------------------------

def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, kl_ref, qs_ref,
                ks_ref, dk_ref, dv_ref, dk_acc, dv_acc, *, scale, spec,
                bq, bk, n_g, n_q_tiles, s_len, t_len):
    j, g, i = pl.program_id(1), pl.program_id(2), pl.program_id(3)
    kl = kl_ref[0, 0]
    qseg = qs_ref[0] if spec.has_segments else None
    kseg = ks_ref[0] if spec.has_segments else None

    @pl.when((g == 0) & (i == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(_run_pair(i, j, bq, bk, spec, kl, qseg, kseg, s_len, t_len))
    def _compute():
        # the q (token) axis is contracted here, so — unlike forward/dQ —
        # undefined remainder *rows* must be zeroed on both operand sides
        qrows = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                  q_ref.shape[2:], 0)
        q_eff = jnp.where(qrows < s_len, q_ref[0, 0], 0)
        dorows = i * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                   do_ref.shape[2:], 0)
        do_eff = jnp.where(dorows < s_len, do_ref[0, 0], 0)
        s = _sdot(q_eff, k_ref[0, 0]) * scale
        valid, rowmask = _masks(i, j, bq, bk, spec, kl, qseg, kseg, s_len,
                                t_len)
        # rows past S carry undefined lse/delta: fold the row bound into
        # the mask so p/ds are exactly 0 there (0 * NaN would poison the
        # whole dK/dV accumulator, not just one row)
        valid &= rowmask
        p = jnp.where(valid, jnp.exp(s - lse_ref[0, 0]), 0.0)
        dv_acc[...] += _tdot(p.astype(do_eff.dtype), do_eff)
        v_eff = _zero_invalid_rows(v_ref, j, bk, t_len)
        dp = _sdot(do_eff, v_eff)
        ds = jnp.where(valid, p * (dp - d_ref[0, 0]) * scale, 0.0)
        dk_acc[...] += _tdot(ds.astype(q_eff.dtype), q_eff)

    @pl.when((g == n_g - 1) & (i == n_q_tiles - 1))
    def _emit():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def mha_bwd_dkv(q, k, v, dout, lse, delta, kv_len=None, *, scale: float,
                causal: bool = True, segments=None,
                spec: MaskSpec | None = None, block=None,
                interpret: bool = True):
    """(dK, dV) in kv dtypes, emitted directly in the (B, T, K, hd|hdv)
    storage layout: the grid iterates (kv tiles, group, q tiles) with the
    dK/dV accumulators resident in VMEM, so the GQA reduction over the
    ``group`` query heads of each kv head never materializes a
    (B, T, H, hd)-sized gradient.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // K
    spec = _resolve_spec(spec, S, T, causal, kv_len, segments)
    bq, bk = _pick_tiles(S, T, hd, hdv, block, el_bytes=q.dtype.itemsize)
    grid = (B * K, pl.cdiv(T, bk), G, pl.cdiv(S, bq))
    kl = jnp.asarray(T if kv_len is None else kv_len,
                     jnp.int32).reshape(1, 1)
    qs, ks, qs_spec, ks_spec = _seg_blocks(spec, segments, B, S, T, bq, bk,
                                           K, dkv=True)
    qmap = lambda bk_, j, g, i: (bk_ // K, (bk_ % K) * G + g, i, 0)
    kvmap = lambda bk_, j, g, i: (bk_ // K, bk_ % K, j, 0)
    row = pl.BlockSpec((1, 1, bq, 1), qmap)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, spec=spec,
                          bq=bq, bk=bk, n_g=G,
                          n_q_tiles=grid[3], s_len=S, t_len=T),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), qmap),
            pl.BlockSpec((1, 1, bk, hd), kvmap),
            pl.BlockSpec((1, 1, bk, hdv), kvmap),
            pl.BlockSpec((1, 1, bq, hdv), qmap),
            row, row,
            pl.BlockSpec((1, 1), lambda bk_, j, g, i: (0, 0),
                         memory_space=pltpu.SMEM),
            qs_spec, ks_spec,
        ],
        out_specs=[pl.BlockSpec((1, 1, bk, hd), kvmap),
                   pl.BlockSpec((1, 1, bk, hdv), kvmap)],
        out_shape=[jax.ShapeDtypeStruct((B, K, T, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, K, T, hdv), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hdv), jnp.float32)],
        interpret=interpret,
    )(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
      jnp.swapaxes(dout, 1, 2), lse[..., None], delta[..., None], kl, qs, ks)
    return jnp.swapaxes(dk, 1, 2), jnp.swapaxes(dv, 1, 2)
