# Fused blockwise (flash) attention kernels. As with the optimizer-update
# and xent packages, `attention.py` holds the Pallas kernels and `ref.py`
# the pure-jnp oracle; `repro.kernels.dispatch` owns routing (backend/mode
# selection, the coverage matrix, shard_map plans) — import that, not this.
# The *production* jnp fallback is the `lax.scan` custom_vjp in
# `repro.models.layers.flash_attention` (bitwise pre-PR-5 path); ref.py is
# the test-scale full-softmax oracle.
