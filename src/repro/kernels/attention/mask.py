"""Unified attention mask specification.

Before this module, the attention stack threaded three ad-hoc masking
signals separately through ``models.layers``, the fused kernels and the
dispatch layer: a ``causal`` flag, the static rectangular offset ``T - S``
it implies, and a traced ``kv_len`` fill bound. Packed multi-document
batches add a fourth — per-position segment ids — and rather than a fourth
parallel plumbing run, every entry point now consumes one
:class:`MaskSpec`.

The split is **static vs traced**: ``MaskSpec`` holds only hashable Python
values (it keys ``lru_cache``d dispatch closures and rides through
``custom_vjp`` nondiff slots), while the traced operands it *describes* —
the ``kv_len`` scalar and the ``(B, S)``/``(B, T)`` segment-id arrays —
travel separately alongside q/k/v. ``has_kv_len``/``has_segments`` record
which traced operands are live so kernels can specialize their tile
machinery statically.

A position pair (query ``i``, key ``j``) is valid iff ALL live clauses
hold:

  * ``causal``:   ``offset + i >= j`` (rectangular causal; ``offset`` is
    ``T - S`` so ``T == S`` is ordinary causal and ``T > S`` a
    cached-prefill continuation);
  * ``kv_len``:   ``j < kv_len`` (decode over a partially filled cache);
  * ``segments``: ``q_seg[b, i] == kv_seg[b, j]`` (no cross-document
    attention in packed batches; pad positions carry segment id 0 and so
    form their own island — real tokens never attend pad and vice versa).

``segments`` and ``kv_len`` are mutually exclusive by construction
(packing is a train-time format, the fill bound a decode-time one);
:func:`mask_spec` enforces it.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp


class MaskSpec(NamedTuple):
    """Static (hashable) description of an attention mask.

    ``offset`` is only meaningful when ``causal``; it is pinned to 0
    otherwise so specs compare/hash canonically.
    """
    causal: bool = True
    offset: int = 0
    has_kv_len: bool = False
    has_segments: bool = False


def mask_spec(S: int, T: int, *, causal: bool = True, kv_len=None,
              segments=None) -> MaskSpec:
    """Canonical :class:`MaskSpec` for a (S query, T key) problem.

    ``kv_len`` / ``segments`` are the *traced* operands (or None); only
    their presence is recorded. Rejects the two combinations with no
    coherent semantics: causal with T < S (queries past the key range) and
    segments together with kv_len (packed train batches have no partial
    cache fill).
    """
    if causal and T < S:
        raise ValueError(f"causal attention needs T >= S, got S={S} T={T}")
    if segments is not None and kv_len is not None:
        raise ValueError("segments and kv_len are mutually exclusive "
                         "(packed batches have no cache-fill bound)")
    return MaskSpec(causal=bool(causal), offset=(T - S) if causal else 0,
                    has_kv_len=kv_len is not None,
                    has_segments=segments is not None)


def mask_array(spec: MaskSpec, S: int, T: int, *, kv_len=None,
               segments: Optional[Tuple] = None) -> jnp.ndarray:
    """Dense boolean validity mask for reference/oracle paths.

    Returns ``(1, S, T)`` when the spec has no segment clause (the mask is
    batch-invariant) and ``(B, S, T)`` with one. Traced operands must be
    passed iff the spec declares them.
    """
    if spec.has_kv_len != (kv_len is not None):
        raise ValueError("kv_len operand does not match spec.has_kv_len")
    if spec.has_segments != (segments is not None):
        raise ValueError("segments operand does not match spec.has_segments")
    valid = jnp.ones((1, S, T), bool)
    if spec.causal:
        qpos = spec.offset + jnp.arange(S)
        valid &= (qpos[:, None] >= jnp.arange(T)[None, :])[None]
    if spec.has_kv_len:
        valid &= (jnp.arange(T) < kv_len)[None, None, :]
    if spec.has_segments:
        q_seg, kv_seg = segments
        valid = valid & (q_seg[:, :, None] == kv_seg[:, None, :])
    return valid
