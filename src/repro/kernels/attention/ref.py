"""Pure-jnp oracle for fused flash attention.

Materializes the full (B, H, S, T) score matrix, so it is a *test-scale*
oracle: the memory-safe jnp fallback for training is the blockwise
``lax.scan`` in ``repro.models.layers.flash_attention`` (bitwise reference
for ``REPRO_FUSED=off``), and the decode-over-cache fallback is
``repro.models.layers.chunked_q_attention``.

Masking semantics match the kernels exactly (one
:class:`~repro.kernels.attention.mask.MaskSpec`, densified here via
:func:`~repro.kernels.attention.mask.mask_array`):

  * GQA: kv heads are repeated to the query head count inside (the kernels
    instead index the kv block by ``q_head // group``);
  * causal is *rectangular*: query ``i`` sees keys ``j <= (T - S) + i``
    (``T == S`` is ordinary causal; ``T > S`` is a cached-prefill
    continuation where the query block sits at the end of the key range);
  * ``kv_len`` bounds the valid key positions (decode over a partially
    filled cache);
  * ``segments`` — a ((B, S), (B, T)) int32 pair — forbids attention
    across packed-document boundaries (ids must match);
  * fully-masked rows produce **0** output (the flash convention — the
    running normalizer is clamped at 1e-30 — where a naive softmax would
    NaN), via the same finite -inf stand-in the kernels use.

Everything is differentiable: parity tests take ``jax.grad`` of this to
pin dQ/dK/dV for the backward kernels (the kv repeat sums group-head
gradients back onto the (B, T, K, hd) layout automatically).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .mask import mask_array, mask_spec

NEG = -1e30


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              scale: float, causal: bool = True,
              kv_len: Optional[jnp.ndarray] = None,
              segments=None) -> jnp.ndarray:
    """q (B, S, H, hd); k (B, T, K, hd), v (B, T, K, hdv); H % K == 0."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * scale
    spec = mask_spec(S, T, causal=causal, kv_len=kv_len, segments=segments)
    valid = mask_array(spec, S, T, kv_len=kv_len, segments=segments)
    valid = valid[:, None]  # (1|B, 1, S, T) against the (B, H, S, T) scores
    s = jnp.where(valid, s, NEG)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqs,bshd->bqhd", (p / l).astype(v.dtype), v)
    return out.astype(q.dtype)
