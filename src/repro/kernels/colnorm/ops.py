"""Column-norm entry points, routed through :mod:`repro.kernels.dispatch`.

Kept as thin aliases for existing call sites; new code should import
``repro.kernels.dispatch`` directly, which owns backend selection
(compiled on TPU / interpret elsewhere) and the coverage fallbacks.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import dispatch as _d


def colnorm(g: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Column-normalized gradient via the fused kernels."""
    return _d.normalize(g, "col", eps)


def colnorm_update(theta: jnp.ndarray, g: jnp.ndarray, lr,
                   eps: float = 1e-8) -> jnp.ndarray:
    """Fused SCALE matrix update: theta - lr * colnorm(g)."""
    return _d.norm_update(theta, g, lr, "col", eps)
