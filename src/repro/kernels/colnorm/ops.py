"""Jitted wrappers for the column-norm Pallas kernels.

Falls back to the pure-jnp oracle when a shape cannot be tiled (non-128-
aligned dims, or >2-D stacked parameters, where we vmap the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import colnorm as K
from . import ref


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _tileable(shape) -> bool:
    if len(shape) != 2:
        return False
    m, n = shape
    return m % min(K.DEFAULT_BLOCK[0], m) == 0 and \
        n % min(K.DEFAULT_BLOCK[1], n) == 0 and m >= 8 and n >= 128


@functools.partial(jax.jit, static_argnames=("eps",))
def colnorm(g: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Column-normalized gradient via the Pallas kernels."""
    if not _tileable(g.shape):
        return ref.colnorm(g, eps)
    interp = not _on_tpu()
    ss = K.col_sumsq(g, interpret=interp)
    return K.colnorm_apply(g, ss, eps=eps, interpret=interp)


@functools.partial(jax.jit, static_argnames=("eps",))
def colnorm_update(theta: jnp.ndarray, g: jnp.ndarray, lr,
                   eps: float = 1e-8) -> jnp.ndarray:
    """Fused SCALE matrix update: theta - lr * colnorm(g)."""
    if not _tileable(theta.shape):
        return ref.colnorm_update(theta, g, lr, eps)
    interp = not _on_tpu()
    ss = K.col_sumsq(g, interpret=interp)
    return K.update_apply(theta, g, ss, lr, eps=eps, interpret=interp)
