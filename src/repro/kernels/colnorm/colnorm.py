"""Pallas TPU kernels for row/column gradient normalization and the fused
SCALE parameter update.

The SCALE optimizer step is HBM-bandwidth-bound: every parameter matrix and
its gradient stream through HBM once per step, and the arithmetic per element
is tiny. These kernels minimize HBM passes; :mod:`repro.kernels.dispatch`
decides when they run compiled vs. in interpret mode.

Coverage matrix (see ``dispatch.supported``):

  ndim   norm kind          dtype            handling
  -----  -----------------  ---------------  -------------------------------
  2      col / row          f32 / bf16       single grid cell per (j, i) tile
  3      col / row          f32 / bf16       leading grid axis over layers /
                                             experts (stacked scan params)
  any    larger             f32 / bf16       resolved to col/row per shape at
                                             dispatch (shapes are static)
  any    sign / ns / svd    --               jnp reference (not fused)

Arbitrary (non-tile-divisible) shapes are supported: grids use ``pl.cdiv``
and kernels mask the remainder rows/cols of the reduction axis with a
``broadcasted_iota`` predicate, so vocab-size 50257 heads and odd MLP dims
take the fused path instead of falling back to jnp.

Kernels:

  * ``norm_sumsq``    — tiled sum-of-squares reduction along the reduce axis
    (rows for ``col``, columns for ``row``), f32 accumulator in VMEM scratch.
    The reduce axis is the innermost grid dimension, exploiting Pallas TPU's
    sequential grid execution to accumulate across tiles and emit once per
    output tile.
  * ``norm_apply``    — element-wise tiles consuming the sums-of-squares;
    out = g / (||axis||+eps). One read of g, one write of the output.
  * ``update_apply``  — fuses the SGD subtraction: theta' = theta -
    lr * g/(||axis||+eps). theta and g are read once and theta written once;
    theta is aliased to the output (``input_output_aliases``) so under
    buffer donation the write is truly in-place — no fresh theta allocation.

Every kernel takes a ``gscale`` scalar (SMEM) applied to the gradient at
read time (``g_eff = gscale * f32(g)``). This is how the trainer folds the
global-norm clip factor into the fused step: the clipped gradient never
materializes, saving the separate full grad read+write a tree-level
``g * scale`` would cost (XLA cannot fuse element-wise prologues into a
``pallas_call``). ``gscale`` participates in both the sum-of-squares and
the apply, so the result is exactly clip-then-update.

HBM-pass accounting per matrix parameter: one pass = one full-matrix read
or write (the per-slice norm vector is ~1/256 of a matrix — noise). For the
stateless update theta' = theta - lr * g/||g||:

  unfused jnp sequence:   g r (sumsq); g r, gn w (scale);
                          theta r, gn r, theta' w (apply)        = 6 passes
  fused (sumsq + update_apply):
                          g r (sumsq); theta r, g r, theta' w    = 4 passes

i.e. the bandwidth-dominant apply stage touches each matrix exactly 3x
(theta read, grad read, theta write); the preceding norm reduction re-reads
g once — a hard floor for col/row norms, which need the full column/row
sums before any element can be scaled.

Tile sizes default to (256, 256): 256x256xf32 = 256 KiB per operand tile,
three operands + scratch < 2 MiB, comfortably inside a v5e core's 16 MiB
VMEM while keeping both dims multiples of the (8, 128) f32 / (16, 128) bf16
tiling and the 128-lane VPU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 256)


def _canon3(x: jnp.ndarray) -> jnp.ndarray:
    """Canonicalize to (L, m, n); 2-D inputs get a unit layer axis."""
    if x.ndim == 2:
        return x[None]
    if x.ndim == 3:
        return x
    raise ValueError(f"fused kernels take 2-D/3-D arrays, got {x.shape}")


def _blocks(m: int, n: int, block=DEFAULT_BLOCK):
    """Clamp the default block to the (padded) array size.

    Sublane dim rounds to 32 (covers f32/bf16/int8 tiling), lane dim to 128,
    so a single-tile grid over a small or ragged array stays hardware-aligned.
    """
    bm = min(block[0], -(-m // 32) * 32)
    bn = min(block[1], -(-n // 128) * 128)
    return bm, bn


def _red_mask(shape, tile_idx, block_sz, dim, axis_in_tile):
    """True for positions whose global index along the reduce axis is < dim.

    Remainder tiles are zero-padded via this mask before squaring — Pallas
    pads out-of-bounds block regions with undefined values (NaN in interpret
    mode), which would otherwise poison the accumulator.
    """
    idx = jax.lax.broadcasted_iota(jnp.int32, shape, axis_in_tile)
    return tile_idx * block_sz + idx < dim


# --------------------------------------------------------------------------
# norm_sumsq: sum of squares along the reduce axis
# --------------------------------------------------------------------------

def _sumsq_kernel(g_ref, gs_ref, out_ref, acc_ref, *, n_red_tiles, red_dim,
                  red_block, red_axis):
    i = pl.program_id(2)  # reduce-axis tile (innermost)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gf = g_ref[0].astype(jnp.float32) * gs_ref[0, 0]
    gf = jnp.where(_red_mask(gf.shape, i, red_block, red_dim, red_axis),
                   gf, 0.0)
    acc_ref[...] += jnp.sum(gf * gf, axis=red_axis, keepdims=True)

    @pl.when(i == n_red_tiles - 1)
    def _emit():
        out_ref[0] = acc_ref[...]


def norm_sumsq(g: jnp.ndarray, axis: str = "col", block=DEFAULT_BLOCK,
               interpret: bool = True, gscale=1.0) -> jnp.ndarray:
    """Per-column (axis="col") or per-row (axis="row") sum of squares of
    gscale * g.

    g (L, m, n) -> (L, 1, n) for col, (L, m, 1) for row; f32.
    """
    L, m, n = g.shape
    bm, bn = _blocks(m, n, block)
    if axis == "col":  # reduce over rows
        grid = (L, pl.cdiv(n, bn), pl.cdiv(m, bm))
        g_map = lambda l, j, i: (l, i, j)
        out_spec = pl.BlockSpec((1, 1, bn), lambda l, j, i: (l, 0, j))
        out_shape = jax.ShapeDtypeStruct((L, 1, n), jnp.float32)
        scratch = pltpu.VMEM((1, bn), jnp.float32)
        red_dim, red_block, red_axis = m, bm, 0
    elif axis == "row":  # reduce over columns
        grid = (L, pl.cdiv(m, bm), pl.cdiv(n, bn))
        g_map = lambda l, j, i: (l, j, i)
        out_spec = pl.BlockSpec((1, bm, 1), lambda l, j, i: (l, j, 0))
        out_shape = jax.ShapeDtypeStruct((L, m, 1), jnp.float32)
        scratch = pltpu.VMEM((bm, 1), jnp.float32)
        red_dim, red_block, red_axis = n, bn, 1
    else:
        raise ValueError(f"axis must be 'col' or 'row', got {axis!r}")
    gs_arr = jnp.asarray(gscale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_sumsq_kernel, n_red_tiles=grid[2],
                          red_dim=red_dim, red_block=red_block,
                          red_axis=red_axis),
        grid=grid,
        in_specs=[pl.BlockSpec((1, bm, bn), g_map),
                  pl.BlockSpec((1, 1), lambda l, j, i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[scratch],
        interpret=interpret,
    )(g, gs_arr)


# --------------------------------------------------------------------------
# norm_apply / update_apply: element-wise consumers of the sums-of-squares
# --------------------------------------------------------------------------

def _norm_apply_kernel(g_ref, ss_ref, gs_ref, out_ref, *, eps: float):
    # ss block is (1, 1, bn) or (1, bm, 1); broadcasting covers both axes.
    norm = jnp.sqrt(ss_ref[0]) + eps
    gf = g_ref[0].astype(jnp.float32) * gs_ref[0, 0]
    out_ref[0] = (gf / norm).astype(out_ref.dtype)


def _ew_specs(L, m, n, bm, bn, axis):
    """Grid + block specs shared by the element-wise kernels."""
    grid = (L, pl.cdiv(n, bn), pl.cdiv(m, bm))
    tile = pl.BlockSpec((1, bm, bn), lambda l, j, i: (l, i, j))
    if axis == "col":
        ss = pl.BlockSpec((1, 1, bn), lambda l, j, i: (l, 0, j))
    else:
        ss = pl.BlockSpec((1, bm, 1), lambda l, j, i: (l, i, 0))
    smem = pl.BlockSpec((1, 1), lambda l, j, i: (0, 0),
                        memory_space=pltpu.SMEM)
    return grid, tile, ss, smem


def norm_apply(g, ss, axis: str = "col", block=DEFAULT_BLOCK,
               eps: float = 1e-8, interpret: bool = True, gscale=1.0,
               out_dtype=None):
    """gscale * g / (sqrt(ss)+eps) with ss broadcast along the reduce axis.

    ``out_dtype`` overrides the output dtype (math is f32 regardless) —
    used when g is a reduced-precision momentum buffer but the normalized
    direction must stay f32.
    """
    L, m, n = g.shape
    bm, bn = _blocks(m, n, block)
    grid, tile, ss_spec, smem = _ew_specs(L, m, n, bm, bn, axis)
    gs_arr = jnp.asarray(gscale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_norm_apply_kernel, eps=eps),
        grid=grid,
        in_specs=[tile, ss_spec, smem],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((L, m, n), out_dtype or g.dtype),
        interpret=interpret,
    )(g, ss, gs_arr)


def _update_apply_kernel(theta_ref, g_ref, ss_ref, lr_ref, gs_ref, out_ref,
                         *, eps: float):
    norm = jnp.sqrt(ss_ref[0]) + eps
    gf = g_ref[0].astype(jnp.float32) * gs_ref[0, 0]
    upd = theta_ref[0].astype(jnp.float32) - lr_ref[0, 0] * gf / norm
    out_ref[0] = upd.astype(out_ref.dtype)


def update_apply(theta, g, ss, lr, axis: str = "col", block=DEFAULT_BLOCK,
                 eps: float = 1e-8, interpret: bool = True, gscale=1.0):
    """theta - lr * gscale*g/(sqrt(ss)+eps): the fused SCALE parameter write.

    theta is aliased to the output buffer (``input_output_aliases={0: 0}``):
    when the caller donates theta (``donate_argnums`` on the train step) the
    update happens in-place and no fresh theta-sized buffer is allocated.
    """
    L, m, n = theta.shape
    bm, bn = _blocks(m, n, block)
    grid, tile, ss_spec, smem = _ew_specs(L, m, n, bm, bn, axis)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    gs_arr = jnp.asarray(gscale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_update_apply_kernel, eps=eps),
        grid=grid,
        in_specs=[tile, tile, ss_spec, smem, smem],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((L, m, n), theta.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(theta, g, ss, lr_arr, gs_arr)


# --------------------------------------------------------------------------
# 2-D convenience wrappers (legacy call sites and tests)
# --------------------------------------------------------------------------

def col_sumsq(g: jnp.ndarray, block=DEFAULT_BLOCK, interpret: bool = True):
    """Sum of squares per column. g (m, n) -> (1, n), f32."""
    return norm_sumsq(_canon3(g), "col", block, interpret)[0]


def colnorm_apply(g, ss, block=DEFAULT_BLOCK, eps: float = 1e-8,
                  interpret: bool = True):
    return norm_apply(_canon3(g), _canon3(ss), "col", block, eps, interpret)[0]
