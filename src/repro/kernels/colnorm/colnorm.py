"""Pallas TPU kernels for column-wise gradient normalization.

The SCALE optimizer step is HBM-bandwidth-bound: every parameter matrix and
its gradient stream through HBM once per step. The fused kernels here avoid
materializing the normalized gradient:

  * ``col_sumsq``   — tiled reduction over the input (sublane) dimension,
    f32 accumulator in VMEM scratch. Grid is (col_tiles, row_tiles) with the
    row axis innermost, exploiting Pallas TPU's sequential grid execution to
    accumulate across row tiles and emit once per column tile.
  * ``colnorm_apply`` / ``update_apply`` — element-wise tiles consuming the
    (1, n) sums-of-squares; ``update_apply`` fuses the SGD subtraction so
    theta/g are read once and theta written once (3 HBM passes total versus
    5 for the unfused jnp sequence).

Tile sizes default to (256, 256): 256x256xf32 = 256 KiB per operand tile,
three operands + scratch < 2 MiB, comfortably inside a v5e core's 16 MiB
VMEM while keeping both dims multiples of the (8, 128) f32 tiling and the
128-lane VPU/MXU width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = (256, 256)


def _col_sumsq_kernel(g_ref, out_ref, acc_ref, *, n_row_tiles: int):
    i = pl.program_id(1)  # row tile (innermost)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    gf = g_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(gf * gf, axis=0, keepdims=True)

    @pl.when(i == n_row_tiles - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


def col_sumsq(g: jnp.ndarray, block=DEFAULT_BLOCK, interpret: bool = True):
    m, n = g.shape
    bm, bn = min(block[0], m), min(block[1], n)
    assert m % bm == 0 and n % bn == 0, (g.shape, block)
    grid = (n // bn, m // bm)  # columns outer, rows inner (sequential accum)
    return pl.pallas_call(
        functools.partial(_col_sumsq_kernel, n_row_tiles=grid[1]),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j))],
        out_specs=pl.BlockSpec((1, bn), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
    )(g)


def _colnorm_apply_kernel(g_ref, ss_ref, out_ref, *, eps: float):
    norm = jnp.sqrt(ss_ref[...]) + eps
    out_ref[...] = (g_ref[...].astype(jnp.float32) / norm).astype(out_ref.dtype)


def colnorm_apply(g, ss, block=DEFAULT_BLOCK, eps: float = 1e-8,
                  interpret: bool = True):
    m, n = g.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (n // bn, m // bm)
    return pl.pallas_call(
        functools.partial(_colnorm_apply_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((1, bn), lambda j, i: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), g.dtype),
        interpret=interpret,
    )(g, ss)


def _update_apply_kernel(theta_ref, g_ref, ss_ref, lr_ref, out_ref, *, eps: float):
    norm = jnp.sqrt(ss_ref[...]) + eps
    upd = theta_ref[...].astype(jnp.float32) - \
        lr_ref[0, 0] * g_ref[...].astype(jnp.float32) / norm
    out_ref[...] = upd.astype(out_ref.dtype)


def update_apply(theta, g, ss, lr, block=DEFAULT_BLOCK, eps: float = 1e-8,
                 interpret: bool = True):
    m, n = theta.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = (n // bn, m // bm)
    lr_arr = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_update_apply_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
                  pl.BlockSpec((1, bn), lambda j, i: (0, j)),
                  pl.BlockSpec((1, 1), lambda j, i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), theta.dtype),
        interpret=interpret,
    )(theta, g, ss, lr_arr)
