"""Pure-jnp oracles for the column-norm kernels."""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def col_sumsq(g: jnp.ndarray) -> jnp.ndarray:
    """Sum of squares per column (f32). g (m, n) -> (1, n)."""
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf, axis=0, keepdims=True)


def colnorm(g: jnp.ndarray, eps: float = EPS) -> jnp.ndarray:
    """g / (||col||_2 + eps), per column."""
    gf = g.astype(jnp.float32)
    norms = jnp.sqrt(col_sumsq(g))
    return (gf / (norms + eps)).astype(g.dtype)


def colnorm_update(theta: jnp.ndarray, g: jnp.ndarray, lr,
                   eps: float = EPS) -> jnp.ndarray:
    """theta - lr * colnorm(g)  (the SCALE matrix update)."""
    return (theta.astype(jnp.float32)
            - jnp.asarray(lr, jnp.float32) * colnorm(g).astype(jnp.float32)
            ).astype(theta.dtype)
