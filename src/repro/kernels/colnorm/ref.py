"""Pure-jnp oracles for the row/column-norm kernels.

``axis="col"`` reduces over rows (axis=-2, per output unit); ``axis="row"``
reduces over columns (axis=-1). Oracles accept 2-D or stacked 3-D inputs.
"""
from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8

_RED = {"col": -2, "row": -1}


def norm_sumsq(g: jnp.ndarray, axis: str = "col") -> jnp.ndarray:
    """Sum of squares along the reduce axis (f32, keepdims)."""
    gf = g.astype(jnp.float32)
    return jnp.sum(gf * gf, axis=_RED[axis], keepdims=True)


def normalize(g: jnp.ndarray, axis: str = "col",
              eps: float = EPS) -> jnp.ndarray:
    """g / (||slice||_2 + eps) along the reduce axis."""
    gf = g.astype(jnp.float32)
    norms = jnp.sqrt(norm_sumsq(g, axis))
    return (gf / (norms + eps)).astype(g.dtype)


def norm_update(theta: jnp.ndarray, g: jnp.ndarray, lr,
                axis: str = "col", eps: float = EPS) -> jnp.ndarray:
    """theta - lr * normalize(g)  (the SCALE matrix update)."""
    return (theta.astype(jnp.float32)
            - jnp.asarray(lr, jnp.float32)
            * normalize(g, axis, eps).astype(jnp.float32)
            ).astype(theta.dtype)


# Legacy column-wise names (tests / older call sites).

def col_sumsq(g: jnp.ndarray) -> jnp.ndarray:
    return norm_sumsq(g, "col")


def colnorm(g: jnp.ndarray, eps: float = EPS) -> jnp.ndarray:
    return normalize(g, "col", eps)


def colnorm_update(theta: jnp.ndarray, g: jnp.ndarray, lr,
                   eps: float = EPS) -> jnp.ndarray:
    return norm_update(theta, g, lr, "col", eps)
