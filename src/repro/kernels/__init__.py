# OPTIONAL layer: custom kernels for the training step's three hot paths
# — the SCALE update (colnorm/scale_head), the LM-head cross-entropy
# (xent), and flash attention (attention). `dispatch` is the single entry
# point — it owns backend selection (compiled on TPU, interpret oracle
# elsewhere), the coverage matrix, shard_map plans, and jnp-reference
# fallbacks. The kernel packages each pair a Pallas implementation
# (<name>.py) with a pure-jnp oracle (ref.py).
from . import dispatch

__all__ = ["dispatch"]
