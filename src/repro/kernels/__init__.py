# OPTIONAL layer: custom kernels for the paper's compute hot-spot (the
# SCALE update). `dispatch` is the single entry point — it owns backend
# selection (compiled on TPU, interpret oracle elsewhere), the coverage
# matrix, and jnp-reference fallbacks. The kernel packages each pair a
# Pallas implementation (<name>.py) with a pure-jnp oracle (ref.py).
from . import dispatch

__all__ = ["dispatch"]
