from .pipeline import DataConfig, SyntheticLM, make_dataset
__all__ = ["DataConfig", "SyntheticLM", "make_dataset"]
