"""Deterministic, shard-aware synthetic LM data pipeline.

Design goals (what a real C4 loader must provide, reproduced without network
access):

  * **Deterministic & resumable** — a batch is a pure function of
    ``(seed, step)``; restart-from-checkpoint replays the exact stream with
    no loader state to save beyond the step counter.
  * **Shard-aware** — each host slices its ``[host_id]`` rows of the global
    batch; every host computes only its shard.
  * **Learnable + realistic marginals** — tokens follow a Zipf marginal
    (frequent-token skew drives the paper's LM-head column-norm imbalance,
    Fig. 10) with a deterministic affine bigram backbone the model can learn
    (loss decreases well below ln(V)).

Packed batches (``pack_documents``)
-----------------------------------
Production pretraining feeds packed multi-document rows, not one document
per row: variable-length documents are first-fit binned into fixed-S rows
so pad tokens (attention + loss work spent on nothing) shrink from
``1 - mean_len/S`` of the batch to the first-fit remainder. A packed batch
carries three extra per-token operands, all (B, S):

  * ``segment_ids`` int32 — document id within the row, 1..N in placement
    order; pad positions are 0. The attention stack masks cross-document
    (and pad) pairs via the segment clause of
    :class:`repro.kernels.attention.mask.MaskSpec`.
  * ``positions`` int32 — *within-document* position 0..len-1 (RoPE and
    learned position embeddings restart at each boundary); 0 on pad.
  * ``loss_weights`` f32 — 1.0 where the label is a real within-document
    next token, 0.0 at document ends and pad; doubles as the loss mask
    through the weighted ``dispatch.xent_loss``.

Labels are next-token *within* a document (-1 at each document's last
token and on pad), so no loss term ever crosses a boundary. Everything
stays a pure function of (seed, step): document lengths come from a
``RandomState`` keyed on (seed, step) and contents from the same bigram
generator as the unpacked path. :func:`unpack_to_rows` is the inverse
used by the parity tests — offset-preserving (each document lands in its
own row at its packed offset), which keeps the reference attention path
bitwise identical per document.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bigram_prob: float = 0.8     # P(next token follows the affine map)
    zipf_a: float = 1.2          # Zipf exponent for the noise marginal
    n_codebooks: int = 0         # audio: tokens (B, n_codebooks, S)
    n_image_tokens: int = 0      # vlm: synthetic patch embeddings
    d_model: int = 0             # vlm: embedding width
    pack_documents: bool = False  # first-fit packed multi-document rows
    min_doc_len: int = 8         # packed: shortest sampled document


def _zipf_cdf(vocab: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, vocab + 1) ** a
    return np.cumsum(w / w.sum())


class SyntheticLM:
    """Stateless synthetic next-token dataset."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._cdf = jnp.asarray(_zipf_cdf(cfg.vocab_size, cfg.zipf_a),
                                jnp.float32)
        # affine bigram backbone: next = (a * prev + b) % V
        rng = np.random.RandomState(cfg.seed)
        self._a = int(rng.randint(3, 97) * 2 + 1)  # odd -> bijective mod V
        self._b = int(rng.randint(0, cfg.vocab_size))

    def _sample_zipf(self, key, shape):
        u = jax.random.uniform(key, shape)
        return jnp.searchsorted(self._cdf, u).astype(jnp.int32)

    def _gen_tokens(self, key, batch: int):
        cfg = self.cfg
        k0, k1, k2 = jax.random.split(key, 3)
        first = self._sample_zipf(k0, (batch,))
        noise = self._sample_zipf(k1, (batch, cfg.seq_len))
        coin = jax.random.uniform(k2, (batch, cfg.seq_len)) < cfg.bigram_prob

        def step(prev, inp):
            nz, c = inp
            nxt = jnp.where(c, (self._a * prev + self._b) % cfg.vocab_size, nz)
            return nxt, nxt

        _, toks = jax.lax.scan(step, first, (noise.T, coin.T))
        return toks.T  # (batch, seq)

    # ------------------------------------------------------------- packing

    def _packed_batch(self, step: int) -> dict:
        """First-fit packed (B, S) batch — see the module docstring.

        Host-side numpy: packing is data-dependent control flow (placement
        depends on every earlier document's length), so it runs eagerly
        like a real loader would, staying a pure function of (seed, step).
        """
        cfg = self.cfg
        B, S = cfg.global_batch, cfg.seq_len
        lo = min(cfg.min_doc_len, S)
        rng = np.random.RandomState(
            (1000003 * cfg.seed + 7919 * step + 13) % (2 ** 31 - 1))
        # enough candidates to fill B rows at the ~(lo+S)/2 mean length
        n_cand = 4 * B + 8
        lens = rng.randint(lo, S + 1, size=n_cand)
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        cand = np.asarray(self._gen_tokens(jax.random.fold_in(key, 17),
                                           n_cand))
        tokens = np.zeros((B, S), np.int32)
        labels = np.full((B, S), -1, np.int32)
        segment_ids = np.zeros((B, S), np.int32)
        positions = np.zeros((B, S), np.int32)
        weights = np.zeros((B, S), np.float32)
        fill = np.zeros(B, np.int64)
        nseg = np.zeros(B, np.int64)
        for d in range(n_cand):
            L = int(lens[d])
            b = next((b for b in range(B) if S - fill[b] >= L), None)
            if b is None:
                if int((S - fill).max()) < lo:
                    break  # no future candidate can fit anywhere
                continue
            o = int(fill[b])
            doc = cand[d, :L]
            tokens[b, o:o + L] = doc
            segment_ids[b, o:o + L] = nseg[b] + 1
            positions[b, o:o + L] = np.arange(L)
            labels[b, o:o + L - 1] = doc[1:]       # within-document only:
            weights[b, o:o + L - 1] = 1.0          # last token predicts
            fill[b] += L                           # nothing across the
            nseg[b] += 1                           # boundary
        return {"tokens": jnp.asarray(tokens),
                "labels": jnp.asarray(labels),
                "segment_ids": jnp.asarray(segment_ids),
                "positions": jnp.asarray(positions),
                "loss_weights": jnp.asarray(weights)}

    def global_batch_at(self, step: int) -> dict:
        """The full (unsharded) batch for ``step``; labels are next-token."""
        cfg = self.cfg
        if cfg.pack_documents:
            if cfg.n_codebooks or cfg.n_image_tokens:
                raise ValueError("pack_documents: packing is a plain-text "
                                 "format (no audio codebooks / image rows)")
            return self._packed_batch(step)
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        if cfg.n_codebooks:
            keys = jax.random.split(key, cfg.n_codebooks)
            toks = jnp.stack([self._gen_tokens(k, cfg.global_batch)
                              for k in keys], axis=1)  # (B, ncb, S)
            labels = jnp.concatenate(
                [toks[..., 1:], jnp.full(toks.shape[:-1] + (1,), -1, jnp.int32)], -1)
        else:
            toks = self._gen_tokens(key, cfg.global_batch)
            labels = jnp.concatenate(
                [toks[:, 1:], jnp.full((cfg.global_batch, 1), -1, jnp.int32)], -1)
        batch = {"tokens": toks, "labels": labels}
        if cfg.n_image_tokens:
            batch["image_embeds"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, 7),
                (cfg.global_batch, cfg.n_image_tokens, cfg.d_model))
        return batch

    def host_batch_at(self, step: int, host_id: int = 0,
                      n_hosts: int = 1) -> dict:
        """This host's shard (rows host_id::n_hosts of the global batch)."""
        full = self.global_batch_at(step)
        assert self.cfg.global_batch % n_hosts == 0
        per = self.cfg.global_batch // n_hosts
        return jax.tree_util.tree_map(
            lambda x: x[host_id * per:(host_id + 1) * per], full)


def unpack_to_rows(batch: dict) -> dict:
    """Packed batch -> one row per document, **offset-preserving**.

    Each document keeps its packed row offset (everything outside it is
    pad: token 0, label -1, segment/position 0, weight 0). Preserving the
    offset keeps every per-document computation on the reference attention
    path *bitwise* identical to the packed run — the document's tokens sit
    in the same lanes, and all other lanes are masked in both layouts —
    which is what the packed-vs-unpacked parity tests pin.
    """
    toks = np.asarray(batch["tokens"])
    labs = np.asarray(batch["labels"])
    segs = np.asarray(batch["segment_ids"])
    poss = np.asarray(batch["positions"])
    wts = np.asarray(batch["loss_weights"])
    rows = {k: [] for k in ("tokens", "labels", "segment_ids", "positions",
                            "loss_weights")}
    for b in range(toks.shape[0]):
        for s in np.unique(segs[b]):
            if s == 0:
                continue
            m = segs[b] == s
            rows["tokens"].append(np.where(m, toks[b], 0))
            rows["labels"].append(np.where(m, labs[b], -1))
            rows["segment_ids"].append(np.where(m, segs[b], 0))
            rows["positions"].append(np.where(m, poss[b], 0))
            rows["loss_weights"].append(np.where(m, wts[b], 0.0))
    return {k: jnp.asarray(np.stack(v)) for k, v in rows.items()}


def make_dataset(model_cfg, seq_len: int, global_batch: int,
                 seed: int = 0, pack_documents: bool = False) -> SyntheticLM:
    """Dataset matched to a ModelConfig (codebooks / image stubs wired up)."""
    return SyntheticLM(DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        n_codebooks=model_cfg.n_codebooks if model_cfg.family == "audio" else 0,
        n_image_tokens=model_cfg.n_image_tokens if model_cfg.family == "vlm" else 0,
        d_model=model_cfg.d_model,
        pack_documents=pack_documents,
    ))
