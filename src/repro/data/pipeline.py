"""Deterministic, shard-aware synthetic LM data pipeline.

Design goals (what a real C4 loader must provide, reproduced without network
access):

  * **Deterministic & resumable** — a batch is a pure function of
    ``(seed, step)``; restart-from-checkpoint replays the exact stream with
    no loader state to save beyond the step counter.
  * **Shard-aware** — each host slices its ``[host_id]`` rows of the global
    batch; every host computes only its shard.
  * **Learnable + realistic marginals** — tokens follow a Zipf marginal
    (frequent-token skew drives the paper's LM-head column-norm imbalance,
    Fig. 10) with a deterministic affine bigram backbone the model can learn
    (loss decreases well below ln(V)).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bigram_prob: float = 0.8     # P(next token follows the affine map)
    zipf_a: float = 1.2          # Zipf exponent for the noise marginal
    n_codebooks: int = 0         # audio: tokens (B, n_codebooks, S)
    n_image_tokens: int = 0      # vlm: synthetic patch embeddings
    d_model: int = 0             # vlm: embedding width


def _zipf_cdf(vocab: int, a: float) -> np.ndarray:
    w = 1.0 / np.arange(1, vocab + 1) ** a
    return np.cumsum(w / w.sum())


class SyntheticLM:
    """Stateless synthetic next-token dataset."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._cdf = jnp.asarray(_zipf_cdf(cfg.vocab_size, cfg.zipf_a),
                                jnp.float32)
        # affine bigram backbone: next = (a * prev + b) % V
        rng = np.random.RandomState(cfg.seed)
        self._a = int(rng.randint(3, 97) * 2 + 1)  # odd -> bijective mod V
        self._b = int(rng.randint(0, cfg.vocab_size))

    def _sample_zipf(self, key, shape):
        u = jax.random.uniform(key, shape)
        return jnp.searchsorted(self._cdf, u).astype(jnp.int32)

    def _gen_tokens(self, key, batch: int):
        cfg = self.cfg
        k0, k1, k2 = jax.random.split(key, 3)
        first = self._sample_zipf(k0, (batch,))
        noise = self._sample_zipf(k1, (batch, cfg.seq_len))
        coin = jax.random.uniform(k2, (batch, cfg.seq_len)) < cfg.bigram_prob

        def step(prev, inp):
            nz, c = inp
            nxt = jnp.where(c, (self._a * prev + self._b) % cfg.vocab_size, nz)
            return nxt, nxt

        _, toks = jax.lax.scan(step, first, (noise.T, coin.T))
        return toks.T  # (batch, seq)

    def global_batch_at(self, step: int) -> dict:
        """The full (unsharded) batch for ``step``; labels are next-token."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        if cfg.n_codebooks:
            keys = jax.random.split(key, cfg.n_codebooks)
            toks = jnp.stack([self._gen_tokens(k, cfg.global_batch)
                              for k in keys], axis=1)  # (B, ncb, S)
            labels = jnp.concatenate(
                [toks[..., 1:], jnp.full(toks.shape[:-1] + (1,), -1, jnp.int32)], -1)
        else:
            toks = self._gen_tokens(key, cfg.global_batch)
            labels = jnp.concatenate(
                [toks[:, 1:], jnp.full((cfg.global_batch, 1), -1, jnp.int32)], -1)
        batch = {"tokens": toks, "labels": labels}
        if cfg.n_image_tokens:
            batch["image_embeds"] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, 7),
                (cfg.global_batch, cfg.n_image_tokens, cfg.d_model))
        return batch

    def host_batch_at(self, step: int, host_id: int = 0,
                      n_hosts: int = 1) -> dict:
        """This host's shard (rows host_id::n_hosts of the global batch)."""
        full = self.global_batch_at(step)
        assert self.cfg.global_batch % n_hosts == 0
        per = self.cfg.global_batch // n_hosts
        return jax.tree_util.tree_map(
            lambda x: x[host_id * per:(host_id + 1) * per], full)


def make_dataset(model_cfg, seq_len: int, global_batch: int,
                 seed: int = 0) -> SyntheticLM:
    """Dataset matched to a ModelConfig (codebooks / image stubs wired up)."""
    return SyntheticLM(DataConfig(
        vocab_size=model_cfg.vocab_size,
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        n_codebooks=model_cfg.n_codebooks if model_cfg.family == "audio" else 0,
        n_image_tokens=model_cfg.n_image_tokens if model_cfg.family == "vlm" else 0,
        d_model=model_cfg.d_model,
    ))
