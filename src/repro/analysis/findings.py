"""Finding records + suppression-baseline IO for ``repro.analysis``.

A :class:`Finding` is one rule violation at one location. Baseline keys
deliberately exclude line numbers — ``rule:path:message`` — so unrelated
edits that shift code around do not invalidate suppressions, while any
change to *what* is wrong (a different op name, a different kernel) does.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``rule``     stable rule ID (e.g. ``KC003``) — see analysis/README.md.
    ``path``     repo-relative posix path of the offending file.
    ``line``     1-based line number (0 when the finding is not tied to a
                 specific line, e.g. a registry-level drift).
    ``message``  human-readable description; stable across line shifts.
    """

    rule: str
    path: str
    line: int
    message: str

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule}: {self.message}"


def load_baseline(path: Path) -> set:
    """Read a committed baseline; missing file == empty baseline."""
    p = Path(path)
    if not p.exists():
        return set()
    doc = json.loads(p.read_text())
    return set(doc.get("suppressions", []))


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    keys = sorted({f.key for f in findings})
    doc = {
        "schema": "repro.analysis/baseline/v1",
        "comment": ("Suppressed findings (rule:path:message). Regenerate "
                    "with `python -m repro.analysis --write-baseline`; "
                    "prefer fixing over suppressing."),
        "suppressions": keys,
    }
    Path(path).write_text(json.dumps(doc, indent=2) + "\n")


def split_by_baseline(findings, baseline):
    """Partition findings into (new, suppressed) against a baseline set."""
    new, suppressed = [], []
    for f in findings:
        (suppressed if f.key in baseline else new).append(f)
    return new, suppressed
