"""JH: JAX tracing-hygiene checks.

Rules
-----
JH001  Python-level branching on a traced value: an ``if``/``while`` test
       built from jnp/lax array ops (comparisons through ``jnp.*`` calls,
       ``.any()``/``.all()`` method results). Under jit these raise
       ``TracerBoolConversionError`` — or worse, silently specialize when
       the input happens to be concrete.
JH002  ``except TypeError`` feature-probing. Calling an API and catching
       ``TypeError`` to detect a missing kwarg also swallows genuine type
       bugs (the class of bug PR 6 removed from ``launch/train.py``);
       probe with ``inspect.signature`` instead.
JH003  environment reads inside jitted code. ``os.environ`` /
       ``os.getenv`` in a jit-decorated function runs once at trace time
       and is frozen into the cache — the ``REPRO_FUSED`` re-read pitfall
       PR 2 fixed. Resolve env config *outside* jit and pass it in as a
       static argument.
"""
from __future__ import annotations

import ast

from .astutil import ModuleInfo, call_name, dotted
from .findings import Finding

# jnp calls that return Python scalars / static facts, fine in `if` tests
_STATIC_OK = {"issubdtype", "isdtype", "result_type", "promote_types",
              "can_cast", "finfo", "iinfo", "ndim", "shape", "size",
              "dtype", "isinstance", "len",
              # host-side facts, not traced arrays
              "process_count", "process_index", "device_count",
              "local_device_count", "devices", "local_devices",
              "default_backend", "tree_leaves", "tree_structure",
              "tree_all", "isscalar"}
_TRACED_PREFIXES = ("jnp", "jax", "lax", "np.jnp")


def run(modules, resolver=None, rel=None):
    rel = rel or (lambda p: str(p))
    out = []
    for mi in modules:
        path = rel(mi.path)
        out.extend(_tracer_branches(mi, path))
        out.extend(_typeerror_probes(mi, path))
        out.extend(_env_reads_in_jit(mi, path))
    return out


def _is_traced_expr(node):
    """Heuristic: does this test expression hold a traced jnp value?"""
    for sub in ast.walk(node):
        name = call_name(sub)
        if not name:
            continue
        parts = name.split(".")
        last = parts[-1]
        if last in _STATIC_OK:
            continue
        if last in ("any", "all") and isinstance(sub.func, ast.Attribute):
            # x.any() / x.all() on an array result
            return True, f"{name}()"
        if parts[0] in _TRACED_PREFIXES or (
                len(parts) > 1 and parts[-2] in ("lax", "numpy")):
            return True, f"{name}(...)"
    return False, None


def _tracer_branches(mi, path):
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, (ast.If, ast.While)):
            continue
        traced, what = _is_traced_expr(node.test)
        if traced:
            out.append(Finding(
                "JH001", path, node.lineno,
                f"Python-level branch on a traced value ({what}); use "
                f"jnp.where / lax.cond or hoist the decision out of "
                f"traced code"))
    return out


def _typeerror_probes(mi, path):
    out = []
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.ExceptHandler) or node.type is None:
            continue
        names = []
        if isinstance(node.type, ast.Tuple):
            names = [dotted(e) for e in node.type.elts]
        else:
            names = [dotted(node.type)]
        if any(n and n.split(".")[-1] == "TypeError" for n in names):
            out.append(Finding(
                "JH002", path, node.lineno,
                "except TypeError feature-probe swallows genuine type "
                "bugs; detect optional kwargs with inspect.signature "
                "instead"))
    return out


def _is_jit_decorated(fn):
    for dec in fn.decorator_list:
        for sub in ast.walk(dec):
            name = call_name(sub) or dotted(sub)
            if name and name.split(".")[-1] in ("jit", "pjit"):
                return True
    return False


def _env_reads_in_jit(mi, path):
    out = []
    for fn in ast.walk(mi.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if not _is_jit_decorated(fn):
            continue
        for node in ast.walk(fn):
            name = call_name(node) or (
                dotted(node) if isinstance(node, ast.Attribute) else None)
            if name in ("os.getenv", "os.environ.get") or (
                    name is not None and name.startswith("os.environ")):
                out.append(Finding(
                    "JH003", path, node.lineno,
                    f"environment read ({name}) inside jit-decorated "
                    f"{fn.name}; the value is frozen at trace time — "
                    f"resolve it outside jit and pass it as a static "
                    f"arg"))
                break
    return out


def analyze_source(path, source):
    """Convenience for tests: analyze one synthetic module."""
    return run([ModuleInfo(path, source)])
