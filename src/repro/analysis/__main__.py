"""``python -m repro.analysis`` — run the repo's static-analysis passes.

Exit codes: 0 clean (or all findings baselined), 2 new findings, 1 on
internal errors. ``--json PATH`` additionally writes a machine-readable
report (``-`` for stdout); CI uploads it as an artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import collective_axes, jax_hygiene, kernel_contract, registry_drift
from .astutil import ModuleInfo, Resolver
from .findings import load_baseline, split_by_baseline, write_baseline
from .lowering import apply_fix

_REPO_ROOT = Path(__file__).resolve().parents[3]
_DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def _collect_modules(paths):
    modules = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            try:
                modules.append(ModuleInfo(f))
            except SyntaxError as e:
                print(f"repro.analysis: cannot parse {f}: {e}",
                      file=sys.stderr)
        if not p.exists():
            raise SystemExit(f"repro.analysis: no such path: {p}")
    return modules


def _rel(path):
    try:
        return Path(path).resolve().relative_to(_REPO_ROOT).as_posix()
    except ValueError:
        return Path(path).as_posix()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro-lint: kernel/dispatch/pipeline contract checks")
    ap.add_argument("--paths", nargs="+", default=None, metavar="PATH",
                    help="analyze these files/dirs instead of src/ "
                         "(skips the live registry-drift pass)")
    ap.add_argument("--json", dest="json_out", default=None, metavar="PATH",
                    help="write the JSON report here ('-' for stdout)")
    ap.add_argument("--baseline", default=str(_DEFAULT_BASELINE),
                    metavar="PATH", help="suppression baseline to apply")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report everything)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current findings as the new baseline")
    ap.add_argument("--fix", action="store_true",
                    help="regenerate the dispatch lowering table from "
                         "OPTIMIZER_REGISTRY, then re-check")
    args = ap.parse_args(argv)

    if args.fix:
        changed = apply_fix()
        print("lowering table: "
              + ("rewritten" if changed else "already in sync"))

    default_scan = args.paths is None
    roots = ([_REPO_ROOT / "src" / "repro"] if default_scan
             else [Path(p) for p in args.paths])
    modules = _collect_modules(roots)
    resolver = Resolver()
    for mi in modules:
        resolver.add(mi)

    findings = []
    findings += kernel_contract.run(modules, resolver, rel=_rel)
    findings += collective_axes.run(modules, resolver, rel=_rel)
    findings += jax_hygiene.run(modules, rel=_rel)
    if default_scan:
        # live-import passes only make sense against the real tree
        findings += registry_drift.run()
        findings += collective_axes.check_dispatch_contract()
    findings.sort(key=lambda f: (f.path, f.line, f.rule, f.message))

    baseline = set() if args.no_baseline else load_baseline(args.baseline)
    new, suppressed = split_by_baseline(findings, baseline)

    if args.write_baseline:
        write_baseline(args.baseline, findings)
        print(f"baseline: wrote {len(findings)} suppression(s) to "
              f"{args.baseline}")
        return 0

    report = {
        "schema": "repro.analysis/v1",
        "root": str(_REPO_ROOT),
        "counts": {"new": len(new), "suppressed": len(suppressed)},
        "findings": [dict(f.to_dict(), suppressed=(f.key in baseline))
                     for f in findings],
    }
    # with --json -, stdout is the machine-readable report; the text
    # report moves to stderr so the JSON stays pipeable
    json_on_stdout = args.json_out == "-"
    if args.json_out:
        payload = json.dumps(report, indent=2) + "\n"
        if json_on_stdout:
            sys.stdout.write(payload)
        else:
            Path(args.json_out).write_text(payload)

    out = sys.stderr if json_on_stdout else sys.stdout
    for f in new:
        print(f.render(), file=out)
    tail = (f"{len(new)} new finding(s), {len(suppressed)} baselined, "
            f"{len(modules)} module(s) analyzed")
    print(("FAIL: " if new else "OK: ") + tail, file=out)
    return 2 if new else 0


if __name__ == "__main__":
    sys.exit(main())
