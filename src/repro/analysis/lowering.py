"""Generated per-optimizer lowering table for the dispatch docstring.

``kernels/dispatch.py``'s module docstring carries a table describing how
each registry optimizer lowers (or doesn't) onto the fused kernels. That
table is *generated* from ``core.api.OPTIMIZER_REGISTRY`` — each
:class:`OptimizerSpec` carries its ``lowering`` note — and lives between
two marker lines::

    .. lowering-table-begin
    ...generated content...
    .. lowering-table-end

``python -m repro.analysis --fix`` rewrites the region in place; the
registry-drift pass (RD001) fails when the on-disk region and the
rendered registry disagree.
"""
from __future__ import annotations

import textwrap
from pathlib import Path

BEGIN_MARK = ".. lowering-table-begin"
END_MARK = ".. lowering-table-end"
_NOTE = ("(generated from core.api.OPTIMIZER_REGISTRY — edit the specs'\n"
         "``lowering`` text and run ``python -m repro.analysis --fix``)")


def render_lowering_table(registry=None) -> str:
    """Deterministic reST table, one row per registry optimizer."""
    if registry is None:
        from repro.core.api import OPTIMIZER_REGISTRY as registry
    name_w = max([len("registry optimizer")] + [len(n) for n in registry])
    fused_w = len("fused")
    text_w = 79 - 2 - name_w - 2 - fused_w - 2
    bar = f"  {'=' * name_w}  {'=' * fused_w}  {'=' * text_w}"
    lines = [_NOTE, "", bar,
             f"  {'registry optimizer':<{name_w}}  {'fused':<{fused_w}}"
             f"  lowering",
             bar]
    for name, spec in registry.items():
        fused = "yes" if spec.fused else "no"
        body = textwrap.wrap(spec.lowering or "(no lowering note)",
                             text_w) or [""]
        lines.append(f"  {name:<{name_w}}  {fused:<{fused_w}}  {body[0]}")
        for cont in body[1:]:
            lines.append(f"  {'':<{name_w}}  {'':<{fused_w}}  {cont}")
    lines.append(bar)
    return "\n".join(line.rstrip() for line in lines)


def extract_region(source: str):
    """(region text, begin idx, end idx) between the markers, else None."""
    lines = source.splitlines()
    begin = end = None
    for i, line in enumerate(lines):
        if line.strip() == BEGIN_MARK and begin is None:
            begin = i
        elif line.strip() == END_MARK and begin is not None:
            end = i
            break
    if begin is None or end is None:
        return None
    return "\n".join(lines[begin + 1:end]), begin, end


def _normalize(text: str) -> str:
    return "\n".join(line.rstrip() for line in text.strip("\n").splitlines())


def region_matches(source: str, registry=None) -> bool:
    region = extract_region(source)
    if region is None:
        return False
    return _normalize(region[0]) == _normalize(
        render_lowering_table(registry))


def apply_fix(path=None, registry=None) -> bool:
    """Rewrite the marker region in dispatch.py. True if the file changed."""
    if path is None:
        from repro.kernels import dispatch as _d
        path = Path(_d.__file__)
    path = Path(path)
    source = path.read_text()
    region = extract_region(source)
    if region is None:
        raise SystemExit(
            f"{path}: missing {BEGIN_MARK!r} / {END_MARK!r} markers; "
            f"cannot rewrite the lowering table")
    _, begin, end = region
    lines = source.splitlines()
    new = (lines[:begin + 1] + render_lowering_table(registry).splitlines()
           + lines[end:])
    out = "\n".join(new) + ("\n" if source.endswith("\n") else "")
    if out == source:
        return False
    path.write_text(out)
    return True
