"""CX: shard_map / collective-axis contract checks.

Rules
-----
CX001  hard-coded axis-name string literal passed directly to a
       collective (``psum``/``pmax``/...). Mesh axis names are caller
       config; kernels must take them from the sharding-derived plan.
CX002  collective axis that resolves to a constant string (a module- or
       function-level ``AXIS = "data"``) — same bug, one assignment
       removed.
CX003  ``shard_map`` ``in_specs``/``out_specs`` arity vs the wrapped
       function's positional parameters / returned tuple.
CX004  (dynamic) the dispatch reduce-axis derivation: ``_red_axes`` must
       return exactly the plan axes that shard the *reduce* dimension
       (rows for col-norms, columns for row-norms). Runs by importing
       ``repro.kernels.dispatch`` and probing a synthetic plan.
"""
from __future__ import annotations

import ast

from .astutil import (ModuleInfo, Resolver, call_name, iter_calls, kwarg,
                      positional_arity)
from .findings import Finding

_COLLECTIVES = {"psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
                "ppermute", "axis_index"}


def run(modules, resolver=None, rel=None):
    resolver = resolver or Resolver()
    for mi in modules:
        resolver.add(mi)
    rel = rel or (lambda p: str(p))
    out = []
    for mi in modules:
        path = rel(mi.path)
        out.extend(_check_collectives(mi, resolver, path))
        out.extend(_check_shard_map(mi, resolver, path))
    return out


def _axis_arg(call, last):
    if last == "axis_index":
        pos = 0
    else:
        pos = 1
    if len(call.args) > pos:
        return call.args[pos]
    return kwarg(call, "axis_name")


def _axis_strings(node):
    """Axis-name string literals at the *top level* of an axis argument:
    a bare string, or elements of a tuple/list of axis names. Strings
    buried deeper (e.g. a ``"col"`` comparison inside a subscript that
    selects the plan axes) are not axis names."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [el.value for el in node.elts
                if isinstance(el, ast.Constant)
                and isinstance(el.value, str)]
    return []


def _check_collectives(mi, resolver, path):
    out = []
    for call in ast.walk(mi.tree):
        name = call_name(call)
        if not name:
            continue
        parts = name.split(".")
        last = parts[-1]
        if last not in _COLLECTIVES:
            continue
        if len(parts) > 1 and parts[-2] not in ("lax", "jax"):
            continue  # some other object's method, not a jax collective
        axis = _axis_arg(call, last)
        if axis is None:
            continue
        lits = _axis_strings(axis)
        if lits:
            out.append(Finding(
                "CX001", path, call.lineno,
                f"{last} over hard-coded axis name "
                f"{lits[0]!r}; derive collective axes from the "
                f"sharding plan, not string literals"))
            continue
        ctx = resolver.ctx_for(call, mi)
        for val, _ in resolver.resolve(axis, ctx):
            if val is axis:
                continue
            lits = _axis_strings(val)
            if lits:
                out.append(Finding(
                    "CX002", path, call.lineno,
                    f"{last} axis resolves to constant "
                    f"{lits[0]!r}; derive collective axes from "
                    f"the sharding plan, not module constants"))
                break
    return out


def _return_arities(fn):
    """Possible return-tuple arities of a FunctionDef/Lambda body."""
    arities = set()
    if isinstance(fn, ast.Lambda):
        body = fn.body
        arities.add(len(body.elts) if isinstance(body, ast.Tuple) else 1)
        return arities, True
    resolvable = True
    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and node.value is not None:
            if isinstance(node.value, ast.Tuple):
                arities.add(len(node.value.elts))
            elif isinstance(node.value, (ast.Name, ast.Constant,
                                         ast.Attribute, ast.Subscript,
                                         ast.BinOp, ast.UnaryOp)):
                arities.add(1)
            elif isinstance(node.value, ast.Call):
                nm = call_name(node.value) or ""
                # x.reshape(...) / x.astype(...) return one array
                if nm.split(".")[-1] in ("reshape", "astype", "sum", "mean",
                                         "transpose"):
                    arities.add(1)
                else:
                    resolvable = False
            else:
                resolvable = False
    return arities, resolvable


def _check_shard_map(mi, resolver, path):
    out = []
    for call in iter_calls(mi.tree, "shard_map"):
        if not call.args:
            continue
        ctx = resolver.ctx_for(call, mi)
        fns = resolver.resolve_function(call.args[0], ctx)
        in_specs = kwarg(call, "in_specs")
        out_specs = kwarg(call, "out_specs")
        n_in = None
        if isinstance(in_specs, (ast.Tuple, ast.List)):
            n_in = len(in_specs.elts)
        n_out = None
        if isinstance(out_specs, (ast.Tuple, ast.List)):
            n_out = len(out_specs.elts)
        for fn, _ in fns:
            if getattr(fn, "args", None) is not None and (
                    fn.args.vararg is not None):
                continue
            arity = positional_arity(fn)
            fname = getattr(fn, "name", "<lambda>")
            if n_in is not None and arity != n_in:
                out.append(Finding(
                    "CX003", path, call.lineno,
                    f"shard_map in_specs has {n_in} entries but wrapped "
                    f"fn {fname} takes {arity} positional args"))
            if n_out is not None and not isinstance(fn, ast.Lambda):
                rets, resolvable = _return_arities(fn)
                if resolvable and rets and n_out not in rets:
                    out.append(Finding(
                        "CX003", path, call.lineno,
                        f"shard_map out_specs has {n_out} entries but "
                        f"wrapped fn {fname} returns "
                        f"{sorted(rets)} value(s)"))
    return out


def check_dispatch_contract():
    """CX004: executable probe of the reduce-axis derivation.

    Col-kind norms reduce over rows, so the cross-shard psum must run
    over the axes sharding dim 1 of the padded (L, m, n) layout
    (``plan.spec3[1]``); row-kind over dim 2. A synthetic plan makes the
    mapping observable without any mesh.
    """
    out = []
    try:
        from repro.kernels import dispatch as _d
    except Exception as e:  # pragma: no cover - import env problems
        return [Finding("CX004", "src/repro/kernels/dispatch.py", 0,
                        f"could not import dispatch for the dynamic "
                        f"reduce-axis probe: {e!r}")]
    red = getattr(_d, "_red_axes", None)
    plan_cls = getattr(_d, "ShardPlan", None)
    if red is None or plan_cls is None:
        return [Finding("CX004", "src/repro/kernels/dispatch.py", 0,
                        "dispatch no longer exposes _red_axes/ShardPlan; "
                        "update the CX004 probe alongside the refactor")]
    try:
        plan = plan_cls(None, ((), ("row_ax",), ("col_ax",)))
        got_col = tuple(red(plan, "col"))
        got_row = tuple(red(plan, "row"))
    except Exception as e:
        return [Finding("CX004", "src/repro/kernels/dispatch.py", 0,
                        f"_red_axes probe raised {e!r}")]
    if got_col != ("row_ax",):
        out.append(Finding(
            "CX004", "src/repro/kernels/dispatch.py", 0,
            f"col-kind reduce axes must be the row-dim sharding axes "
            f"(spec3[1]); got {got_col!r}"))
    if got_row != ("col_ax",):
        out.append(Finding(
            "CX004", "src/repro/kernels/dispatch.py", 0,
            f"row-kind reduce axes must be the col-dim sharding axes "
            f"(spec3[2]); got {got_row!r}"))
    return out


def analyze_source(path, source):
    """Convenience for tests: analyze one synthetic module."""
    return run([ModuleInfo(path, source)])
