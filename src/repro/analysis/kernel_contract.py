"""KC: Pallas kernel contract checks (``pl.pallas_call`` sites).

Rules
-----
KC001  grid arity vs BlockSpec ``index_map`` arity — a 3-D grid with a
       2-arg index_map silently reads the wrong tiles.
KC002  ``input_output_aliases`` consistency — alias indices must be in
       range of the operand lists and the aliased input/output BlockSpecs
       must describe the same tiling (donation writes through the input's
       layout).
KC003  tile-iota remainder masking — every contraction (``dot_general``,
       ``@``, ``jnp.dot``, and ``+= jnp.sum(...)`` scratch accumulation)
       must either take an operand whose value provably reaches a
       ``broadcasted_iota`` remainder mask, or have its result flow into
       a ``jnp.where`` whose predicate does. Unmasked remainder lanes are
       undefined memory folded into the reduction.
KC004  f32 statistics scratch — accumulator/statistics scratch declared
       with an explicit low-precision dtype (bf16/f16/f8) loses the
       paper's parity claims; sums and softmax stats stay in float32.

All checks are best-effort AST resolution (see ``astutil``): anything
unresolvable is skipped rather than reported.
"""
from __future__ import annotations

import ast

from .astutil import (ModuleInfo, Resolver, call_name, dotted, iter_calls,
                      kwarg, positional_arity)
from .findings import Finding

_IOTA = ("broadcasted_iota", "iota")
_LOW_PRECISION = ("bfloat16", "float16", "half", "float8_e4m3fn",
                  "float8_e5m2", "float8_e4m3", "int8")


def run(modules, resolver=None, rel=None):
    resolver = resolver or Resolver()
    for mi in modules:
        resolver.add(mi)
    rel = rel or (lambda p: str(p))
    out = []
    for mi in modules:
        path = rel(mi.path)
        for call in iter_calls(mi.tree, "pallas_call"):
            ctx = resolver.ctx_for(call, mi)
            out.extend(_check_site(call, ctx, resolver, mi, path))
    return out


# -- helpers ---------------------------------------------------------------

def _kernel_target(call):
    """(display name, expression) of the kernel function operand."""
    if not call.args:
        return "<kernel>", None
    k = call.args[0]
    if (isinstance(k, ast.Call) and (call_name(k) or "").endswith("partial")
            and k.args):
        k = k.args[0]
    return dotted(k) or "<kernel>", k


def _blockspecs(node, ctx, resolver, depth=3):
    """Yield (BlockSpec Call, ctx, position) candidates from a specs expr."""
    if node is None or depth <= 0:
        return
    for val, vctx in resolver.resolve(node, ctx):
        if isinstance(val, (ast.Tuple, ast.List)):
            for i, el in enumerate(val.elts):
                for spec, sctx, _ in _blockspecs(el, vctx, resolver,
                                                 depth - 1):
                    yield spec, sctx, i
        elif isinstance(val, ast.Call):
            if (call_name(val) or "").endswith("BlockSpec"):
                yield val, vctx, 0


def _index_map(spec):
    if len(spec.args) > 1:
        return spec.args[1]
    return kwarg(spec, "index_map")


def _literal_elements(node, ctx, resolver):
    """First literal tuple/list candidate of a specs expr, else None."""
    if node is None:
        return None
    for val, vctx in resolver.resolve(node, ctx):
        if isinstance(val, (ast.Tuple, ast.List)):
            return val.elts, vctx
    return None


def _spec_shapes(node, ctx, resolver):
    """Set of ast.dump()s of the BlockSpec tilings an expr resolves to."""
    return {ast.dump(spec) for spec, _, _ in _blockspecs(node, ctx, resolver)}


def _fn_reaches_iota(fn, mi, resolver, seen, depth=3):
    if depth <= 0 or id(fn) in seen:
        return False
    seen.add(id(fn))
    for node in ast.walk(fn):
        name = call_name(node)
        if name and name.split(".")[-1] in _IOTA:
            return True
    ctx = ((mi.env,), mi)
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            for f, fctx in resolver.resolve_function(node.func, ctx, 3):
                if isinstance(f, ast.Lambda):
                    continue
                if _fn_reaches_iota(f, fctx[1], resolver, seen, depth - 1):
                    return True
    return False


def _reaches_iota(expr, ctx, resolver, depth=5, visited=None):
    """Does this expression's value (transitively) involve an iota mask?"""
    if expr is None or depth <= 0:
        return False
    visited = visited if visited is not None else set()
    if id(expr) in visited:
        return False
    visited.add(id(expr))
    for node in ast.walk(expr):
        name = call_name(node)
        if name and name.split(".")[-1] in _IOTA:
            return True
    for node in ast.walk(expr):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            for f, fctx in resolver.resolve_function(node.func, ctx, 3):
                if isinstance(f, ast.Lambda):
                    if _reaches_iota(f.body, fctx, resolver, depth - 1,
                                     visited):
                        return True
                elif _fn_reaches_iota(f, fctx[1], resolver, set()):
                    return True
    for node in ast.walk(expr):
        if isinstance(node, ast.Name):
            for val, vctx in resolver.resolve(node, ctx, 4):
                if val is node:
                    continue
                if _reaches_iota(val, vctx, resolver, depth - 1, visited):
                    return True
    return False


def _contractions(fn):
    """Yield (node, operand exprs) for contraction sites in a kernel."""
    for node in ast.walk(fn):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            yield node, [node.left, node.right]
            continue
        name = call_name(node)
        if name:
            last = name.split(".")[-1]
            if last in ("dot_general", "dot", "matmul") and len(node.args) >= 2:
                yield node, list(node.args[:2])
    for node in ast.walk(fn):
        if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Add):
            for sub in ast.walk(node.value):
                nm = call_name(sub)
                if nm and nm.split(".")[-1] == "sum" and sub.args:
                    yield sub, [sub.args[0]]


def _result_masked(node, fn, mi, resolver):
    """Result-flow masking: the contraction's value lands in a Name that
    is later consumed inside an iota-predicated ``jnp.where``."""
    parent = mi.parents.get(node)
    while parent is not None and isinstance(parent, (ast.BinOp, ast.Call)):
        node, parent = parent, mi.parents.get(parent)
    if not (isinstance(parent, ast.Assign) and len(parent.targets) == 1
            and isinstance(parent.targets[0], ast.Name)):
        return False
    target = parent.targets[0].id
    for call in iter_calls(fn, "where"):
        if not call.args:
            continue
        used = any(isinstance(n, ast.Name) and n.id == target
                   for a in call.args for n in ast.walk(a))
        if used and _reaches_iota(call.args[0],
                                  resolver.ctx_for(call, mi), resolver):
            return True
    return False


def _dtype_bad(node, ctx, resolver):
    for val, _ in resolver.resolve(node, ctx, 3):
        name = dotted(val)
        if name is None and isinstance(val, ast.Constant) \
                and isinstance(val.value, str):
            name = val.value
        if name is None:
            continue
        last = name.split(".")[-1]
        if last in _LOW_PRECISION:
            return last
    return None


# -- per-site checks -------------------------------------------------------

def _check_site(call, ctx, resolver, mi, path):
    out = []
    kname, kexpr = _kernel_target(call)

    # KC001: grid arity vs index_map arity
    grid_lens = set()
    grid_node = kwarg(call, "grid")
    if grid_node is not None:
        for val, _ in resolver.resolve(grid_node, ctx):
            if isinstance(val, (ast.Tuple, ast.List)):
                grid_lens.add(len(val.elts))
            elif isinstance(val, ast.Constant) and isinstance(val.value, int):
                grid_lens.add(1)
    in_specs = kwarg(call, "in_specs")
    out_specs = kwarg(call, "out_specs")
    if len(grid_lens) == 1:
        grid_arity = next(iter(grid_lens))
        for role, specs_node in (("in", in_specs), ("out", out_specs)):
            for spec, sctx, pos in _blockspecs(specs_node, ctx, resolver):
                imap = _index_map(spec)
                if imap is None:
                    continue
                arities = {positional_arity(f) for f, _
                           in resolver.resolve_function(imap, sctx)}
                if arities and grid_arity not in arities:
                    out.append(Finding(
                        "KC001", path, spec.lineno,
                        f"{kname}: {role}_specs[{pos}] index_map takes "
                        f"{sorted(arities)} grid indices but the grid "
                        f"has arity {grid_arity}"))

    # KC002: input_output_aliases bounds + matching tilings
    alias = kwarg(call, "input_output_aliases")
    if isinstance(alias, ast.Dict):
        ins = _literal_elements(in_specs, ctx, resolver)
        outs = _literal_elements(out_specs, ctx, resolver)
        n_in = len(ins[0]) if ins else None
        n_out = len(outs[0]) if outs else (
            1 if out_specs is not None and not isinstance(
                out_specs, (ast.Tuple, ast.List)) else None)
        for knode, vnode in zip(alias.keys, alias.values):
            if not (isinstance(knode, ast.Constant)
                    and isinstance(vnode, ast.Constant)):
                continue
            i, o = knode.value, vnode.value
            if not isinstance(i, int) or not isinstance(o, int):
                continue
            if (n_in is not None and i >= n_in) or \
                    (n_out is not None and o >= n_out):
                out.append(Finding(
                    "KC002", path, alias.lineno,
                    f"{kname}: input_output_aliases {{{i}: {o}}} is out "
                    f"of range for {n_in} inputs / {n_out} outputs"))
                continue
            in_el = ins[0][i] if ins else None
            out_el = outs[0][o] if outs else out_specs
            if in_el is None or out_el is None:
                continue
            a = _spec_shapes(in_el, ins[1] if ins else ctx, resolver)
            b = _spec_shapes(out_el, outs[1] if outs else ctx, resolver)
            if a and b and not (a & b):
                out.append(Finding(
                    "KC002", path, alias.lineno,
                    f"{kname}: aliased operand {i} -> output {o} have "
                    f"different BlockSpec tilings (donation writes "
                    f"through the input layout)"))

    # KC003: remainder masking on contractions in the kernel body
    for fn, fctx in resolver.resolve_function(kexpr, ctx) if kexpr is not None \
            else ():
        if isinstance(fn, ast.Lambda):
            continue
        fmi = fctx[1]
        seen_lines = set()
        for node, operands in _contractions(fn):
            line = getattr(node, "lineno", fn.lineno)
            if line in seen_lines:
                continue
            # scope chain of the *contraction site* — kernels hide their
            # compute in nested @pl.when functions with their own locals
            site_ctx = resolver.ctx_for(node, fmi)
            masked = any(_reaches_iota(op, site_ctx, resolver)
                         for op in operands)
            if not masked:
                masked = _result_masked(node, fn, fmi, resolver)
            if not masked:
                seen_lines.add(line)
                out.append(Finding(
                    "KC003", path, line,
                    f"{kname}: contraction in kernel body has no "
                    f"tile-iota remainder mask on any operand and its "
                    f"result never flows through a masked jnp.where"))

    # KC004: low-precision statistics scratch
    scratch = kwarg(call, "scratch_shapes")
    if scratch is not None:
        for node in ast.walk(scratch):
            name = call_name(node)
            if not name or name.split(".")[-1] not in ("VMEM", "SMEM"):
                continue
            if len(node.args) < 2:
                continue
            bad = _dtype_bad(node.args[1], ctx, resolver)
            if bad:
                out.append(Finding(
                    "KC004", path, node.lineno,
                    f"{kname}: scratch buffer declared {bad}; "
                    f"accumulator/statistics scratch must be float32"))
    return out


def analyze_source(path, source, extra=None):
    """Convenience for tests: analyze one synthetic module."""
    modules = [ModuleInfo(path, source)]
    for p, s in (extra or {}).items():
        modules.append(ModuleInfo(p, s))
    return run(modules)
