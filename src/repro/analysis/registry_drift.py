"""RD: registry / docstring / pipeline drift checks.

Rules
-----
RD001  the dispatch docstring's per-optimizer lowering table differs from
       the one rendered from ``OPTIMIZER_REGISTRY`` (or the marker region
       is missing). Fix with ``python -m repro.analysis --fix``.
RD002  a dispatch ``REGISTRY`` op has no row in the docstring coverage
       matrix (the op is live but undocumented).
RD003  an optimizer's ``fused`` flag contradicts the Stages compositions
       it actually builds: the registry claims fused but no per-label
       plan lowers (or vice versa). Uses the ``plans`` carried on the
       built :class:`GradientTransformation` and mirrors the pipeline's
       ``_use_kernel`` predicate.
RD004  ``fused=True`` on a factory with no ``impl`` kwarg — the flag is
       unreachable (``make_optimizer`` could never build the fused
       variant).
RD005  a ``kind`` default outside dispatch's ``FUSED_KINDS`` marked
       fused, or a fused-coverable kind marked unfused.

These checks run against the *live* modules (they import
``repro.core.api`` and ``repro.kernels.dispatch``), with injection
points for tests to mutate a registry row or the docstring source.
"""
from __future__ import annotations

import ast
from pathlib import Path

from .findings import Finding
from .lowering import region_matches

_DISPATCH_REL = "src/repro/kernels/dispatch.py"
_API_REL = "src/repro/core/api.py"


def _lowerable(stages, fused_kinds) -> bool:
    """Mirror of ``core.pipeline``'s `_use_kernel` static predicate."""
    return (stages.norm in fused_kinds and not stages.adam
            and not getattr(stages, "adams", False)
            and stages.project is None and not stages.standardize
            and not stages.nesterov)


def run(registry=None, dispatch_source=None, build=True):
    """Run all RD checks.

    ``registry``: mapping name -> OptimizerSpec (default: the live
    ``OPTIMIZER_REGISTRY``). ``dispatch_source``: override the dispatch
    module source text (tests mutate the docstring). ``build``: also
    build every optimizer and check RD003 (slower; pure-CPU tracing of
    the factory closures only, no kernels run).
    """
    from repro.core.api import OPTIMIZER_REGISTRY
    from repro.kernels import dispatch as _dispatch

    registry = OPTIMIZER_REGISTRY if registry is None else registry
    if dispatch_source is None:
        dispatch_source = Path(_dispatch.__file__).read_text()
    out = []

    # RD001: generated lowering table in sync with the registry
    if not region_matches(dispatch_source, registry):
        out.append(Finding(
            "RD001", _DISPATCH_REL, 0,
            "dispatch docstring lowering table is out of sync with "
            "OPTIMIZER_REGISTRY; run `python -m repro.analysis --fix`"))

    # RD002: every dispatch op documented in the coverage-matrix docstring
    doc = ast.get_docstring(ast.parse(dispatch_source)) or ""
    for op in _dispatch.REGISTRY:
        if f"``{op}" not in doc and op not in doc:
            out.append(Finding(
                "RD002", _DISPATCH_REL, 0,
                f"dispatch op {op!r} has no row in the docstring "
                f"coverage matrix"))

    fused_kinds = tuple(_dispatch.FUSED_KINDS)

    for name, spec in registry.items():
        # RD004: fused flag must be reachable through the factory
        if spec.fused and "impl" not in spec.valid_kwargs():
            out.append(Finding(
                "RD004", _API_REL, 0,
                f"optimizer {name!r} is marked fused but its factory "
                f"has no `impl` kwarg; the fused path is unreachable"))
        # RD005: kind default vs dispatch coverage
        kind = spec.defaults.get("kind")
        if kind is not None and spec.fused != (kind in fused_kinds):
            out.append(Finding(
                "RD005", _API_REL, 0,
                f"optimizer {name!r} has kind={kind!r} but "
                f"fused={spec.fused}; dispatch FUSED_KINDS is "
                f"{fused_kinds}"))
        # RD003: fused flag vs the Stages plans that actually lower
        if not build:
            continue
        try:
            kw = dict(spec.defaults)
            if spec.fused and "impl" in spec.valid_kwargs():
                kw.setdefault("impl", "fused")
            tx = spec.factory(1e-3, **kw)
        except Exception as e:
            out.append(Finding(
                "RD003", _API_REL, 0,
                f"optimizer {name!r} factory failed to build with its "
                f"registry defaults: {e!r}"))
            continue
        plans = getattr(tx, "plans", None)
        if plans is None:
            continue  # non-pipeline transform; nothing to introspect
        lowers = any(_lowerable(st, fused_kinds) for st in plans.values())
        if lowers != spec.fused:
            out.append(Finding(
                "RD003", _API_REL, 0,
                f"optimizer {name!r}: registry says fused={spec.fused} "
                f"but its stage plans "
                f"{'do' if lowers else 'do not'} lower to the fused "
                f"kernels"))
    return out
