"""repro-lint: AST-based contract checks for kernels, dispatch, pipeline.

Run ``python -m repro.analysis`` (see ``__main__.py`` for flags and
``README.md`` for the rule catalogue). Passes:

* ``registry_drift``   (RD00x) registry / docstring / Stages-plan drift
* ``kernel_contract``  (KC00x) pallas_call grid/BlockSpec/alias/mask/f32
* ``collective_axes``  (CX00x) shard_map specs + collective axis sourcing
* ``jax_hygiene``      (JH00x) tracer branches, TypeError probes, env-in-jit
"""
from .findings import Finding, load_baseline, split_by_baseline, \
    write_baseline
from .lowering import apply_fix, render_lowering_table

__all__ = ["Finding", "load_baseline", "split_by_baseline",
           "write_baseline", "apply_fix", "render_lowering_table"]
