"""Shared AST plumbing for the ``repro.analysis`` passes.

The passes need to answer questions like "what tuple does the ``grid``
kwarg resolve to?" or "does this contraction operand's value reach a
``broadcasted_iota`` call?" across local assignments, if/else candidate
branches, tuple unpacks, helper-function return values, and (for the
kernel helpers shared between kernel packages) relative imports. This
module provides a small best-effort resolver for that: every resolution
returns a *list of candidates*, each paired with the scope context it
was found in, and passes treat "unresolvable" as "skip / assume fine" —
the analyzer prefers false negatives over noisy false positives.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Optional


def dotted(node) -> Optional[str]:
    """``'pl.pallas_call'`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node) -> Optional[str]:
    if isinstance(node, ast.Call):
        return dotted(node.func)
    return None


def kwarg(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _collect_env(body, env):
    """Record name -> [entry] bindings for a statement list.

    Entries: ``("value", node)`` plain assignment, ``("unpack", node, i)``
    tuple-unpack element i, ``("func", FunctionDef)`` nested def. Control
    flow (if/for/while/with/try) is flattened — multiple bindings of one
    name become multiple candidates. Nested function bodies are *not*
    descended into (they are separate scopes resolved lazily).
    """
    for node in body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            env.setdefault(node.name, []).append(("func", node))
            continue
        if isinstance(node, ast.ClassDef):
            continue
        if isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    env.setdefault(tgt.id, []).append(("value", node.value))
                elif isinstance(tgt, (ast.Tuple, ast.List)):
                    for i, el in enumerate(tgt.elts):
                        if isinstance(el, ast.Name):
                            env.setdefault(el.id, []).append(
                                ("unpack", node.value, i))
        elif isinstance(node, ast.AnnAssign):
            if isinstance(node.target, ast.Name) and node.value is not None:
                env.setdefault(node.target.id, []).append(
                    ("value", node.value))
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(node, field, None)
            if sub:
                _collect_env(sub, env)
        for handler in getattr(node, "handlers", ()) or ():
            _collect_env(handler.body, env)


class ModuleInfo:
    """Parsed module plus its name-resolution indexes."""

    def __init__(self, path, source: Optional[str] = None):
        self.path = Path(path)
        self.source = (source if source is not None
                       else self.path.read_text())
        self.tree = ast.parse(self.source)
        self.parents = {}
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                self.parents[child] = parent
        self.env = {}
        _collect_env(self.tree.body, self.env)
        # local name -> (module string, original name, relative level)
        self.imports = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                for alias in node.names:
                    self.imports[alias.asname or alias.name] = (
                        node.module, alias.name, node.level)

    def enclosing_function(self, node):
        while node is not None:
            node = self.parents.get(node)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return node
        return None


class Resolver:
    """Best-effort value resolution across one or more modules.

    ``modules`` maps resolved file Paths to :class:`ModuleInfo`, enabling
    cross-module lookups through relative ``from .. import`` statements
    (e.g. ``scale_head`` importing ``_red_mask`` from ``colnorm``).
    """

    def __init__(self, modules: Optional[dict] = None):
        self.modules = dict(modules or {})
        self._func_envs = {}

    def add(self, mi: ModuleInfo):
        self.modules[mi.path.resolve()] = mi

    # -- scope construction ------------------------------------------------

    def func_env(self, fn) -> dict:
        cached = self._func_envs.get(id(fn))
        if cached is None:
            cached = {}
            args = fn.args
            for a in (list(args.posonlyargs) + list(args.args)
                      + list(args.kwonlyargs)):
                cached.setdefault(a.arg, []).append(("param", a))
            _collect_env(fn.body, cached)
            self._func_envs[id(fn)] = cached
        return cached

    def ctx_for(self, node, mi: ModuleInfo):
        """Scope chain (innermost first) for a node's lexical position."""
        scopes = []
        fn = mi.enclosing_function(node)
        while fn is not None:
            scopes.append(self.func_env(fn))
            fn = mi.enclosing_function(fn)
        scopes.append(mi.env)
        return (tuple(scopes), mi)

    def _import_target(self, mi: ModuleInfo, name: str):
        """Resolve ``from X import name`` to (ModuleInfo, name) if parsed."""
        imp = mi.imports.get(name)
        if imp is None:
            return None
        module, orig, level = imp
        if level:
            base = mi.path.resolve().parents[level - 1]
            cand = base.joinpath(*module.split("."))
        else:
            parts = module.split(".")
            root = mi.path.resolve()
            # walk up until the first path component of the module matches
            cand = None
            for up in root.parents:
                if up.name == parts[0] and len(parts) > 1:
                    cand = up.joinpath(*parts[1:])
                    break
            if cand is None:
                return None
        for p in (cand.with_suffix(".py"), cand / "__init__.py"):
            other = self.modules.get(p.resolve())
            if other is not None:
                return other, orig
        return None

    # -- resolution --------------------------------------------------------

    def resolve(self, node, ctx, depth: int = 6):
        """Return candidate ``(node, ctx)`` values for an expression."""
        if node is None or depth <= 0:
            return [] if node is None else [(node, ctx)]
        scopes, mi = ctx
        if isinstance(node, ast.Name):
            for env in scopes:
                entries = env.get(node.id)
                if not entries:
                    continue
                out = []
                for entry in entries:
                    kind = entry[0]
                    if kind == "value":
                        out.extend(self.resolve(entry[1], ctx, depth - 1))
                    elif kind == "func":
                        out.append((entry[1], ctx))
                    elif kind == "param":
                        out.append((node, ctx))
                    elif kind == "unpack":
                        hit = False
                        for val, vctx in self.resolve(entry[1], ctx,
                                                      depth - 1):
                            if (isinstance(val, (ast.Tuple, ast.List))
                                    and entry[2] < len(val.elts)):
                                out.extend(self.resolve(
                                    val.elts[entry[2]], vctx, depth - 1))
                                hit = True
                        if not hit:
                            out.append((node, ctx))
                return out or [(node, ctx)]
            target = self._import_target(mi, node.id)
            if target is not None:
                other, orig = target
                octx = ((other.env,), other)
                if orig in other.env:
                    return self.resolve(ast.Name(id=orig, ctx=ast.Load()),
                                        octx, depth - 1)
            return [(node, ctx)]
        if isinstance(node, ast.IfExp):
            return (self.resolve(node.body, ctx, depth - 1)
                    + self.resolve(node.orelse, ctx, depth - 1))
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
            out = []
            for val, vctx in self.resolve(node.func, ctx, depth - 1):
                if isinstance(val, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    fenv = self.func_env(val)
                    vscopes, vmi = vctx
                    inner = ((fenv,) + tuple(vscopes), vmi)
                    for stmt in ast.walk(val):
                        if (isinstance(stmt, ast.Return)
                                and stmt.value is not None):
                            out.extend(self.resolve(stmt.value, inner,
                                                    depth - 1))
            return out or [(node, ctx)]
        return [(node, ctx)]

    def resolve_function(self, node, ctx, depth: int = 4):
        """Candidate FunctionDef/Lambda values for a callable expression."""
        out = []
        for val, vctx in self.resolve(node, ctx, depth):
            if isinstance(val, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                out.append((val, vctx))
        return out

    def tuple_lengths(self, node, ctx) -> set:
        """Possible literal lengths of a tuple/list-valued expression."""
        lens = set()
        for val, _ in self.resolve(node, ctx):
            if isinstance(val, (ast.Tuple, ast.List)):
                lens.add(len(val.elts))
        return lens


def positional_arity(fn) -> int:
    args = fn.args
    return len(args.posonlyargs) + len(args.args)


def iter_calls(tree, suffix: str):
    """Yield Call nodes whose dotted callee name ends with ``suffix``."""
    for node in ast.walk(tree):
        name = call_name(node)
        if name is not None and (name == suffix
                                 or name.endswith("." + suffix)):
            yield node
