"""Gradient compression for cross-pod (DCN) gradient reduction.

At multi-pod scale the pod-axis gradient all-reduce crosses data-center
network, not ICI; compressing gradients to int8 before the cross-pod hop
quarters that traffic.

The paper-specific insight: **column-wise int8 quantization composes
exactly with SCALE**. colnorm(g) is invariant to any positive per-column
rescaling, so the per-column quantization scale — the lossy part of most
compression schemes — cancels identically in SCALE's update; the only
error is the 8-bit rounding *within* a column (bounded relative error
1/254 per element). For Adam-family optimizers the scale does not cancel
and compression bias accumulates in v_t. Property-tested in
tests/test_compression.py.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .types import GradientTransformation, PyTree

_I8_MAX = 127.0


class CompressedLeaf(NamedTuple):
    q: jnp.ndarray       # int8 payload
    scale: jnp.ndarray   # per-column f32 scale (1, ..., d_out)


def compress_leaf(g: jnp.ndarray) -> CompressedLeaf:
    """Column-wise symmetric int8 quantization (matrices; reduction axis -2)."""
    gf = g.astype(jnp.float32)
    if g.ndim >= 2:
        amax = jnp.max(jnp.abs(gf), axis=-2, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(gf), keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / _I8_MAX
    q = jnp.clip(jnp.round(gf / scale), -_I8_MAX, _I8_MAX).astype(jnp.int8)
    return CompressedLeaf(q, scale)


def decompress_leaf(c: CompressedLeaf, dtype) -> jnp.ndarray:
    return (c.q.astype(jnp.float32) * c.scale).astype(dtype)


def compress(grads: PyTree) -> PyTree:
    return jax.tree_util.tree_map(compress_leaf, grads)


def decompress(comp: PyTree, like: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda c, g: decompress_leaf(c, g.dtype), comp, like,
        is_leaf=lambda x: isinstance(x, CompressedLeaf))


def compressed(tx: GradientTransformation) -> GradientTransformation:
    """Wrap an optimizer so it sees int8-roundtripped gradients — exactly
    what arrives after a compressed cross-pod reduction."""

    def init(params):
        return tx.init(params)

    def update(grads, state, params=None):
        rt = decompress(compress(grads), grads)
        return tx.update(rt, state, params)

    return GradientTransformation(init, update)


def compression_ratio(grads: PyTree) -> float:
    """Wire-bytes ratio achieved by int8 + per-column f32 scales."""
    orig = comp = 0
    for g in jax.tree_util.tree_leaves(grads):
        n = g.size
        cols = n // g.shape[-2] if g.ndim >= 2 else 1
        orig += n * g.dtype.itemsize
        comp += n * 1 + cols * 4
    return orig / max(comp, 1)
