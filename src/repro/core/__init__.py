"""repro.core — the paper's contribution: SCALE + baseline optimizers."""
from .api import (OPTIMIZER_NAMES, OPTIMIZER_REGISTRY, OptimizerSpec,
                  make_optimizer)
from .labels import LabelRules, label_tree, partition_sizes
from .memory import (MemoryReport, memory_report,
                     momentum_eligible_elements, optimizer_state_elements)
from .normalization import (colnorm, normalize, NORMALIZATIONS,
                            ns_orthogonalize, resolve_larger, rownorm,
                            signnorm, svd_orthogonalize)
from .optimizers import adam, muon, normalized_sgd, sgd, stable_spam_adam
from .pipeline import PipeState, Project, Stages, build_pipeline
from .compression import (compress, compressed, compression_ratio,
                          decompress)
from .galore import apollo, apollo_mini, fira, galore
from .scale import ScaleState, scale
from .schedules import constant, linear_warmup_cosine
from .swan import swan
from .types import (GradientTransformation, apply_updates, chain,
                    global_norm, identity)

__all__ = [
    "OPTIMIZER_NAMES", "OPTIMIZER_REGISTRY", "OptimizerSpec",
    "PipeState", "Project", "Stages", "build_pipeline",
    "make_optimizer", "LabelRules", "label_tree",
    "partition_sizes", "MemoryReport", "memory_report",
    "momentum_eligible_elements", "optimizer_state_elements", "colnorm", "normalize", "NORMALIZATIONS",
    "resolve_larger",
    "ns_orthogonalize", "rownorm", "signnorm", "svd_orthogonalize",
    "adam", "muon", "normalized_sgd", "sgd", "stable_spam_adam",
    "apollo", "apollo_mini", "fira", "galore", "compress", "compressed",
    "compression_ratio", "decompress", "ScaleState", "scale",
    "constant", "linear_warmup_cosine", "swan", "GradientTransformation",
    "apply_updates", "chain", "global_norm", "identity",
]
