"""Parameter labeling: which update branch each parameter takes.

Groups (paper Algorithm 1 + Appendix C):
  * ``last``   — the LM head (logit-producing matrix); gets momentum + colnorm.
  * ``first``  — the token embedding (used by ablations / SWAN / mmt-first+last).
  * ``matrix`` — every other >=2-D weight; gets stateless normalization.
  * ``vector`` — <=1-D params (norm scales, biases, A_log/D in Mamba); Adam.

Classification is by tree path (joined with '/') against configurable
substrings, with the dimensionality fallback. This matches how the paper's
torch implementation special-cases ``lm_head`` and ``embed`` modules.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

LAST_LAYER_PATTERNS = (r"lm_head", r"output_head", r"codebook_head")
FIRST_LAYER_PATTERNS = (r"tok_embed", r"embed_tokens", r"frame_embed", r"patch_embed")
# Patterns promoted to ``last`` by LabelRules.tied(): with tie_embeddings the
# token embedding IS the logit-producing matrix, stored transposed ((V, D)
# instead of the head's (D, V) use layout).
TIED_LAST_PATTERNS = (r"tok_embed", r"embed_tokens")
# Params that are per-layer scales/biases/SSM scalars even when stacked to
# >=2-D by scan-over-layers. These take the Adam branch (paper Appendix C).
VECTOR_PATTERNS = (r"norm", r"bias", r"/b[qkv]$", r"A_log", r"dt_bias",
                   r"/D$", r"conv_b", r"conv_w", r"/s$", r"scale")


@dataclasses.dataclass(frozen=True)
class LabelRules:
    last: tuple = LAST_LAYER_PATTERNS
    first: tuple = FIRST_LAYER_PATTERNS
    vector: tuple = VECTOR_PATTERNS
    # Logit-producing matrices stored transposed: (d_out, d_in) = (V, D)
    # instead of the head's (d_in, d_out) use layout. Matching paths are
    # labeled ``last`` (ahead of ``first``) and flagged by ``transposed`` so
    # SCALE can flip its col/row norm kind — the normalization must follow
    # the *output* dimension, not the storage axis.
    tied_last: tuple = ()

    @classmethod
    def tied(cls, tied_last: tuple = TIED_LAST_PATTERNS, **kw) -> "LabelRules":
        """Rules for a ``tie_embeddings=True`` model: the token embedding is
        the LM head, so it takes the ``last`` (momentum) branch."""
        return cls(tied_last=tuple(tied_last), **kw)

    def classify(self, path: str, ndim: int) -> str:
        if ndim <= 1:
            return "vector"
        for pat in self.vector:
            if re.search(pat, path):
                return "vector"
        # tied heads outrank ``first``: with weight tying the embedding IS
        # the logit-producing matrix (paper: momentum lives on the output
        # layer because its gradient variance is highest)
        for pat in self.tied_last:
            if re.search(pat, path):
                return "last"
        for pat in self.last:
            if re.search(pat, path):
                return "last"
        for pat in self.first:
            if re.search(pat, path):
                return "first"
        return "matrix"

    def transposed(self, path: str, ndim: int = 2) -> bool:
        """True when ``path`` names a matrix stored (d_out, d_in) — a tied
        head; col/row norm kinds must be flipped for it."""
        if ndim <= 1:
            return False
        return any(re.search(pat, path) for pat in self.tied_last)


# Coarse layer groups for observability (paper Fig. 4 / Fig. 10 axes):
# the output head vs the token embedding vs everything in between. This is
# deliberately coarser than the optimizer labels above — the paper's
# variance/column-norm figures are stated per *layer group*, and both the
# offline benchmark (benchmarks/variance_analysis.py) and the live in-jit
# stats collector (repro.obs.stats) must bucket identically.
LAYER_GROUPS = ("embedding", "hidden", "lm_head")


def layer_group(path: str, tied: bool = False) -> str:
    """Map a parameter tree path to its Fig. 4 layer group.

    ``tied=True`` mirrors :meth:`LabelRules.tied`: with weight tying the
    token embedding IS the logit-producing matrix, so it reports under
    ``lm_head`` (where the paper's variance/col-norm claims live) instead
    of ``embedding``.
    """
    for pat in LAST_LAYER_PATTERNS:
        if re.search(pat, path):
            return "lm_head"
    if tied:
        for pat in TIED_LAST_PATTERNS:
            if re.search(pat, path):
                return "lm_head"
    for pat in FIRST_LAYER_PATTERNS:
        if re.search(pat, path):
            return "embedding"
    return "hidden"


def path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def label_tree(params: PyTree, rules: LabelRules | None = None,
               require_last: bool = False) -> PyTree:
    """Return a pytree of str labels mirroring ``params``.

    ``require_last=True`` (used by optimizers whose head branch matters,
    i.e. SCALE's momentum group): a tree that contains an embedding-like
    (``first``) matrix but no ``last``-labeled matrix is a hard error. This
    is exactly the ``tie_embeddings=True`` failure mode — the tied model has
    no ``lm_head`` leaf, so under the default rules the logit-producing
    matrix would silently land outside the ``last`` group and the head
    would train with no momentum and the wrong norm axis.
    """
    rules = rules or LabelRules()

    def f(kp, leaf):
        return rules.classify(path_str(kp), jnp.ndim(leaf))

    labels = jax.tree_util.tree_map_with_path(f, params)
    if require_last:
        labs = set(jax.tree_util.tree_leaves(labels))
        if "first" in labs and "last" not in labs:
            raise ValueError(
                "params contain an embedding-like ('first') matrix but no "
                "logit-producing ('last') matrix matched the label rules. "
                "For a tie_embeddings=True model the head IS the embedding: "
                "build the optimizer with rules=LabelRules.tied() so the "
                "tied matrix takes the 'last' (momentum + output-dim "
                "normalization) branch. For a custom head name, extend "
                "LabelRules(last=...).")
    return labels


def transposed_tree(params: PyTree, rules: LabelRules | None = None) -> PyTree:
    """Bool pytree: True where a leaf is a transposed-storage (tied) head."""
    rules = rules or LabelRules()

    def f(kp, leaf):
        return rules.transposed(path_str(kp), jnp.ndim(leaf))

    return jax.tree_util.tree_map_with_path(f, params)


def partition_sizes(params: PyTree, rules: LabelRules | None = None) -> dict:
    """Parameter counts per label group (for memory accounting & logging)."""
    labels = label_tree(params, rules)
    sizes: dict = {}
    for lab, leaf in zip(
        jax.tree_util.tree_leaves(labels), jax.tree_util.tree_leaves(params)
    ):
        sizes[lab] = sizes.get(lab, 0) + int(jnp.size(leaf))
    return sizes
