"""Parameter labeling: which update branch each parameter takes.

Groups (paper Algorithm 1 + Appendix C):
  * ``last``   — the LM head (logit-producing matrix); gets momentum + colnorm.
  * ``first``  — the token embedding (used by ablations / SWAN / mmt-first+last).
  * ``matrix`` — every other >=2-D weight; gets stateless normalization.
  * ``vector`` — <=1-D params (norm scales, biases, A_log/D in Mamba); Adam.

Classification is by tree path (joined with '/') against configurable
substrings, with the dimensionality fallback. This matches how the paper's
torch implementation special-cases ``lm_head`` and ``embed`` modules.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

LAST_LAYER_PATTERNS = (r"lm_head", r"output_head", r"codebook_head")
FIRST_LAYER_PATTERNS = (r"tok_embed", r"embed_tokens", r"frame_embed", r"patch_embed")
# Params that are per-layer scales/biases/SSM scalars even when stacked to
# >=2-D by scan-over-layers. These take the Adam branch (paper Appendix C).
VECTOR_PATTERNS = (r"norm", r"bias", r"/b[qkv]$", r"A_log", r"dt_bias",
                   r"/D$", r"conv_b", r"conv_w", r"/s$", r"scale")


@dataclasses.dataclass(frozen=True)
class LabelRules:
    last: tuple = LAST_LAYER_PATTERNS
    first: tuple = FIRST_LAYER_PATTERNS
    vector: tuple = VECTOR_PATTERNS

    def classify(self, path: str, ndim: int) -> str:
        if ndim <= 1:
            return "vector"
        for pat in self.vector:
            if re.search(pat, path):
                return "vector"
        for pat in self.last:
            if re.search(pat, path):
                return "last"
        for pat in self.first:
            if re.search(pat, path):
                return "first"
        return "matrix"


def path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def label_tree(params: PyTree, rules: LabelRules | None = None) -> PyTree:
    """Return a pytree of str labels mirroring ``params``."""
    rules = rules or LabelRules()

    def f(kp, leaf):
        return rules.classify(path_str(kp), jnp.ndim(leaf))

    return jax.tree_util.tree_map_with_path(f, params)


def partition_sizes(params: PyTree, rules: LabelRules | None = None) -> dict:
    """Parameter counts per label group (for memory accounting & logging)."""
    labels = label_tree(params, rules)
    sizes: dict = {}
    for lab, leaf in zip(
        jax.tree_util.tree_leaves(labels), jax.tree_util.tree_leaves(params)
    ):
        sizes[lab] = sizes.get(lab, 0) + int(jnp.size(leaf))
    return sizes
