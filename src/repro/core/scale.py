"""SCALE — Stochastic Column-normalized Last-layer momentum (Algorithm 1).

Per parameter group:
  * last layer (LM head):   m <- beta*m + (1-beta)*g ;  delta = -lr * colnorm(m)
  * other matrices:         delta = -lr * colnorm(g)           (stateless)
  * vector params:          Adam (negligible memory; Appendix C)

SCALE is expressed as a stage composition over the shared leaf-update
pipeline (:mod:`repro.core.pipeline`): the momentum groups are
``Stages(momentum=beta, norm=...)``, the other matrices ``Stages(norm=...)``
and vectors the Adam stage. The pipeline owns the kernel lowering, the
delta/write entry points, and the state treedef — this module only builds
the per-label plans.

Ablation knobs reproduce the paper's Tables 8 and 13:
  * ``momentum_on``: which groups carry momentum (default ("last",)).
  * ``norm_last`` / ``norm_rest``: normalization kind per group
    (Table 13 mixed schemes, incl. "larger" = normalize along larger dim).

Implementations (``impl``):
  * ``"jnp"``   — pure-jnp reference; updates are materialized and applied by
    ``apply_updates`` (6 HBM passes per matrix: g read twice, normalized g
    written + read, theta read + written).
  * ``"fused"`` — matrix updates route through the Pallas kernels behind
    :mod:`repro.kernels.dispatch` (compiled on TPU, interpret oracle on
    CPU/GPU). Dispatch coverage: 2-D and stacked 3-D params, arbitrary
    shapes (remainder tiles masked in-kernel), ``col``/``row``/``larger``
    norm kinds, f32/bf16 inputs; anything outside that matrix (``sign``/
    ``ns``/``svd`` kinds, >3-D leaves) falls back to jnp per-leaf.

Both impls produce the same updates (parity-tested) and bitwise-identical
state treedefs, so checkpoints are interchangeable.

Fused parameter write: both impls also provide ``update_params`` (see
:class:`repro.core.types.GradientTransformation`), which updates theta
directly instead of materializing an update tree. Under ``impl="fused"``
a stateless matrix costs 4 HBM passes per step instead of the unfused 6
(one grad read for the norm reduction, then an apply stage that touches
each matrix exactly 3x: theta read, grad read, theta write); momentum
matrices cost 6 instead of 9 (the exact accounting lives in
:mod:`repro.kernels.dispatch`). The trainer feature-detects
``update_params`` and skips the separate ``apply_updates`` pass.
``update_params`` takes the ``shardings`` / ``grad_scale`` keyword
extensions the trainer also feature-detects (see the pipeline module).

State invariant: ``update`` returns a state with exactly the shapes/dtypes
``init`` produced (int32 count; f32 Adam moments; momentum in
``momentum_dtype``) — ``lax.scan`` training loops and donated buffers rely
on this fixed point (regression-tested via ``jax.eval_shape``).

``momentum_dtype`` ("float32" default, "bfloat16") sets the storage dtype
of the momentum buffers — SCALE's only matrix state, carried on the LM
head. bf16 halves the head's optimizer memory at some quality cost (the
paper's App. C keeps f32). Semantics are cast-on-read/write: the EMA and
the norm reduction run in f32 and only the *stored* momentum is rounded.
The two impls differ in one bf16-rounding-sized detail: the jnp branch
normalizes the pre-cast f32 EMA, while the fused kernels' apply stage
consumes the momentum it just *stored* (``momentum_sumsq`` emits
m'.astype(momentum_dtype) while accumulating the f32 sums-of-squares; an
extra f32 emit for the apply would double the momentum HBM traffic the
fusion exists to avoid). So under bf16 momentum the impls agree to bf16
rounding (parity-tested at that tolerance), and with the f32 default they
remain exactly as before. Adam's vector moments stay f32 regardless
(negligible; Appendix C).
"""
from __future__ import annotations

from typing import Optional, Sequence

from .labels import LabelRules
from .pipeline import ADAM_LR_STAGE, PipeState, Stages, build_pipeline
from .types import GradientTransformation, Schedule

# SCALE's state is the shared pipeline state (count, mu, nu, extra=None).
ScaleState = PipeState


def _norm_kind_for(label: str, norm_last: str, norm_first: str,
                   norm_rest: str) -> str:
    if label == "last":
        return norm_last
    if label == "first":
        return norm_first
    return norm_rest


def scale(
    lr: Schedule | float,
    beta: float = 0.9,
    momentum_on: Sequence[str] = ("last",),
    norm_last: str = "col",
    norm_first: str = None,
    norm_rest: str = "col",
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
    lr_scaling: bool = False,
    impl: str = "jnp",
    momentum_dtype: str = "float32",
) -> GradientTransformation:
    """Build the SCALE optimizer (paper Algorithm 1).

    ``lr_scaling=True`` enables the Muon-style per-matrix lr scale the paper
    uses for its 1B run (Appendix C). ``impl="fused"`` routes matrix updates
    through :mod:`repro.kernels.dispatch` (Pallas kernels).
    ``momentum_dtype="bfloat16"`` halves the momentum (LM-head) state with
    cast-on-read/write semantics (see the module docstring).

    Tied embeddings: for a ``tie_embeddings=True`` model pass
    ``rules=LabelRules.tied()`` — the token embedding is then the ``last``
    (momentum) group, and because it is stored in the (V, D) embedding
    layout rather than the head's (D, V) use layout, its col/row norm kind
    is flipped per leaf (``normalization.flip_kind``) so the normalization
    still runs along the output (vocab) dimension. A tied param tree handed
    the untied default rules is a hard error (``label_tree(require_last=
    True)``): the head would otherwise silently lose its momentum branch.
    """
    norm_first = norm_first if norm_first is not None else norm_rest
    momentum_on = tuple(momentum_on)

    def plan(lab):
        # vectors route to Adam even when "vector" is listed in momentum_on
        # (negligible memory; Appendix C) — init and update must agree or
        # the state dtype fixed point breaks
        if lab == "vector":
            return ADAM_LR_STAGE
        kind = _norm_kind_for(lab, norm_last, norm_first, norm_rest)
        return Stages(momentum=beta if lab in momentum_on else 0.0,
                      norm=kind, flip_transposed=True,
                      lr_scaling=lr_scaling)

    plans = {lab: plan(lab) for lab in ("first", "last", "matrix", "vector")}
    return build_pipeline(plans, lr, adam_lr, b1=b1, b2=b2, eps=eps,
                          rules=rules, require_last=True, impl=impl,
                          momentum_dtype=momentum_dtype)
