"""SCALE — Stochastic Column-normalized Last-layer momentum (Algorithm 1).

Per parameter group:
  * last layer (LM head):   m <- beta*m + (1-beta)*g ;  delta = -lr * colnorm(m)
  * other matrices:         delta = -lr * colnorm(g)           (stateless)
  * vector params:          Adam (negligible memory; Appendix C)

Ablation knobs reproduce the paper's Tables 8 and 13:
  * ``momentum_on``: which groups carry momentum (default ("last",)).
  * ``norm_last`` / ``norm_rest``: normalization kind per group
    (Table 13 mixed schemes, incl. "larger" = normalize along larger dim).
  * ``impl``: "jnp" (reference) or "fused" (Pallas kernels; see
    repro.kernels) — both produce identical updates (tested).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from .labels import LabelRules, label_tree
from .normalization import colnorm, normalize
from .optimizers import _adam_leaf, _empty, _lr_at, _zeros, muon_lr_scale
from .types import GradientTransformation, PyTree, Schedule

_f32 = jnp.float32


class ScaleState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree  # momentum for momentum_on groups; adam-m for vectors; else empty
    nu: PyTree  # adam-v for vectors; else empty


def _norm_kind_for(label: str, norm_last: str, norm_first: str, norm_rest: str) -> str:
    if label == "last":
        return norm_last
    if label == "first":
        return norm_first
    return norm_rest


def _apply_norm(g: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "larger":  # Table 13 row 4: normalize along the larger dim
        # reduce over the larger of the two trailing dims
        kind = "col" if g.shape[-2] >= g.shape[-1] else "row"
    return normalize(g, kind)


def scale(
    lr: Schedule | float,
    beta: float = 0.9,
    momentum_on: Sequence[str] = ("last",),
    norm_last: str = "col",
    norm_first: str = None,
    norm_rest: str = "col",
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
    lr_scaling: bool = False,
    impl: str = "jnp",
) -> GradientTransformation:
    """Build the SCALE optimizer (paper Algorithm 1).

    ``lr_scaling=True`` enables the Muon-style per-matrix lr scale the paper
    uses for its 1B run (Appendix C). ``impl="fused"`` routes matrix updates
    through the Pallas kernels in :mod:`repro.kernels`.
    """
    rules = rules or LabelRules()
    adam_lr = adam_lr if adam_lr is not None else lr
    norm_first = norm_first if norm_first is not None else norm_rest
    momentum_on = tuple(momentum_on)

    if impl == "fused":
        from repro.kernels.colnorm import ops as _colnorm_ops
        from repro.kernels.scale_head import ops as _head_ops
    elif impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")

    def init(params):
        labels = label_tree(params, rules)

        def mk_mu(lab, p):
            return _zeros(p) if (lab in momentum_on or lab == "vector") else _empty(p)

        def mk_nu(lab, p):
            return _zeros(p) if lab == "vector" else _empty(p)

        return ScaleState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(mk_mu, labels, params),
            nu=jax.tree_util.tree_map(mk_nu, labels, params),
        )

    def update(grads, state, params=None):
        labels = label_tree(grads, rules)
        count = state.count
        lr_t = _lr_at(lr, count)
        alr_t = _lr_at(adam_lr, count)

        def leaf(lab, g, m, v):
            # updates are cast back to the gradient dtype at the source: a
            # f32 update tree would materialize full-size f32 copies of the
            # biggest (stacked-layer) parameters (dry-run: +27 GB on v3-671B)
            if lab == "vector":
                upd, m, v = _adam_leaf(g, m, v, count, b1, b2, eps)
                return (-alr_t * upd).astype(g.dtype), m, v
            gf = g.astype(_f32)
            s = muon_lr_scale(g.shape) if lr_scaling else 1.0
            kind = _norm_kind_for(lab, norm_last, norm_first, norm_rest)
            if lab in momentum_on:
                if impl == "fused" and kind == "col" and g.ndim == 2:
                    m, d = _head_ops.momentum_colnorm(m, gf, beta)
                    return (-lr_t * s * d).astype(g.dtype), m, v
                m = beta * m + (1.0 - beta) * gf
                return (-lr_t * s * _apply_norm(m, kind)).astype(g.dtype), m, v
            if impl == "fused" and kind == "col" and g.ndim == 2:
                return (-lr_t * s * _colnorm_ops.colnorm(gf)).astype(g.dtype), m, v
            return (-lr_t * s * _apply_norm(gf, kind)).astype(g.dtype), m, v

        out = jax.tree_util.tree_map(leaf, labels, grads, state.mu, state.nu)
        istup = lambda x: isinstance(x, tuple)
        return (
            jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup),
            ScaleState(
                count + 1,
                jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup),
                jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=istup),
            ),
        )

    return GradientTransformation(init, update)
