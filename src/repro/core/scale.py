"""SCALE — Stochastic Column-normalized Last-layer momentum (Algorithm 1).

Per parameter group:
  * last layer (LM head):   m <- beta*m + (1-beta)*g ;  delta = -lr * colnorm(m)
  * other matrices:         delta = -lr * colnorm(g)           (stateless)
  * vector params:          Adam (negligible memory; Appendix C)

Ablation knobs reproduce the paper's Tables 8 and 13:
  * ``momentum_on``: which groups carry momentum (default ("last",)).
  * ``norm_last`` / ``norm_rest``: normalization kind per group
    (Table 13 mixed schemes, incl. "larger" = normalize along larger dim).

Implementations (``impl``):
  * ``"jnp"``   — pure-jnp reference; updates are materialized and applied by
    ``apply_updates`` (6 HBM passes per matrix: g read twice, normalized g
    written + read, theta read + written).
  * ``"fused"`` — matrix updates route through the Pallas kernels behind
    :mod:`repro.kernels.dispatch` (compiled on TPU, interpret oracle on
    CPU/GPU). Dispatch coverage: 2-D and stacked 3-D params, arbitrary
    shapes (remainder tiles masked in-kernel), ``col``/``row``/``larger``
    norm kinds, f32/bf16 inputs; anything outside that matrix (``sign``/
    ``ns``/``svd`` kinds, >3-D leaves) falls back to jnp per-leaf.

Both impls produce the same updates (parity-tested) and bitwise-identical
state treedefs, so checkpoints are interchangeable.

Fused parameter write: both impls also provide ``update_params`` (see
:class:`repro.core.types.GradientTransformation`), which updates theta
directly instead of materializing an update tree. Under ``impl="fused"``
a stateless matrix costs 4 HBM passes per step instead of the unfused 6
(one grad read for the norm reduction, then an apply stage that touches
each matrix exactly 3x: theta read, grad read, theta write); momentum
matrices cost 6 instead of 9 (the exact accounting lives in
:mod:`repro.kernels.dispatch`). The trainer feature-detects
``update_params`` and skips the separate ``apply_updates`` pass.

``update_params`` takes two optional keyword extensions the trainer also
feature-detects:

  * ``shardings`` — a pytree of per-parameter ``NamedSharding`` (same
    structure as params, derived from ``models/sharding.Rules``). Passed
    through to the kernel dispatch, which shard_maps the fused step over
    the mesh and psums the per-slice sums-of-squares over the mesh axes
    sharding each matrix's reduce dim. Without it the fused kernels are
    only correct on a single device / fully-replicated params.
  * ``grad_scale`` — a scalar multiplied into every gradient at read time
    (inside the kernels; as ``g * grad_scale`` on jnp branches, bitwise
    identical to the trainer's old clip tree-map). This folds global-norm
    clipping into the update and removes one full grad read+write.

State invariant: ``update`` returns a state with exactly the shapes/dtypes
``init`` produced (int32 count; f32 Adam moments; momentum in
``momentum_dtype``) — ``lax.scan`` training loops and donated buffers rely
on this fixed point (regression-tested via ``jax.eval_shape``).

``momentum_dtype`` ("float32" default, "bfloat16") sets the storage dtype
of the momentum buffers — SCALE's only matrix state, carried on the LM
head. bf16 halves the head's optimizer memory at some quality cost (the
paper's App. C keeps f32). Semantics are cast-on-read/write: the EMA and
the norm reduction run in f32 and only the *stored* momentum is rounded.
The two impls differ in one bf16-rounding-sized detail: the jnp branch
normalizes the pre-cast f32 EMA, while the fused kernels' apply stage
consumes the momentum it just *stored* (``momentum_sumsq`` emits
m'.astype(momentum_dtype) while accumulating the f32 sums-of-squares; an
extra f32 emit for the apply would double the momentum HBM traffic the
fusion exists to avoid). So under bf16 momentum the impls agree to bf16
rounding (parity-tested at that tolerance), and with the f32 default they
remain exactly as before. Adam's vector moments stay f32 regardless
(negligible; Appendix C).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from .labels import LabelRules, label_tree, transposed_tree
from .normalization import flip_kind, normalize, resolve_larger
from .optimizers import _adam_leaf, _empty, _lr_at, _zeros, muon_lr_scale
from .types import GradientTransformation, PyTree, Schedule

_f32 = jnp.float32


class ScaleState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree  # momentum for momentum_on groups; adam-m for vectors; else empty
    nu: PyTree  # adam-v for vectors; else empty


def _norm_kind_for(label: str, norm_last: str, norm_first: str, norm_rest: str) -> str:
    if label == "last":
        return norm_last
    if label == "first":
        return norm_first
    return norm_rest


def _apply_norm(g: jnp.ndarray, kind: str) -> jnp.ndarray:
    return normalize(g, resolve_larger(kind, g.shape))


def scale(
    lr: Schedule | float,
    beta: float = 0.9,
    momentum_on: Sequence[str] = ("last",),
    norm_last: str = "col",
    norm_first: str = None,
    norm_rest: str = "col",
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
    lr_scaling: bool = False,
    impl: str = "jnp",
    momentum_dtype: str = "float32",
) -> GradientTransformation:
    """Build the SCALE optimizer (paper Algorithm 1).

    ``lr_scaling=True`` enables the Muon-style per-matrix lr scale the paper
    uses for its 1B run (Appendix C). ``impl="fused"`` routes matrix updates
    through :mod:`repro.kernels.dispatch` (Pallas kernels).
    ``momentum_dtype="bfloat16"`` halves the momentum (LM-head) state with
    cast-on-read/write semantics (see the module docstring).

    Tied embeddings: for a ``tie_embeddings=True`` model pass
    ``rules=LabelRules.tied()`` — the token embedding is then the ``last``
    (momentum) group, and because it is stored in the (V, D) embedding
    layout rather than the head's (D, V) use layout, its col/row norm kind
    is flipped per leaf (``normalization.flip_kind``) so the normalization
    still runs along the output (vocab) dimension. A tied param tree handed
    the untied default rules is a hard error (``label_tree(require_last=
    True)``): the head would otherwise silently lose its momentum branch.
    """
    rules = rules or LabelRules()
    adam_lr = adam_lr if adam_lr is not None else lr
    norm_first = norm_first if norm_first is not None else norm_rest
    momentum_on = tuple(momentum_on)
    try:
        mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[momentum_dtype]
    except KeyError:
        raise ValueError(f"momentum_dtype must be float32|bfloat16, "
                         f"got {momentum_dtype!r}") from None

    fused = impl == "fused"
    if fused:
        from repro.kernels import dispatch as _kd
    elif impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")

    def _use_kernel(shape, kind, mode) -> bool:
        return fused and _kd.supported(shape, kind, mode)

    def init(params):
        # require_last: a tree with an embedding but no 'last' matrix means
        # a tied model was handed the untied rules — hard error, the head
        # would silently train with no momentum (see labels.label_tree)
        labels = label_tree(params, rules, require_last=True)

        def mk_mu(lab, p):
            # vector check first: update() routes vectors to Adam (f32
            # moments) even when "vector" is listed in momentum_on, so
            # init must agree or the state dtype fixed point breaks
            if lab == "vector":
                return _zeros(p)
            if lab in momentum_on:  # SCALE momentum: momentum_dtype storage
                return jnp.zeros(p.shape, mdt)
            return _empty(p)

        def mk_nu(lab, p):
            return _zeros(p) if lab == "vector" else _empty(p)

        return ScaleState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(mk_mu, labels, params),
            nu=jax.tree_util.tree_map(mk_nu, labels, params),
        )

    def _step(grads, state, params, shardings=None, grad_scale=None):
        """Shared per-leaf routing for both entry points.

        ``params is None`` -> delta mode: return the update tree (classic
        ``update`` contract). Otherwise -> write mode: return new params
        directly (``update_params``). Keeping one copy of the label/kind/
        kernel branching is what guarantees the two modes cannot drift.

        ``shardings``/``grad_scale`` (write mode): per-leaf NamedSharding
        for the mesh-aware kernel dispatch, and the trainer's fused clip
        factor. On jnp branches ``grad_scale`` is applied as ``g * scale``
        before any cast — the same op the trainer's clip tree-map used, so
        clip-then-update and fold-into-update are bitwise-equal there.

        Updates/applies are rounded through the gradient dtype at the
        source: a f32 update tree would materialize full-size f32 copies of
        the biggest (stacked-layer) parameters (dry-run: +27 GB on
        v3-671B). The jnp write-mode branches replay the delta mode's exact
        cast chain (round to g.dtype, then to p.dtype on apply), so for
        ``impl="jnp"`` both modes are bitwise-equal for any grad/param
        dtype combination. The fused kernel write skips the intermediate
        g.dtype rounding and applies in full f32 — slightly more precise,
        within the parity-test tolerance.
        """
        labels = label_tree(grads, rules, require_last=True)
        count = state.count
        lr_t = _lr_at(lr, count)
        alr_t = _lr_at(adam_lr, count)
        # REPRO_FUSED is re-read on every (re)trace and keys the dispatch
        # caches; an outer jit around the whole step still pins the mode at
        # its own trace time (see the dispatch module docstring)
        mode = _kd.resolve_mode() if fused else None

        def emit(u, g, p):
            # delta mode returns the rounded update; write mode applies it
            u = u.astype(g.dtype)
            return u if p is None else p + u.astype(p.dtype)

        def leaf(lab, tr, g, m, v, p, sh):
            # jnp-branch view of the gradient: scaled up front, exactly the
            # op the trainer's clip tree-map used (XLA fuses it — free).
            # Kernel branches instead thread grad_scale INTO the kernels,
            # where it multiplies g at read time: scaling first would
            # materialize a full g*scale copy (pallas_call is opaque to
            # XLA fusion) — the HBM pass the fold exists to remove.
            gsc = g if grad_scale is None else g * grad_scale
            if lab == "vector":
                upd, m, v = _adam_leaf(gsc, m, v, count, b1, b2, eps)
                return emit(-alr_t * upd, gsc, p), m, v
            s = muon_lr_scale(g.shape) if lr_scaling else 1.0
            kind = _norm_kind_for(lab, norm_last, norm_first, norm_rest)
            if tr:
                # tied head stored (V, D): the paper's normalization along
                # the output dimension is a row norm of the storage layout
                kind = flip_kind(kind)
            lr_eff = lr_t * s
            if lab in momentum_on:
                if _use_kernel(g.shape, kind, mode):
                    gf = g.astype(_f32)
                    if p is None:
                        m, d = _kd.momentum_norm(
                            m, gf, beta, kind, gscale=grad_scale,
                            sharding=sh, mode=mode)
                        return emit(-lr_eff * d, gsc, p), m, v
                    p_new, m = _kd.momentum_norm_update(
                        p, m, gf, beta, lr_eff, kind, gscale=grad_scale,
                        sharding=sh, mode=mode)
                    return p_new, m, v
                gf = gsc.astype(_f32)
                # cast-on-read/write: EMA and norm in f32, storage in mdt
                m_f = beta * m.astype(_f32) + (1.0 - beta) * gf
                return (emit(-lr_eff * _apply_norm(m_f, kind), gsc, p),
                        m_f.astype(mdt), v)
            if _use_kernel(g.shape, kind, mode):
                gf = g.astype(_f32)
                if p is None:
                    return emit(-lr_eff * _kd.normalize(
                        gf, kind, gscale=grad_scale, sharding=sh,
                        mode=mode), gsc, p), m, v
                return _kd.norm_update(p, gf, lr_eff, kind,
                                       gscale=grad_scale, sharding=sh,
                                       mode=mode), m, v
            return emit(-lr_eff * _apply_norm(gsc.astype(_f32), kind),
                        gsc, p), m, v

        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        n = len(g_leaves)
        flat = treedef.flatten_up_to
        lab_l, mu_l, nu_l = flat(labels), flat(state.mu), flat(state.nu)
        tr_l = flat(transposed_tree(grads, rules)) if rules.tied_last \
            else [False] * n
        p_l = flat(params) if params is not None else [None] * n
        sh_l = flat(shardings) if shardings is not None else [None] * n
        out = [leaf(*args) for args in zip(lab_l, tr_l, g_leaves, mu_l, nu_l,
                                           p_l, sh_l)]
        result = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        return result, ScaleState(count + 1, mu, nu)

    def update(grads, state, params=None):
        del params  # classic contract: deltas are independent of theta
        return _step(grads, state, None)

    def update_params(grads, state, params, shardings=None, grad_scale=None):
        """Fused step: write theta directly (no materialized update tree).

        ``shardings``: optional pytree of per-param NamedSharding — makes
        the fused kernels mesh-correct under pjit (see module docstring).
        ``grad_scale``: optional scalar folded into the gradient read
        (the trainer's global-norm clip factor).
        """
        return _step(grads, state, params, shardings, grad_scale)

    return GradientTransformation(init, update, update_params)
