"""Staged leaf-update pipeline shared by the whole optimizer zoo.

Every optimizer in :mod:`repro.core` is a *composition of stages* routed per
parameter label (``core.labels``: first / last / matrix / vector) instead of
a hand-rolled ``init``/``update``/``leaf`` triple. A :class:`Stages` value
describes what happens to one label group, in fixed order:

    grad-scale fold -> [project] -> [momentum EMA (+nesterov)] ->
    [standardize] -> [normalize] -> [adam] -> lr scale -> apply

and :func:`build_pipeline` turns ``{label: Stages}`` plans into a
:class:`~repro.core.types.GradientTransformation` with BOTH entry points:

  * ``update``        — classic delta mode (updates materialized, applied by
    ``apply_updates``);
  * ``update_params`` — write mode (theta written directly, ``shardings`` +
    ``grad_scale`` aware), for *every* pipeline optimizer. On the jnp path
    write mode replays delta mode's exact cast chain (round the update to
    the grad dtype, then to the param dtype on apply), so the two entry
    points are bitwise-equal and the trainer may auto-switch freely.

Kernel lowering
---------------
Stage compositions that match the fused primitives in
:mod:`repro.kernels.dispatch` lower to Pallas kernels under ``impl="fused"``
(compiled on TPU, interpret oracle on CPU/GPU, ``REPRO_FUSED`` override —
the same machinery as PRs 1-5):

  ======================================  ==================================
  composition                             kernel entry points
  ======================================  ==================================
  ``norm`` in {col,row,larger}, no        ``normalize`` (delta) /
  momentum/adam/standardize               ``norm_update`` (write)
  momentum EMA + ``norm`` in              ``momentum_norm`` (delta) /
  {col,row,larger}, no nesterov/adam      ``momentum_norm_update`` (write)
  ======================================  ==================================

Everything else (adam, sign/ns/svd norms, projections, nesterov blends,
standardize) stays on the jnp path per leaf; ``dispatch.supported`` gates
shape coverage exactly as before. ``grad_scale`` is threaded INTO the
kernels (multiplied at gradient read time) and applied as ``g * grad_scale``
on jnp branches — bitwise what the trainer's clip tree-map used to do.

State
-----
All pipeline optimizers share one state treedef, :class:`PipeState`
``(count, mu, nu, extra)``:

  * ``mu`` — first-moment buffer (momentum EMA or adam-m); stored in
    ``momentum_dtype`` for non-vector leaves (cast-on-read/write: the EMA
    and all math run in f32, only the *stored* buffer is rounded), f32 for
    vector adam moments (negligible; paper Appendix C).
  * ``nu`` — adam second moment, always f32.
  * ``extra`` — optimizer-specific tree: ``None`` for most, ``{"proj": ...}``
    for the GaLore family's projectors, Stable-SPAM's clip/norm EMAs.

Buffers a composition does not need are zero-length placeholders, so the
treedef is uniform at ~zero cost and ``update`` is an exact ``eval_shape``
fixed point of ``init`` (lax.scan / donated-buffer loops rely on this).

Tree-level hooks
----------------
``pre``/``pre_init`` run once per step on the whole gradient tree before
the leaf stages (Stable-SPAM's AdaClip + AdaGN live here), and
``reset_interval`` zeroes (mu, nu) every k steps (Stable-SPAM momentum
reset). When a ``pre`` hook is present the ``grad_scale`` fold is applied
up-front as a tree-map (the hook must see the clipped gradients; such
optimizers have no kernel stages, so XLA fuses the multiply for free).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .labels import LabelRules, label_tree, transposed_tree
from .normalization import flip_kind, normalize, ns_orthogonalize, resolve_larger
from .types import GradientTransformation, PyTree, Schedule

_f32 = jnp.float32

_LABELS = ("first", "last", "matrix", "vector")


def _empty(p):
    return jnp.zeros((0,), _f32)


def _zeros(p):
    return jnp.zeros(p.shape, _f32)


def _lr_at(lr, count):
    return lr(count) if callable(lr) else jnp.asarray(lr, _f32)


def muon_lr_scale(shape) -> float:
    """Muon's matched-lr scaling (Liu et al., 2025): 0.2 * sqrt(max dims)."""
    return 0.2 * float(max(shape[-2], shape[-1])) ** 0.5


def _adam_leaf(g, m, v, count, b1, b2, eps):
    gf = g.astype(_f32)
    m = b1 * m + (1.0 - b1) * gf
    v = b2 * v + (1.0 - b2) * gf * gf
    mhat = m / (1.0 - b1 ** (count + 1))
    vhat = v / (1.0 - b2 ** (count + 1))
    upd = mhat / (jnp.sqrt(vhat) + eps)
    return upd, m, v


# --------------------------------------------------------------------------
# Low-rank projection helpers (GaLore / Fira / APOLLO family).
# --------------------------------------------------------------------------

def _proj_left(shape) -> bool:
    """Project the smaller dimension (GaLore's rule): left iff d_in <= d_out."""
    return shape[-2] <= shape[-1]


def _rank_for(shape, rank: int) -> int:
    return min(rank, shape[-2], shape[-1])


def _svd_projector(g: jnp.ndarray, r: int) -> jnp.ndarray:
    """Top-r left (or right) singular vectors of g, shape (..., min_dim, r).

    Stacked (scan-over-layers / per-expert) leaves project per slice.
    """
    gf = g.astype(_f32)
    if _proj_left(g.shape):
        u, _, _ = jnp.linalg.svd(gf, full_matrices=False)
        return u[..., :, :r]  # (..., m, r)
    _, _, vt = jnp.linalg.svd(gf, full_matrices=False)
    return jnp.swapaxes(vt[..., :r, :], -1, -2)  # (..., n, r)


def _random_projector(key, shape, r: int) -> jnp.ndarray:
    d = shape[-2] if _proj_left(shape) else shape[-1]
    return jax.random.normal(key, tuple(shape[:-2]) + (d, r), _f32) / jnp.sqrt(r)


def _project(g, p):
    # left: R = P^T G  (..., r, n); right: R = G P  (..., m, r)
    if _proj_left(g.shape):
        return jnp.einsum("...dr,...dn->...rn", p, g)
    return jnp.einsum("...mn,...nr->...mr", g, p)


def _project_back(r_upd, p, shape):
    if _proj_left(shape):
        return jnp.einsum("...dr,...rn->...dn", p, r_upd)
    return jnp.einsum("...mr,...nr->...mn", r_upd, p)


@dataclasses.dataclass(frozen=True)
class Project:
    """Low-rank projection stage config (GaLore family).

    ``mode``: "galore" (SVD projector, adam in the subspace, project back),
    "fira" (+ full-rank residual scaled by the low-rank adam norm ratio),
    "apollo" (random projector, channel-wise gradient scaling) or
    "apollo_mini" (rank-1 tensor-wise variant with the sqrt(128) boost).
    """
    mode: str
    rank: int = 256
    update_proj_gap: int = 200
    scale_factor: float = 1.0
    seed: int = 0

    @property
    def eff_rank(self) -> int:
        return 1 if self.mode == "apollo_mini" else self.rank

    @property
    def random(self) -> bool:
        return self.mode in ("apollo", "apollo_mini")


@dataclasses.dataclass(frozen=True)
class Stages:
    """Stage composition for one label group (see module docstring).

    ``momentum``  — EMA coefficient for the first-moment stage (0 = off);
                    ``nesterov`` blends ``beta*m' + (1-beta)*g`` as the
                    direction instead of ``m'``.
    ``standardize`` — SWAN GradNorm: zero-mean/unit-variance per row.
    ``norm``      — normalization kind (col/row/larger/sign/ns/svd) applied
                    to the direction, or None. ``ns_steps`` parameterizes
                    the Newton-Schulz kinds. ``flip_transposed`` flips
                    col<->row for transposed-storage (tied-head) leaves —
                    opt-in, because the fixed-kind sgd_*norm ablations
                    normalize along the storage axis as defined.
    ``adam``      — full Adam on this group (``weight_decay`` decoupled);
                    mutually exclusive with momentum/norm stages.
    ``adams``     — AdamS (Huang et al., 2025): Adam's second moment is
                    replaced by the instantaneous mix
                    ``sqrt(b2*m_hat^2 + (1-b2)*g^2)``, so the group keeps
                    SGDM-sized state (first moment only) with Adam-like
                    per-element step sizes. Mutually exclusive with
                    ``adam`` and the momentum/norm stages.
    ``project``   — low-rank :class:`Project` stage (self-contained: runs
                    its own adam on the projected gradient).
    ``use_adam_lr`` / ``lr_scaling`` — lr source and Muon's per-matrix
                    spectral lr scale.
    """
    momentum: float = 0.0
    nesterov: bool = False
    standardize: bool = False
    norm: Optional[str] = None
    ns_steps: int = 5
    flip_transposed: bool = False
    adam: bool = False
    adams: bool = False
    weight_decay: float = 0.0
    project: Optional[Project] = None
    use_adam_lr: bool = False
    lr_scaling: bool = False


ADAM_STAGE = Stages(adam=True)
ADAM_LR_STAGE = Stages(adam=True, use_adam_lr=True)


class PipeState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree    # first moment (momentum EMA / adam-m); empty when unused
    nu: PyTree    # adam second moment; empty when unused
    extra: PyTree = None  # projectors / clip EMAs / optimizer-specific


def _run_norm(d, kind, ns_steps, shape):
    if kind == "ns":
        return ns_orthogonalize(d, ns_steps)
    return normalize(d, resolve_larger(kind, shape))


def build_pipeline(
    plans: dict,
    lr: Schedule | float,
    adam_lr: Schedule | float | None = None,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
    require_last: bool = False,
    impl: str = "jnp",
    momentum_dtype: str = "float32",
    pre: Optional[Callable] = None,
    pre_init: Optional[Callable] = None,
    reset_interval: int = 0,
) -> GradientTransformation:
    """Build a :class:`GradientTransformation` from per-label stage plans.

    ``plans`` maps every label in ``("first", "last", "matrix", "vector")``
    to a :class:`Stages`. ``impl="fused"`` lowers matching compositions to
    the Pallas kernels (see module docstring); ``momentum_dtype`` sets the
    storage dtype of non-vector first-moment buffers (cast-on-read/write).
    ``pre(grads, extra, count) -> (grads, extra)`` and ``pre_init(params)
    -> extra-dict`` install a tree-level hook; ``reset_interval`` zeroes
    (mu, nu) every k steps (``count % k == 0 and count > 0``).
    """
    rules = rules or LabelRules()
    adam_lr = adam_lr if adam_lr is not None else lr
    missing = [l for l in _LABELS if l not in plans]
    if missing:
        raise ValueError(f"plans missing labels {missing}")
    try:
        mdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[momentum_dtype]
    except KeyError:
        raise ValueError(f"momentum_dtype must be float32|bfloat16, "
                         f"got {momentum_dtype!r}") from None

    fused = impl == "fused"
    if fused:
        from repro.kernels import dispatch as _kd
    elif impl != "jnp":
        raise ValueError(f"unknown impl {impl!r}")

    projects = [st.project for st in plans.values() if st.project is not None]
    if len({id(p) for p in projects}) > 1 and len(set(projects)) > 1:
        raise ValueError("at most one Project spec per pipeline")
    proj_spec = projects[0] if projects else None

    def _mu_dtype(lab):
        return _f32 if lab == "vector" else mdt

    def _use_kernel(st, shape, kind, mode) -> bool:
        return (fused and kind is not None and not st.adam
                and not st.adams and st.project is None
                and not st.standardize and not st.nesterov
                and _kd.supported(shape, kind, mode))

    def _flat_with_labels(tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        labels = label_tree(tree, rules, require_last=require_last)
        return leaves, treedef, treedef.flatten_up_to(labels)

    def init(params):
        leaves, treedef, lab_l = _flat_with_labels(params)

        def mk_mu(lab, p):
            st = plans[lab]
            if st.project is not None:
                r = _rank_for(p.shape, st.project.eff_rank)
                rshape = ((r, p.shape[-1]) if _proj_left(p.shape)
                          else (p.shape[-2], r))
                return jnp.zeros(tuple(p.shape[:-2]) + rshape, _f32)
            if st.adam or st.adams or st.momentum:
                return jnp.zeros(p.shape, _mu_dtype(lab))
            return _empty(p)

        def mk_nu(lab, p):
            st = plans[lab]
            if st.project is not None:
                return mk_mu(lab, p)  # low-rank, f32 (vector is never projected)
            if st.adam:
                return _zeros(p)
            return _empty(p)

        mu = treedef.unflatten([mk_mu(l, p) for l, p in zip(lab_l, leaves)])
        nu = treedef.unflatten([mk_nu(l, p) for l, p in zip(lab_l, leaves)])
        extra = None
        if pre_init is not None:
            extra = pre_init(params)
        if proj_spec is not None:
            base_key = jax.random.PRNGKey(proj_spec.seed)

            def mk_proj(i, lab, p):
                st = plans[lab]
                if st.project is None:
                    return _empty(p)
                r = _rank_for(p.shape, st.project.eff_rank)
                if st.project.random:
                    return _random_projector(
                        jax.random.fold_in(base_key, i), p.shape, r)
                d = p.shape[-2] if _proj_left(p.shape) else p.shape[-1]
                return jnp.zeros(tuple(p.shape[:-2]) + (d, r), _f32)

            extra = {"proj": treedef.unflatten(
                [mk_proj(i, l, p)
                 for i, (l, p) in enumerate(zip(lab_l, leaves))])}
        return PipeState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu,
                         extra=extra)

    def _step(grads, state, params, write, shardings=None, grad_scale=None):
        """Shared per-leaf routing for both entry points.

        ``write=False`` -> delta mode (classic ``update`` contract);
        ``write=True``  -> new params returned directly (``update_params``).
        One copy of the label/stage/kernel branching guarantees the two
        modes cannot drift; the jnp write-mode branches replay delta mode's
        exact cast chain (round to g.dtype, then to p.dtype on apply), so
        both modes are bitwise-equal for any grad/param dtype combination.
        The fused kernel write applies in full f32 (slightly more precise,
        within the parity-test tolerance).
        """
        count = state.count
        lr_t = _lr_at(lr, count)
        alr_t = _lr_at(adam_lr, count)
        # REPRO_FUSED is re-read on every (re)trace and keys the dispatch
        # caches; an outer jit around the whole step still pins the mode at
        # its own trace time (see the dispatch module docstring)
        mode = _kd.resolve_mode() if fused else None
        extra = state.extra

        if pre is not None:
            if grad_scale is not None:
                # the hook must see clipped grads; bitwise = trainer tree-map
                grads = jax.tree_util.tree_map(
                    lambda g: g * grad_scale, grads)
                grad_scale = None
            grads, extra = pre(grads, extra, count)

        mu_in, nu_in = state.mu, state.nu
        if reset_interval:
            do_reset = ((count % reset_interval) == 0) & (count > 0)
            rz = lambda x: jnp.where(do_reset, jnp.zeros_like(x), x)
            mu_in = jax.tree_util.tree_map(rz, mu_in)
            nu_in = jax.tree_util.tree_map(rz, nu_in)

        if proj_spec is not None:
            refresh = (count % proj_spec.update_proj_gap) == 0
            base_key = jax.random.fold_in(
                jax.random.PRNGKey(proj_spec.seed),
                count // proj_spec.update_proj_gap)

        def emit(u, g, p):
            # delta mode returns the rounded update; write mode applies it
            u = u.astype(g.dtype)
            return u if not write else p + u.astype(p.dtype)

        def leaf(i, lab, tr, g, m, v, p, sh, pj):
            st = plans[lab]
            # jnp-branch view of the gradient: scaled up front, exactly the
            # op the trainer's clip tree-map used (XLA fuses it — free).
            # Kernel branches instead thread grad_scale INTO the kernels,
            # where it multiplies g at read time: scaling first would
            # materialize a full g*scale copy (pallas_call is opaque to
            # XLA fusion) — the HBM pass the fold exists to remove.
            gsc = g if grad_scale is None else g * grad_scale

            if st.project is not None:
                pr = st.project
                gf = gsc.astype(_f32)
                r = _rank_for(g.shape, pr.eff_rank)
                if pr.random:
                    new_p = _random_projector(
                        jax.random.fold_in(base_key, i), g.shape, r)
                else:
                    new_p = _svd_projector(gf, r)
                pj = jax.lax.cond(refresh, lambda: new_p, lambda: pj)
                R = _project(gf, pj)
                r_upd, m, v = _adam_leaf(R, m, v, count, b1, b2, eps)
                if pr.mode == "galore":
                    full = _project_back(r_upd, pj, g.shape) * pr.scale_factor
                elif pr.mode == "fira":
                    back = _project_back(r_upd, pj, g.shape)
                    resid = gf - _project_back(R, pj, g.shape)
                    phi = (jnp.linalg.norm(r_upd)
                           / (jnp.linalg.norm(R) + 1e-12))
                    full = (back + phi * resid) * pr.scale_factor
                else:  # apollo / apollo_mini: channel-wise gradient scaling
                    if pr.mode == "apollo_mini":
                        s = (jnp.linalg.norm(r_upd)
                             / (jnp.linalg.norm(R) + 1e-12))
                        # tensor-wise + heuristic sqrt(rank_ref) boost
                        full = gf * s * jnp.sqrt(jnp.asarray(128.0, _f32))
                    else:
                        # channel = output column when left-projected
                        axis = -2 if _proj_left(g.shape) else -1
                        num = jnp.linalg.norm(r_upd, axis=axis, keepdims=True)
                        den = (jnp.linalg.norm(R, axis=axis, keepdims=True)
                               + 1e-12)
                        full = gf * (num / den)
                    full = full * pr.scale_factor
                return emit(-lr_t * full, gsc, p), m, v, pj

            if st.adam:
                m_f = m.astype(_f32)
                upd, m_f, v = _adam_leaf(gsc, m_f, v, count, b1, b2, eps)
                if st.weight_decay:
                    if p is None:
                        raise ValueError(
                            "weight_decay requires params to be passed to "
                            "update()")
                    upd = upd + st.weight_decay * p.astype(_f32)
                lr_eff = alr_t if st.use_adam_lr else lr_t
                return (emit(-lr_eff * upd, gsc, p), m_f.astype(m.dtype), v,
                        pj)

            if st.adams:
                # AdamS: v is synthesized from the momentum and the raw
                # gradient at read time — no second-moment buffer, hence
                # SGDM-sized state with Adam-like per-element step sizes
                gf = gsc.astype(_f32)
                m_f = b1 * m.astype(_f32) + (1.0 - b1) * gf
                m_hat = m_f / (1.0 - b1 ** (count + 1))
                denom = jnp.sqrt(b2 * m_hat * m_hat
                                 + (1.0 - b2) * gf * gf) + eps
                upd = m_hat / denom
                if st.weight_decay:
                    if p is None:
                        raise ValueError(
                            "weight_decay requires params to be passed to "
                            "update()")
                    upd = upd + st.weight_decay * p.astype(_f32)
                lr_eff = alr_t if st.use_adam_lr else lr_t
                return (emit(-lr_eff * upd, gsc, p), m_f.astype(m.dtype), v,
                        pj)

            s = muon_lr_scale(g.shape) if st.lr_scaling else 1.0
            kind = st.norm
            if tr and st.flip_transposed:
                # tied head stored (V, D): the paper's normalization along
                # the output dimension is a row norm of the storage layout
                kind = flip_kind(kind)
            lr_eff = (alr_t if st.use_adam_lr else lr_t) * s

            if st.momentum:
                if _use_kernel(st, g.shape, kind, mode):
                    gf = g.astype(_f32)
                    if not write:
                        m, d = _kd.momentum_norm(
                            m, gf, st.momentum, kind, gscale=grad_scale,
                            sharding=sh, mode=mode)
                        return emit(-lr_eff * d, gsc, p), m, v, pj
                    p_new, m = _kd.momentum_norm_update(
                        p, m, gf, st.momentum, lr_eff, kind,
                        gscale=grad_scale, sharding=sh, mode=mode)
                    return p_new, m, v, pj
                gf = gsc.astype(_f32)
                # cast-on-read/write: EMA and norm in f32, storage in mdt
                m_f = st.momentum * m.astype(_f32) + (1.0 - st.momentum) * gf
                d = (st.momentum * m_f + (1.0 - st.momentum) * gf
                     if st.nesterov else m_f)
                m_out = m_f.astype(m.dtype)
            else:
                if _use_kernel(st, g.shape, kind, mode):
                    gf = g.astype(_f32)
                    if not write:
                        return emit(-lr_eff * _kd.normalize(
                            gf, kind, gscale=grad_scale, sharding=sh,
                            mode=mode), gsc, p), m, v, pj
                    return _kd.norm_update(
                        p, gf, lr_eff, kind, gscale=grad_scale, sharding=sh,
                        mode=mode), m, v, pj
                d = gsc.astype(_f32)
                m_out = m

            if st.standardize:
                mean = jnp.mean(d, axis=-1, keepdims=True)
                std = jnp.std(d, axis=-1, keepdims=True)
                d = (d - mean) / (std + 1e-8)
            if kind is not None:
                d = _run_norm(d, kind, st.ns_steps, g.shape)
            return emit(-lr_eff * d, gsc, p), m_out, v, pj

        g_leaves, treedef, lab_l = _flat_with_labels(grads)
        n = len(g_leaves)
        flat = treedef.flatten_up_to
        mu_l, nu_l = flat(mu_in), flat(nu_in)
        tr_l = flat(transposed_tree(grads, rules)) if rules.tied_last \
            else [False] * n
        p_l = flat(params) if params is not None else [None] * n
        sh_l = flat(shardings) if shardings is not None else [None] * n
        pj_l = flat(extra["proj"]) if proj_spec is not None else [None] * n
        out = [leaf(*args) for args in zip(range(n), lab_l, tr_l, g_leaves,
                                           mu_l, nu_l, p_l, sh_l, pj_l)]
        result = treedef.unflatten([o[0] for o in out])
        mu = treedef.unflatten([o[1] for o in out])
        nu = treedef.unflatten([o[2] for o in out])
        if proj_spec is not None:
            extra = {**extra, "proj": treedef.unflatten([o[3] for o in out])}
        return result, PipeState(count + 1, mu, nu, extra)

    def update(grads, state, params=None):
        return _step(grads, state, params, write=False)

    def update_params(grads, state, params, shardings=None, grad_scale=None):
        """Fused step: write theta directly (no materialized update tree).

        ``shardings``: optional pytree of per-param NamedSharding — makes
        the fused kernels mesh-correct under pjit (see module docstring).
        ``grad_scale``: optional scalar folded into the gradient read
        (the trainer's global-norm clip factor).
        """
        return _step(grads, state, params, write=True,
                     shardings=shardings, grad_scale=grad_scale)

    return GradientTransformation(init, update, update_params,
                                  plans=dict(plans))
