"""Public optimizer factory: ``make_optimizer(name, lr=..., **kw)``.

Names match the paper's tables: scale, sgd, sgd_momentum, adam, adamw,
stable_spam, muon, swan, galore, fira, apollo, apollo_mini, plus the Table-2
normalization ablations sgd_colnorm / sgd_rownorm / sgd_signnorm / sgd_nsnorm.
"""
from __future__ import annotations

from typing import Any

from . import galore as _galore
from . import optimizers as _opt
from . import scale as _scale
from . import swan as _swan
from .types import GradientTransformation


def make_optimizer(name: str, lr: Any = 1e-3, **kw) -> GradientTransformation:
    name = name.lower()
    if name == "scale":
        return _scale.scale(lr, **kw)
    if name == "scale_fused":
        return _scale.scale(lr, impl="fused", **kw)
    if name == "sgd":
        return _opt.sgd(lr, **kw)
    if name == "sgd_momentum":
        kw.setdefault("momentum", 0.9)
        return _opt.sgd(lr, **kw)
    if name in ("adam",):
        return _opt.adam(lr, **kw)
    if name == "adamw":
        kw.setdefault("weight_decay", 0.01)
        return _opt.adam(lr, **kw)
    if name == "stable_spam":
        return _opt.stable_spam_adam(lr, **kw)
    if name == "muon":
        return _opt.muon(lr, **kw)
    if name == "swan":
        return _swan.swan(lr, **kw)
    if name == "galore":
        return _galore.galore(lr, **kw)
    if name == "fira":
        return _galore.fira(lr, **kw)
    if name == "apollo":
        return _galore.apollo(lr, **kw)
    if name == "apollo_mini":
        return _galore.apollo_mini(lr, **kw)
    if name.startswith("sgd_") and name.endswith("norm"):
        kind = {"sgd_colnorm": "col", "sgd_rownorm": "row",
                "sgd_signnorm": "sign", "sgd_nsnorm": "ns",
                "sgd_svdnorm": "svd"}[name]
        return _opt.normalized_sgd(lr, kind=kind, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


OPTIMIZER_NAMES = (
    "scale", "scale_fused", "sgd", "sgd_momentum", "adam", "adamw",
    "stable_spam", "muon", "swan", "galore", "fira", "apollo", "apollo_mini",
    "sgd_colnorm", "sgd_rownorm", "sgd_signnorm", "sgd_nsnorm",
)
