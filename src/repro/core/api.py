"""Public optimizer factory: ``make_optimizer(name, lr=..., **kw)``.

Names match the paper's tables: scale, sgd, sgd_momentum, adam, adamw,
stable_spam, muon, swan, galore, fira, apollo, apollo_mini, plus the Table-2
normalization ablations sgd_colnorm / sgd_rownorm / sgd_signnorm / sgd_nsnorm
/ sgd_svdnorm, and two related-work compositions: adams (AdamS, momentum as
the normalizer — SGDM-sized state) and adapm (partial momentum: SCALE's
stage plan with momentum on the embedding *and* the LM head).

``OPTIMIZER_REGISTRY`` maps each name to an :class:`OptimizerSpec` — the
factory callable, whether the composition can lower to the fused Pallas
kernels (``impl="fused"`` → ``update_params`` in-place writes), and the
default kwargs the name implies (e.g. ``adamw`` = adam + weight_decay).
``make_optimizer`` validates both the name and the kwargs up front and
raises a ``ValueError`` listing the valid choices, instead of the bare
``TypeError`` a misspelled kwarg used to surface deep inside a factory.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Mapping

from . import galore as _galore
from . import optimizers as _opt
from . import scale as _scale
from . import swan as _swan
from .types import GradientTransformation


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """One registry row: how to build an optimizer and what it supports.

    ``fused`` means the composition contains stages that lower to the Pallas
    colnorm/momentum kernels when built with ``impl="fused"`` (and therefore
    gains the in-place ``update_params`` fast path on those leaves).

    ``lowering`` is the human-readable lowering note rendered into the
    dispatch docstring's per-optimizer table (``kernels/dispatch.py``).
    That table is *generated* from this registry by
    ``python -m repro.analysis --fix`` and verified against it by the
    registry-drift analysis pass — edit the text here, not the docstring.
    """
    name: str
    factory: Callable[..., GradientTransformation]
    fused: bool = False
    defaults: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    lowering: str = ""

    def valid_kwargs(self) -> tuple:
        params = inspect.signature(self.factory).parameters
        return tuple(k for k in params if k != "lr")


def _registry() -> dict:
    specs = [
        OptimizerSpec("scale", _scale.scale, fused=True, lowering=(
            "stateless matrices -> normalize / norm_update; momentum "
            "groups (LM head) -> momentum_norm / momentum_norm_update; "
            "Adam vectors stay jnp")),
        OptimizerSpec("scale_fused", _scale.scale, fused=True,
                      defaults={"impl": "fused"}, lowering=(
                          'as scale, built with impl="fused" by default')),
        OptimizerSpec("sgd", _opt.sgd, lowering=(
            "never fused: plain SGD has no norm stage; jnp write path "
            "only")),
        OptimizerSpec("sgd_momentum", _opt.sgd, defaults={"momentum": 0.9},
                      lowering=(
                          "never fused: a bare momentum EMA without a "
                          "col/row norm has no kernel composition")),
        OptimizerSpec("adam", _opt.adam, lowering=(
            "never fused: Adam moments have no kernel composition; jnp "
            "write path only")),
        OptimizerSpec("adamw", _opt.adam, defaults={"weight_decay": 0.01},
                      lowering=(
                          "as adam (decoupled weight decay folds into the "
                          "Adam stage)")),
        OptimizerSpec("adams", _opt.adams, lowering=(
            "never fused: the synthesized AdamS denominator "
            "(sqrt(b2*m^2 + (1-b2)*g^2)) has no kernel composition; jnp "
            "write path only")),
        OptimizerSpec("adapm", _scale.scale, fused=True,
                      defaults={"momentum_on": ("first", "last")}, lowering=(
                          "as scale with momentum on the embedding and the "
                          "LM head (partial momentum); hidden matrices stay "
                          "stateless normalize / norm_update")),
        OptimizerSpec("stable_spam", _opt.stable_spam_adam, lowering=(
            "never fused: AdaClip/AdaGN run as the tree-level pre hook; "
            "the Adam stage stays jnp")),
        OptimizerSpec("muon", _opt.muon, lowering=(
            "never fused: nesterov EMA + Newton-Schulz orthogonalization "
            "sit outside kernel coverage")),
        OptimizerSpec("swan", _swan.swan, lowering=(
            "never fused: standardize (GradNorm) precedes the norm "
            "stage")),
        OptimizerSpec("galore", _galore.galore, lowering=(
            "never fused: the low-rank projection stage has no kernel "
            "composition")),
        OptimizerSpec("fira", _galore.fira, lowering=(
            "as galore (adds the full-rank residual)")),
        OptimizerSpec("apollo", _galore.apollo, lowering=(
            "as galore (random projector, channel-wise scaling)")),
        OptimizerSpec("apollo_mini", _galore.apollo_mini, lowering=(
            "as apollo (rank-1 projector, tensor-wise scaling)")),
        OptimizerSpec("sgd_colnorm", _opt.normalized_sgd, fused=True,
                      defaults={"kind": "col"}, lowering=(
                          "all matrix groups -> normalize / norm_update "
                          'when built with impl="fused"; vectors stay '
                          "jnp")),
        OptimizerSpec("sgd_rownorm", _opt.normalized_sgd, fused=True,
                      defaults={"kind": "row"}, lowering=(
                          "as sgd_colnorm with the row kind")),
        OptimizerSpec("sgd_signnorm", _opt.normalized_sgd,
                      defaults={"kind": "sign"}, lowering=(
                          "never fused: sign norm is outside kernel "
                          "coverage")),
        OptimizerSpec("sgd_nsnorm", _opt.normalized_sgd,
                      defaults={"kind": "ns"}, lowering=(
                          "never fused: Newton-Schulz norm is outside "
                          "kernel coverage")),
        OptimizerSpec("sgd_svdnorm", _opt.normalized_sgd,
                      defaults={"kind": "svd"}, lowering=(
                          "never fused: SVD norm is outside kernel "
                          "coverage")),
    ]
    return {s.name: s for s in specs}


OPTIMIZER_REGISTRY = _registry()
OPTIMIZER_NAMES = tuple(OPTIMIZER_REGISTRY)


def make_optimizer(name: str, lr: Any = 1e-3, **kw) -> GradientTransformation:
    key = name.lower()
    spec = OPTIMIZER_REGISTRY.get(key)
    if spec is None:
        raise ValueError(
            f"unknown optimizer {name!r}; valid choices: "
            + ", ".join(sorted(OPTIMIZER_REGISTRY)))
    valid = spec.valid_kwargs()
    unknown = sorted(set(kw) - set(valid))
    if unknown:
        raise ValueError(
            f"unknown kwarg(s) {unknown} for optimizer {name!r}; "
            f"valid kwargs: {', '.join(valid)}")
    merged = {**spec.defaults, **kw}
    return spec.factory(lr, **merged)
