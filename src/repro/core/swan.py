"""SWAN (Ma et al., 2025): stateless SGD with GradNorm + GradWhitening.

Hidden matrices: (1) GradNorm — row-wise standardization (zero mean / unit
variance along the input dimension); (2) GradWhitening — (GG^T)^{-1/2} G,
approximated with the same Newton–Schulz iteration Muon uses. As a pipeline
composition that is ``Stages(standardize=True, norm="ns")`` on the matrix
group. First/last layers and vector params run full Adam (as in the original
paper, which is why SWAN's memory saving shrinks for small models — §4).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from .labels import LabelRules
from .normalization import ns_orthogonalize
from .pipeline import ADAM_LR_STAGE, PipeState, Stages, build_pipeline
from .types import GradientTransformation, Schedule

SwanState = PipeState


def swan_normalize(g: jnp.ndarray, ns_steps: int = 5) -> jnp.ndarray:
    """GradNorm (row standardize) + GradWhitening (NS orthogonalization)."""
    gf = g.astype(jnp.float32)
    mean = jnp.mean(gf, axis=-1, keepdims=True)
    std = jnp.std(gf, axis=-1, keepdims=True)
    gn = (gf - mean) / (std + 1e-8)
    return ns_orthogonalize(gn, ns_steps).astype(g.dtype)


def swan(
    lr: Schedule | float,
    ns_steps: int = 5,
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
) -> GradientTransformation:
    matrix_st = Stages(standardize=True, norm="ns", ns_steps=ns_steps)
    plans = {"first": ADAM_LR_STAGE, "last": ADAM_LR_STAGE,
             "matrix": matrix_st, "vector": ADAM_LR_STAGE}
    return build_pipeline(plans, lr, adam_lr, b1=b1, b2=b2, eps=eps,
                          rules=rules)
