"""SWAN (Ma et al., 2025): stateless SGD with GradNorm + GradWhitening.

Hidden matrices: (1) GradNorm — row-wise standardization (zero mean / unit
variance along the input dimension); (2) GradWhitening — (GG^T)^{-1/2} G,
approximated with the same Newton–Schulz iteration Muon uses.
First/last layers and vector params run full Adam (as in the original paper,
which is why SWAN's memory saving shrinks for small models — paper §4).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .labels import LabelRules, label_tree
from .normalization import ns_orthogonalize
from .optimizers import _adam_leaf, _empty, _lr_at, _zeros
from .types import GradientTransformation, PyTree, Schedule

_f32 = jnp.float32


class SwanState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree  # adam-m for first/last/vector only
    nu: PyTree


def swan_normalize(g: jnp.ndarray, ns_steps: int = 5) -> jnp.ndarray:
    """GradNorm (row standardize) + GradWhitening (NS orthogonalization)."""
    gf = g.astype(_f32)
    mean = jnp.mean(gf, axis=-1, keepdims=True)
    std = jnp.std(gf, axis=-1, keepdims=True)
    gn = (gf - mean) / (std + 1e-8)
    return ns_orthogonalize(gn, ns_steps).astype(g.dtype)


def swan(
    lr: Schedule | float,
    ns_steps: int = 5,
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
) -> GradientTransformation:
    rules = rules or LabelRules()
    adam_lr = adam_lr if adam_lr is not None else lr

    def init(params):
        labels = label_tree(params, rules)
        mk = lambda lab, p: _zeros(p) if lab != "matrix" else _empty(p)
        mu = jax.tree_util.tree_map(mk, labels, params)
        nu = jax.tree_util.tree_map(mk, labels, params)
        return SwanState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state, params=None):
        del params
        labels = label_tree(grads, rules)
        count = state.count
        lr_t = _lr_at(lr, count)
        alr_t = _lr_at(adam_lr, count)

        def leaf(lab, g, m, v):
            if lab == "matrix":
                return -lr_t * swan_normalize(g, ns_steps), m, v
            upd, m, v = _adam_leaf(g, m, v, count, b1, b2, eps)
            return -alr_t * upd, m, v

        out = jax.tree_util.tree_map(leaf, labels, grads, state.mu, state.nu)
        istup = lambda x: isinstance(x, tuple)
        return (
            jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup),
            SwanState(
                count + 1,
                jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup),
                jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=istup),
            ),
        )

    return GradientTransformation(init, update)
