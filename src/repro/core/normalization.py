"""Gradient normalization schemes from the paper, eq. (6).

Convention: matrix parameters are stored ``(d_in, d_out)`` (JAX kernel layout,
``y = x @ W``).  A *column* of ``G`` is a length-``d_in`` slice ``G[:, j]``
associated with output unit ``j`` — column-wise normalization therefore
reduces over ``axis=-2``.  For stacked parameters (e.g. MoE experts with shape
``(E, d_in, d_out)``) the same rule applies per leading slice.

All functions accept any dtype and compute internally in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-8

# Quintic Newton–Schulz coefficients from Muon (Jordan et al., 2024).
_NS_COEFFS = (3.4445, -4.7750, 2.0315)
_NS_STEPS = 5


def _as_f32(g: jnp.ndarray) -> jnp.ndarray:
    return g.astype(jnp.float32)


def colnorm(g: jnp.ndarray, eps: float = _EPS) -> jnp.ndarray:
    """Column-wise normalization: normalize along the output dimension.

    ``out[:, j] = g[:, j] / ||g[:, j]||_2``; reduction over ``axis=-2``.

    The f32 math lives only inside the (fused) reduction and the broadcast
    scale — a full-size f32 copy of ``g`` is never materialized (matters for
    the stacked-layer gradients of 100B+ models: GBs per leaf).
    """
    if g.ndim < 2:
        raise ValueError(f"colnorm expects a matrix, got shape {g.shape}")
    ss = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=-2, keepdims=True)
    inv = (1.0 / (jnp.sqrt(ss) + eps)).astype(g.dtype)
    return g * inv


def rownorm(g: jnp.ndarray, eps: float = _EPS) -> jnp.ndarray:
    """Row-wise normalization: normalize along the input dimension."""
    if g.ndim < 2:
        raise ValueError(f"rownorm expects a matrix, got shape {g.shape}")
    ss = jnp.sum(jnp.square(g.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = (1.0 / (jnp.sqrt(ss) + eps)).astype(g.dtype)
    return g * inv


def signnorm(g: jnp.ndarray) -> jnp.ndarray:
    """Sign normalization (sign-SGD direction)."""
    return jnp.sign(g).astype(g.dtype)


def _ns_iteration_2d(g: jnp.ndarray, steps: int) -> jnp.ndarray:
    """Newton–Schulz orthogonalization of a single (m, n) matrix, m <= n."""
    a, b, c = _NS_COEFFS
    x = g / (jnp.linalg.norm(g) + 1e-7)

    def body(x, _):
        xxt = x @ x.T
        bxc = b * xxt + c * (xxt @ xxt)
        x = a * x + bxc @ x
        return x, None

    x, _ = jax.lax.scan(body, x, None, length=steps)
    return x


def ns_orthogonalize(g: jnp.ndarray, steps: int = _NS_STEPS) -> jnp.ndarray:
    """Inexact singular-value normalization ``U V^T`` via Newton–Schulz.

    Matches Muon's quintic iteration; computed in float32 (paper uses bf16 on
    GPU; f32 keeps the CPU oracle stable). Supports stacked (..., m, n) inputs.
    """
    if g.ndim < 2:
        raise ValueError(f"ns_orthogonalize expects a matrix, got {g.shape}")
    gf = _as_f32(g)
    d_in, d_out = gf.shape[-2], gf.shape[-1]
    transpose = d_in > d_out
    if transpose:
        gf = jnp.swapaxes(gf, -1, -2)
    if gf.ndim == 2:
        out = _ns_iteration_2d(gf, steps)
    else:
        batch_shape = gf.shape[:-2]
        flat = gf.reshape((-1,) + gf.shape[-2:])
        out = jax.vmap(lambda m: _ns_iteration_2d(m, steps))(flat)
        out = out.reshape(batch_shape + out.shape[-2:])
    if transpose:
        out = jnp.swapaxes(out, -1, -2)
    return out.astype(g.dtype)


def svd_orthogonalize(g: jnp.ndarray) -> jnp.ndarray:
    """Exact singular-value normalization ``U V^T`` (reference / Table 1)."""
    gf = _as_f32(g)
    u, _, vt = jnp.linalg.svd(gf, full_matrices=False)
    return (u @ vt).astype(g.dtype)


NORMALIZATIONS = {
    "col": colnorm,
    "row": rownorm,
    "sign": signnorm,
    "ns": ns_orthogonalize,
    "svd": svd_orthogonalize,
    "none": lambda g: g,
}


def resolve_larger(kind: str, shape) -> str:
    """Resolve the ``larger`` norm kind (Table 13 row 4: normalize along the
    larger trailing dim; ties break to ``col``) to ``col``/``row`` by shape.

    The single source of truth for the tie-break — both the jnp path
    (:mod:`repro.core.scale`) and the kernel dispatch
    (:mod:`repro.kernels.dispatch`) must route through it, or square
    matrices could silently take different axes per impl.
    """
    if kind == "larger":
        if len(shape) < 2:
            raise ValueError(f"norm kind 'larger' needs a matrix, got {shape}")
        return "col" if shape[-2] >= shape[-1] else "row"
    return kind


_FLIPPED = {"col": "row", "row": "col"}


def flip_kind(kind: str) -> str:
    """col/row norm kind for a matrix stored *transposed* ((d_out, d_in)).

    A tied LM head lives in the embedding's (V, D) layout, so the paper's
    column-wise normalization along the output dimension is a **row** norm
    of the stored matrix. ``larger`` is shape-resolved (transposition flips
    both the shape and the axis, so it is already invariant) and the
    elementwise/orthogonalizing kinds (sign/ns/svd) commute with transpose.
    """
    return _FLIPPED.get(kind, kind)


def normalize(g: jnp.ndarray, kind: str) -> jnp.ndarray:
    try:
        fn = NORMALIZATIONS[kind]
    except KeyError:
        raise ValueError(f"unknown normalization {kind!r}; options {list(NORMALIZATIONS)}")
    return fn(g)
