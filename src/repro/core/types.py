"""Optimizer framework primitives (self-contained optax-style transforms).

A :class:`GradientTransformation` is an ``(init, update)`` pair:

    state  = tx.init(params)
    updates, state = tx.update(grads, state, params)
    params = apply_updates(params, updates)

``updates`` are *deltas* (already negated / scaled by the learning rate where
applicable), so ``apply_updates`` is a plain tree add.

Optimizers whose hot path benefits from writing parameters in place (one
theta read + one theta write per step instead of materializing a full-size
update tree) may additionally provide ``update_params``:

    params, state = tx.update_params(grads, state, params)

The field defaults to ``None``; callers (e.g. the trainer) feature-detect it
and fall back to the classic ``update`` + ``apply_updates`` sequence.

``update_params`` implementations may additionally accept two optional
keyword arguments, which callers also feature-detect (via
``inspect.signature``) before passing:

  * ``shardings`` — pytree of per-parameter ``jax.sharding.NamedSharding``
    (same structure as params). Optimizers whose hot path runs custom
    kernels need it to stay correct under pjit meshes: a kernel sees only
    its local shard, so cross-shard reductions (e.g. per-column norms over
    a row-sharded matrix) must be performed explicitly.
  * ``grad_scale`` — scalar folded into the gradient at read time,
    equivalent to ``update_params(tree_map(lambda g: g * grad_scale,
    grads), ...)`` but without materializing the scaled tree. The trainer
    uses it to fuse global-norm clipping into the parameter write.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> lr


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], tuple[PyTree, PyTree]]
    # optional fused path: (grads, state, params) -> (new_params, new_state)
    update_params: Optional[
        Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]] = None
    # static introspection: the per-label Stages plans a pipeline optimizer
    # was built from (None for non-pipeline transforms). Consumed by
    # repro.analysis's registry-drift pass to verify which compositions
    # actually lower to the fused kernels; never touched at trace time.
    plans: Optional[Any] = None


class EmptyState(NamedTuple):
    pass


def identity() -> GradientTransformation:
    def init(params):
        del params
        return EmptyState()

    def update(grads, state, params=None):
        del params
        return grads, state

    return GradientTransformation(init, update)


def chain(*txs: GradientTransformation) -> GradientTransformation:
    """Compose transforms left-to-right (like optax.chain)."""

    def init(params):
        return tuple(tx.init(params) for tx in txs)

    def update(grads, state, params=None):
        new_state = []
        for tx, s in zip(txs, state):
            grads, s = tx.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates
    )


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_zeros_like(tree: PyTree, dtype=None) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree
    )


@dataclasses.dataclass(frozen=True)
class OptimizerInfo:
    """Static metadata attached to a built optimizer (for memory accounting)."""

    name: str
    # bytes of optimizer state per parameter-group, filled by core.memory
    extra: dict = dataclasses.field(default_factory=dict)
