"""Optimizer-state memory accounting (paper Appendix B / Table 4).

Computes weights + optimizer-state bytes analytically from parameter shapes,
following the paper's estimation protocol: bf16 (2 bytes) per float, counting
embedding/attention/MLP/head matrices. Used by ``benchmarks/optimizer_bench.py``
and asserted against the paper's published numbers in ``tests/test_memory.py``.

Tied embeddings: a ``tie_embeddings=True`` shapes tree (from
``models.param_shapes``) has no ``lm_head`` leaf, so the tied matrix is
counted **once** in the weight bytes automatically. Pass
``rules=LabelRules.tied()`` so the state accounting follows the tie too —
the embedding is then ``last`` and carries SCALE's single momentum buffer
(without tied rules it would classify ``first`` and the head momentum
would silently vanish from the table).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import numpy as np

from .labels import LabelRules

GB = 1024 ** 3
GB_DEC = 1e9  # the paper's "G" is decimal (0.131B params * 2B = 0.262G)


@dataclasses.dataclass(frozen=True)
class MemoryReport:
    method: str
    weight_bytes: int
    state_bytes: int

    @property
    def total_bytes(self) -> int:
        return self.weight_bytes + self.state_bytes

    def gb(self, decimal: bool = True) -> tuple:
        d = GB_DEC if decimal else GB
        return (self.weight_bytes / d, self.state_bytes / d, self.total_bytes / d)


def _is_shape(x) -> bool:
    return isinstance(x, (tuple, list)) and all(isinstance(i, int) for i in x)


def _shape_of(leaf) -> tuple:
    if hasattr(leaf, "shape"):
        return tuple(leaf.shape)
    return tuple(leaf)


def _size(shape) -> int:
    return int(np.prod(shape)) if shape else 1


def _proj_state_sizes(shape, rank: int, store_projector: bool) -> int:
    """Low-rank (m, v) + optional projector element count for one matrix."""
    m, n = shape[-2], shape[-1]
    lead = _size(shape[:-2])
    r = min(rank, m, n)
    low = r * min(m, n)          # per-state low-rank elements
    proj = min(m, n) * r if store_projector else 0
    return lead * (2 * low + proj)


def optimizer_state_elements(
    shapes: Mapping | Any,
    method: str,
    rank: int = 256,
    rules: LabelRules | None = None,
) -> int:
    """Number of extra optimizer-state elements for ``method``.

    ``shapes`` is a pytree of arrays or shape-tuples.
    """
    rules = rules or LabelRules()
    leaves_with_path = jax.tree_util.tree_flatten_with_path(shapes, is_leaf=_is_shape)[0]

    from .labels import path_str  # local import to avoid cycle

    total = 0
    for kp, leaf in leaves_with_path:
        shape = _shape_of(leaf)
        lab = rules.classify(path_str(kp), len(shape))
        n = _size(shape)
        if method == "sgd":
            extra = 0
        elif method in ("sgd_momentum",):
            extra = n
        elif method in ("adam", "adamw", "stable_spam"):
            extra = 2 * n
        elif method == "muon":
            # first-order momentum everywhere (paper App. B counts 1x total)
            extra = n if lab != "vector" else 2 * n
        elif method == "swan":
            # Adam on first + last layers; stateless elsewhere
            extra = 2 * n if lab in ("first", "last", "vector") else 0
        elif method == "scale":
            # momentum on last layer only; Adam on vectors (negligible)
            if lab == "last":
                extra = n
            elif lab == "vector":
                extra = 2 * n
            else:
                extra = 0
        elif method in ("galore", "fira"):
            if lab == "matrix":
                extra = _proj_state_sizes(shape, rank, store_projector=True)
            else:
                extra = 2 * n
        elif method == "apollo":
            if lab == "matrix":
                extra = _proj_state_sizes(shape, rank, store_projector=False)
            else:
                extra = 2 * n
        elif method == "apollo_mini":
            if lab == "matrix":
                extra = _proj_state_sizes(shape, 1, store_projector=False)
            else:
                extra = 2 * n
        else:
            raise ValueError(f"unknown method {method!r}")
        total += extra
    return total


def momentum_eligible_elements(
    shapes: Mapping | Any,
    method: str,
    rules: LabelRules | None = None,
) -> int:
    """State elements that ``momentum_dtype="bfloat16"`` would store in bf16.

    Mirrors the pipeline's cast-on-read/write rule: the *first* moment of
    >=2-D params for methods whose factory exposes ``momentum_dtype``
    (adam/adamw, muon, scale's LM-head momentum). Vector Adam moments and
    every second moment stay f32 regardless, and methods without the knob
    (sgd*, swan, stable_spam, the galore family) contribute zero.
    """
    rules = rules or LabelRules()
    leaves_with_path = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=_is_shape)[0]
    from .labels import path_str  # local import to avoid cycle

    total = 0
    for kp, leaf in leaves_with_path:
        shape = _shape_of(leaf)
        lab = rules.classify(path_str(kp), len(shape))
        n = _size(shape)
        if method in ("adam", "adamw") and lab != "vector":
            total += n
        elif method == "muon" and lab != "vector":
            # paper counts muon's non-vector state as 1x = the first moment
            total += n
        elif method == "scale" and lab == "last":
            total += n
    return total


def memory_report(
    shapes, method: str, dtype_bytes: int = 2, rank: int = 256,
    rules: LabelRules | None = None, momentum_dtype: str | None = None,
) -> MemoryReport:
    """Analytic weight/state bytes for ``method`` (paper Appendix B protocol).

    ``momentum_dtype="bfloat16"`` bills the momentum-eligible first-moment
    elements (see :func:`momentum_eligible_elements`) at 2 bytes instead of
    ``dtype_bytes``. With the default ``dtype_bytes=2`` (the paper's bf16
    protocol) that is a no-op; pass ``dtype_bytes=4`` for actual f32-state
    footprints where the knob halves the eligible portion.
    """
    leaves = jax.tree_util.tree_leaves(shapes, is_leaf=_is_shape)
    weight_elems = sum(_size(_shape_of(l)) for l in leaves)
    state_elems = optimizer_state_elements(shapes, method, rank=rank, rules=rules)
    state_bytes = state_elems * dtype_bytes
    if momentum_dtype == "bfloat16":
        mu = momentum_eligible_elements(shapes, method, rules=rules)
        state_bytes += mu * (2 - dtype_bytes)
    elif momentum_dtype not in (None, "float32"):
        raise ValueError(
            f"momentum_dtype must be float32|bfloat16, got {momentum_dtype!r}")
    return MemoryReport(method, weight_elems * dtype_bytes, state_bytes)


METHODS = ("sgd", "sgd_momentum", "adam", "adamw", "stable_spam", "muon",
           "swan", "galore", "fira", "apollo", "apollo_mini", "scale")
