"""Learning-rate schedules (paper Appendix C: cosine + 10% linear warmup)."""
from __future__ import annotations

import jax.numpy as jnp

from .types import Schedule


def constant(lr: float) -> Schedule:
    def f(step):
        return jnp.asarray(lr, jnp.float32)
    return f


def linear_warmup_cosine(
    peak_lr: float,
    total_steps: int,
    warmup_frac: float = 0.1,
    final_frac: float = 0.1,
) -> Schedule:
    warmup_steps = max(1, int(total_steps * warmup_frac))

    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / warmup_steps
        progress = jnp.clip(
            (step - warmup_steps) / max(1, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = peak_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos).astype(jnp.float32)

    return f
