"""Baseline optimizers (paper §4): SGD(±M), Adam(W), Stable-SPAM, Muon,
and SGD with a chosen gradient normalization (Table 2 ablations).

Every optimizer here is a thin stage composition over the shared leaf-update
pipeline (:mod:`repro.core.pipeline`): per-label :class:`~repro.core
.pipeline.Stages` plans are handed to ``build_pipeline``, which owns the
init/update/update_params machinery, the kernel lowering, and the state
treedef. Per the paper (Appendix C) every memory-efficient method applies
Adam to <=1-D "vector" parameters, whose size is negligible. State buffers a
composition does not need are zero-length placeholders so the state pytree
has uniform structure at ~zero cost.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .labels import LabelRules
from .pipeline import ADAM_LR_STAGE, PipeState, Stages, build_pipeline
from .types import GradientTransformation, Schedule, global_norm

_f32 = jnp.float32

# Back-compat aliases: every pipeline optimizer shares one state treedef.
AdamState = SgdState = NormSgdState = MuonState = PipeState


def adam(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    momentum_dtype: str = "float32",
) -> GradientTransformation:
    """Adam / AdamW (decoupled weight decay if ``weight_decay > 0``).

    ``momentum_dtype="bfloat16"`` stores the first moment of >=2-D params in
    bf16 (cast-on-read/write; vector moments and the second moment stay f32).
    """
    st = Stages(adam=True, weight_decay=weight_decay)
    return build_pipeline({lab: st for lab in ("first", "last", "matrix",
                                               "vector")},
                          lr, b1=b1, b2=b2, eps=eps,
                          momentum_dtype=momentum_dtype)


def adams(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    momentum_dtype: str = "float32",
) -> GradientTransformation:
    """AdamS (Huang et al., 2025): momentum itself as the normalizer.

    Adam's second-moment buffer is dropped; the denominator is synthesized
    per step as ``sqrt(b2 * m_hat^2 + (1 - b2) * g^2)``, so the state is
    SGDM-sized (first moment only) while step sizes stay Adam-like.
    ``weight_decay`` is decoupled, as in AdamW. ``momentum_dtype=
    "bfloat16"`` stores the >=2-D first moment in bf16 (cast-on-read/
    write), halving the *entire* optimizer state — AdamS has no other
    matrix buffer to keep in f32.
    """
    st = Stages(adams=True, weight_decay=weight_decay)
    return build_pipeline({lab: st for lab in ("first", "last", "matrix",
                                               "vector")},
                          lr, b1=b1, b2=b2, eps=eps,
                          momentum_dtype=momentum_dtype)


def sgd(
    lr: Schedule | float,
    momentum: float = 0.0,
    nesterov: bool = False,
) -> GradientTransformation:
    """Vanilla SGD, optional heavy-ball momentum (paper eq. (2)/(7))."""
    st = Stages(momentum=momentum, nesterov=nesterov)
    return build_pipeline({lab: st for lab in ("first", "last", "matrix",
                                               "vector")}, lr)


def normalized_sgd(
    lr: Schedule | float,
    kind: str = "col",
    rules: Optional[LabelRules] = None,
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    impl: str = "jnp",
    momentum_dtype: str = "float32",
) -> GradientTransformation:
    """SGD + gradient normalization on all matrix params (Table 2 rows).

    ``kind`` in {col,row,sign,ns,svd}. Vector params use Adam (Appendix C).
    ``impl="fused"`` lowers the col/row kinds to the Pallas normalize /
    norm_update kernels (sign/ns/svd stay on the jnp path).
    ``momentum_dtype`` is accepted for zoo uniformity; with the standard
    labels this optimizer carries no >=2-D first moment, so it is a no-op
    beyond the vector Adam moments (which stay f32 regardless).
    """
    norm_st = Stages(norm=kind)
    plans = {"first": norm_st, "last": norm_st, "matrix": norm_st,
             "vector": ADAM_LR_STAGE}
    return build_pipeline(plans, lr, adam_lr, b1=b1, b2=b2, eps=eps,
                          rules=rules, impl=impl,
                          momentum_dtype=momentum_dtype)


def stable_spam_adam(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    gamma1: float = 0.7,
    gamma2: float = 0.9,
    theta: float = 0.999,
    reset_interval: int = 1000,
) -> GradientTransformation:
    """Adam (Stable-SPAM): adaptive norm/spike clipping + momentum reset.

    Follows Huang et al. (2025): AdaClip clips per-element spikes above the
    EMA of the historical max |g|; AdaGN rescales the global norm toward its
    EMA; momentum (m, v) is reset every ``reset_interval`` steps. The
    clipping runs as the pipeline's tree-level ``pre`` hook, the reset via
    ``reset_interval``, and the update itself is the plain Adam stage.
    """

    def pre_init(params):
        return {
            "norm_ema": jnp.zeros((), _f32),
            "max_ema": jax.tree_util.tree_map(
                lambda p: jnp.zeros((), _f32), params),
        }

    def pre(grads, extra, count):
        # --- AdaClip: per-tensor spike clipping against EMA of max|g|.
        def clip_leaf(g, mx):
            gf = g.astype(_f32)
            gmax = jnp.max(jnp.abs(gf))
            mx = theta * mx + (1 - theta) * gmax
            mx_hat = mx / (1.0 - theta ** (count + 1))
            scale = jnp.where(jnp.abs(gf) > mx_hat,
                              mx_hat / (jnp.abs(gf) + 1e-12), 1.0)
            return gf * scale, mx

        out = jax.tree_util.tree_map(clip_leaf, grads, extra["max_ema"])
        istup = lambda x: isinstance(x, tuple)
        grads_c = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
        max_ema = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)

        # --- AdaGN: global-norm rescaling toward EMA.
        gnorm = global_norm(grads_c)
        norm_ema = gamma1 * extra["norm_ema"] + (1 - gamma1) * gnorm
        norm_hat = norm_ema / (1.0 - gamma1 ** (count + 1))
        gscale = jnp.where(gnorm > gamma2 * norm_hat + eps,
                           (gamma2 * norm_hat + eps) / (gnorm + 1e-12), 1.0)
        grads_c = jax.tree_util.tree_map(lambda g: g * gscale, grads_c)
        return grads_c, {"norm_ema": norm_ema, "max_ema": max_ema}

    st = Stages(adam=True)
    return build_pipeline({lab: st for lab in ("first", "last", "matrix",
                                               "vector")},
                          lr, b1=b1, b2=b2, eps=eps, pre=pre,
                          pre_init=pre_init, reset_interval=reset_interval)


def muon(
    lr: Schedule | float,
    beta: float = 0.95,
    nesterov: bool = True,
    ns_steps: int = 5,
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
    lr_scaling: bool = True,
    momentum_dtype: str = "float32",
) -> GradientTransformation:
    """Muon (Jordan et al., 2024): momentum + Newton–Schulz orthogonalization
    for hidden matrices; Adam for embeddings, LM head, and vector params.
    Stores first-order momentum for every matrix (Table 4 memory row).
    ``momentum_dtype="bfloat16"`` halves that momentum's storage (and the
    first/last adam-m) with cast-on-read/write semantics.
    """
    matrix_st = Stages(momentum=beta, nesterov=nesterov, norm="ns",
                       ns_steps=ns_steps, lr_scaling=lr_scaling)
    plans = {"first": ADAM_LR_STAGE, "last": ADAM_LR_STAGE,
             "matrix": matrix_st, "vector": ADAM_LR_STAGE}
    return build_pipeline(plans, lr, adam_lr, b1=b1, b2=b2, eps=eps,
                          rules=rules, momentum_dtype=momentum_dtype)
