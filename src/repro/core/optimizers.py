"""Baseline optimizers (paper §4): SGD(±M), Adam(W), Stable-SPAM, Muon,
and SGD with a chosen gradient normalization (Table 2 ablations).

All optimizers are label-aware: per the paper (Appendix C) every
memory-efficient method applies Adam to <=1-D "vector" parameters, whose
size is negligible. State buffers that a method does not need are stored as
zero-length arrays so the state pytree has uniform structure at ~zero cost.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .labels import LabelRules, label_tree
from .normalization import normalize, ns_orthogonalize
from .types import GradientTransformation, PyTree, Schedule, global_norm

_f32 = jnp.float32


def _empty(p):
    return jnp.zeros((0,), _f32)


def _zeros(p):
    return jnp.zeros(p.shape, _f32)


def _lr_at(lr, count):
    return lr(count) if callable(lr) else jnp.asarray(lr, _f32)


def muon_lr_scale(shape) -> float:
    """Muon's matched-lr scaling (Liu et al., 2025): 0.2 * sqrt(max dims)."""
    return 0.2 * float(max(shape[-2], shape[-1])) ** 0.5


def _adam_leaf(g, m, v, count, b1, b2, eps):
    gf = g.astype(_f32)
    m = b1 * m + (1.0 - b1) * gf
    v = b2 * v + (1.0 - b2) * gf * gf
    mhat = m / (1.0 - b1 ** (count + 1))
    vhat = v / (1.0 - b2 ** (count + 1))
    upd = mhat / (jnp.sqrt(vhat) + eps)
    return upd, m, v


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree
    nu: PyTree


def adam(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    """Adam / AdamW (decoupled weight decay if ``weight_decay > 0``)."""

    def init(params):
        return AdamState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(_zeros, params),
            nu=jax.tree_util.tree_map(_zeros, params),
        )

    def update(grads, state, params=None):
        count = state.count
        lr_t = _lr_at(lr, count)

        def leaf(g, m, v, p):
            upd, m, v = _adam_leaf(g, m, v, count, b1, b2, eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(_f32)
            return -lr_t * upd, m, v

        out = jax.tree_util.tree_map(leaf, grads, state.mu, state.nu, params)
        updates = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamState(count + 1, mu, nu)

    return GradientTransformation(init, update)


class SgdState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree  # empty leaves when momentum == 0


def sgd(
    lr: Schedule | float,
    momentum: float = 0.0,
    nesterov: bool = False,
) -> GradientTransformation:
    """Vanilla SGD, optional heavy-ball momentum (paper eq. (2)/(7))."""

    def init(params):
        mk = _zeros if momentum else _empty
        return SgdState(jnp.zeros((), jnp.int32), jax.tree_util.tree_map(mk, params))

    def update(grads, state, params=None):
        del params
        lr_t = _lr_at(lr, state.count)

        def leaf(g, m):
            gf = g.astype(_f32)
            if momentum:
                m = momentum * m + (1.0 - momentum) * gf
                d = momentum * m + (1.0 - momentum) * gf if nesterov else m
            else:
                d = gf
            return -lr_t * d, m

        out = jax.tree_util.tree_map(leaf, grads, state.mu)
        updates = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, SgdState(state.count + 1, mu)

    return GradientTransformation(init, update)


class NormSgdState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree  # adam-m for vectors only
    nu: PyTree  # adam-v for vectors only


def normalized_sgd(
    lr: Schedule | float,
    kind: str = "col",
    rules: Optional[LabelRules] = None,
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> GradientTransformation:
    """SGD + gradient normalization on all matrix params (Table 2 rows).

    ``kind`` in {col,row,sign,ns,svd}. Vector params use Adam (Appendix C).
    """
    rules = rules or LabelRules()
    adam_lr = adam_lr if adam_lr is not None else lr

    def init(params):
        labels = label_tree(params, rules)

        def mk(lab, p):
            return _zeros(p) if lab == "vector" else _empty(p)

        z = jax.tree_util.tree_map(mk, labels, params)
        return NormSgdState(jnp.zeros((), jnp.int32), z,
                            jax.tree_util.tree_map(lambda x: x, z))

    def update(grads, state, params=None):
        labels = label_tree(grads, rules)
        count = state.count
        lr_t = _lr_at(lr, count)
        alr_t = _lr_at(adam_lr, count)

        def leaf(lab, g, m, v):
            if lab == "vector":
                upd, m, v = _adam_leaf(g, m, v, count, b1, b2, eps)
                return -alr_t * upd, m, v
            return -lr_t * normalize(g.astype(_f32), kind), m, v

        out = jax.tree_util.tree_map(leaf, labels, grads, state.mu, state.nu)
        istup = lambda x: isinstance(x, tuple)
        return (
            jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup),
            NormSgdState(
                count + 1,
                jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup),
                jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=istup),
            ),
        )

    return GradientTransformation(init, update)


class StableSpamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree
    nu: PyTree
    norm_ema: jnp.ndarray  # AdaGN: EMA of gradient global-norm
    max_ema: PyTree        # AdaClip: EMA of per-tensor max |g|


def stable_spam_adam(
    lr: Schedule | float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    gamma1: float = 0.7,
    gamma2: float = 0.9,
    theta: float = 0.999,
    reset_interval: int = 1000,
) -> GradientTransformation:
    """Adam (Stable-SPAM): adaptive norm/spike clipping + momentum reset.

    Follows Huang et al. (2025): AdaClip clips per-element spikes above the
    EMA of the historical max |g|; AdaGN rescales the global norm toward its
    EMA; momentum (m, v) is reset every ``reset_interval`` steps.
    """

    def init(params):
        return StableSpamState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(_zeros, params),
            nu=jax.tree_util.tree_map(_zeros, params),
            norm_ema=jnp.zeros((), _f32),
            max_ema=jax.tree_util.tree_map(lambda p: jnp.zeros((), _f32), params),
        )

    def update(grads, state, params=None):
        del params
        count = state.count
        lr_t = _lr_at(lr, count)

        # --- AdaClip: per-tensor spike clipping against EMA of max|g|.
        def clip_leaf(g, mx):
            gf = g.astype(_f32)
            gmax = jnp.max(jnp.abs(gf))
            mx = theta * mx + (1 - theta) * gmax
            mx_hat = mx / (1.0 - theta ** (count + 1))
            scale = jnp.where(jnp.abs(gf) > mx_hat, mx_hat / (jnp.abs(gf) + 1e-12), 1.0)
            return gf * scale, mx

        out = jax.tree_util.tree_map(clip_leaf, grads, state.max_ema)
        istup = lambda x: isinstance(x, tuple)
        grads_c = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
        max_ema = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)

        # --- AdaGN: global-norm rescaling toward EMA.
        gnorm = global_norm(grads_c)
        norm_ema = gamma1 * state.norm_ema + (1 - gamma1) * gnorm
        norm_hat = norm_ema / (1.0 - gamma1 ** (count + 1))
        gscale = jnp.where(gnorm > gamma2 * norm_hat + eps,
                           (gamma2 * norm_hat + eps) / (gnorm + 1e-12), 1.0)
        grads_c = jax.tree_util.tree_map(lambda g: g * gscale, grads_c)

        # --- momentum reset
        do_reset = (count % reset_interval) == 0
        mu0 = jax.tree_util.tree_map(
            lambda m: jnp.where(do_reset & (count > 0), jnp.zeros_like(m), m), state.mu)
        nu0 = jax.tree_util.tree_map(
            lambda v: jnp.where(do_reset & (count > 0), jnp.zeros_like(v), v), state.nu)

        def leaf(g, m, v):
            upd, m, v = _adam_leaf(g, m, v, count, b1, b2, eps)
            return -lr_t * upd, m, v

        out = jax.tree_util.tree_map(leaf, grads_c, mu0, nu0)
        return (
            jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup),
            StableSpamState(
                count + 1,
                jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup),
                jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=istup),
                norm_ema,
                max_ema,
            ),
        )

    return GradientTransformation(init, update)


class MuonState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree  # momentum for matrices; adam-m for first/last/vector
    nu: PyTree  # adam-v for first/last/vector


def muon(
    lr: Schedule | float,
    beta: float = 0.95,
    nesterov: bool = True,
    ns_steps: int = 5,
    adam_lr: Schedule | float | None = None,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    rules: Optional[LabelRules] = None,
    lr_scaling: bool = True,
) -> GradientTransformation:
    """Muon (Jordan et al., 2024): momentum + Newton–Schulz orthogonalization
    for hidden matrices; Adam for embeddings, LM head, and vector params.
    Stores first-order momentum for every matrix (Table 4 memory row).
    """
    rules = rules or LabelRules()
    adam_lr = adam_lr if adam_lr is not None else lr

    def init(params):
        labels = label_tree(params, rules)
        mu = jax.tree_util.tree_map(lambda lab, p: _zeros(p), labels, params)
        nu = jax.tree_util.tree_map(
            lambda lab, p: _zeros(p) if lab != "matrix" else _empty(p),
            labels, params)
        return MuonState(jnp.zeros((), jnp.int32), mu, nu)

    def update(grads, state, params=None):
        labels = label_tree(grads, rules)
        count = state.count
        lr_t = _lr_at(lr, count)
        alr_t = _lr_at(adam_lr, count)

        def leaf(lab, g, m, v):
            gf = g.astype(_f32)
            if lab == "matrix":
                m = beta * m + (1.0 - beta) * gf
                d = beta * m + (1.0 - beta) * gf if nesterov else m
                o = ns_orthogonalize(d, ns_steps)
                s = muon_lr_scale(g.shape) if lr_scaling else 1.0
                return -lr_t * s * o, m, v
            upd, m, v = _adam_leaf(g, m, v, count, b1, b2, eps)
            return -alr_t * upd, m, v

        out = jax.tree_util.tree_map(leaf, labels, grads, state.mu, state.nu)
        istup = lambda x: isinstance(x, tuple)
        return (
            jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup),
            MuonState(
                count + 1,
                jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup),
                jax.tree_util.tree_map(lambda o: o[2], out, is_leaf=istup),
            ),
        )

    return GradientTransformation(init, update)
