"""Projection-based memory-efficient Adam variants: GaLore, Fira, APOLLO(-Mini).

These compress Adam's (m, v) states into a rank-``r`` subspace per matrix:

  * GaLore (Zhao et al., 2024): SVD projection refreshed every ``update_proj_gap``
    steps; Adam runs on the projected gradient; the update is projected back.
  * Fira (Chen et al., 2024): GaLore + the full-rank residual re-scaled by the
    low-rank Adam norm ratio ("norm-based scaling").
  * APOLLO (Zhu et al., 2025): *random* projection; the low-rank Adam update is
    used only to estimate channel-wise gradient scaling factors applied to the
    raw full-rank gradient. APOLLO-Mini is the rank-1 / tensor-wise variant.

Per the paper (§4), all of these run full Adam on the first (embedding) and
last (LM head) layers and on vector params — which dominates their memory at
small model sizes. Memory accounting lives in :mod:`repro.core.memory`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .labels import LabelRules, label_tree
from .optimizers import _adam_leaf, _empty, _lr_at, _zeros
from .types import GradientTransformation, PyTree, Schedule

_f32 = jnp.float32


def _proj_left(shape) -> bool:
    """Project the smaller dimension (GaLore's rule): left iff d_in <= d_out."""
    return shape[-2] <= shape[-1]


def _rank_for(shape, rank: int) -> int:
    return min(rank, shape[-2], shape[-1])


def _svd_projector(g: jnp.ndarray, r: int) -> jnp.ndarray:
    """Top-r left (or right) singular vectors of g, shape (..., min_dim, r).

    Stacked (scan-over-layers / per-expert) leaves project per slice.
    """
    gf = g.astype(_f32)
    if _proj_left(g.shape):
        u, _, _ = jnp.linalg.svd(gf, full_matrices=False)
        return u[..., :, :r]  # (..., m, r)
    _, _, vt = jnp.linalg.svd(gf, full_matrices=False)
    return jnp.swapaxes(vt[..., :r, :], -1, -2)  # (..., n, r)


def _random_projector(key, shape, r: int) -> jnp.ndarray:
    d = shape[-2] if _proj_left(shape) else shape[-1]
    return jax.random.normal(key, tuple(shape[:-2]) + (d, r), _f32) / jnp.sqrt(r)


def _project(g, p):
    # left: R = P^T G  (..., r, n); right: R = G P  (..., m, r)
    if _proj_left(g.shape):
        return jnp.einsum("...dr,...dn->...rn", p, g)
    return jnp.einsum("...mn,...nr->...mr", g, p)


def _project_back(r_upd, p, shape):
    if _proj_left(shape):
        return jnp.einsum("...dr,...rn->...dn", p, r_upd)
    return jnp.einsum("...mr,...nr->...mn", r_upd, p)


class GaloreState(NamedTuple):
    count: jnp.ndarray
    proj: PyTree
    mu: PyTree
    nu: PyTree


def _galore_family(
    lr: Schedule | float,
    rank: int,
    update_proj_gap: int,
    scale_factor: float,
    mode: str,  # "galore" | "fira" | "apollo" | "apollo_mini"
    rules: Optional[LabelRules],
    b1: float,
    b2: float,
    eps: float,
    seed: int,
) -> GradientTransformation:
    rules = rules or LabelRules()
    random_proj = mode in ("apollo", "apollo_mini")
    eff_rank = 1 if mode == "apollo_mini" else rank

    def _is_lowrank(lab):
        return lab == "matrix"  # first/last/vector use full Adam

    def init(params):
        labels = label_tree(params, rules)
        base_key = jax.random.PRNGKey(seed)

        def mk_proj(path_i, lab, p):
            if not _is_lowrank(lab):
                return _empty(p)
            r = _rank_for(p.shape, eff_rank)
            if random_proj:
                return _random_projector(jax.random.fold_in(base_key, path_i), p.shape, r)
            d = p.shape[-2] if _proj_left(p.shape) else p.shape[-1]
            return jnp.zeros(tuple(p.shape[:-2]) + (d, r), _f32)

        def mk_state(lab, p):
            if not _is_lowrank(lab):
                return _zeros(p)
            r = _rank_for(p.shape, eff_rank)
            rshape = (r, p.shape[-1]) if _proj_left(p.shape) else (p.shape[-2], r)
            return jnp.zeros(tuple(p.shape[:-2]) + rshape, _f32)

        leaves, treedef = jax.tree_util.tree_flatten(params)
        lab_leaves = jax.tree_util.tree_leaves(labels)
        proj = jax.tree_util.tree_unflatten(
            treedef, [mk_proj(i, l, p) for i, (l, p) in enumerate(zip(lab_leaves, leaves))])
        mu = jax.tree_util.tree_map(mk_state, labels, params)
        nu = jax.tree_util.tree_map(mk_state, labels, params)
        return GaloreState(jnp.zeros((), jnp.int32), proj, mu, nu)

    def update(grads, state, params=None):
        del params
        labels = label_tree(grads, rules)
        count = state.count
        lr_t = _lr_at(lr, count)
        refresh = (count % update_proj_gap) == 0
        base_key = jax.random.fold_in(jax.random.PRNGKey(seed), count // update_proj_gap)

        def leaf(path_i, lab, g, p, m, v):
            gf = g.astype(_f32)
            if not _is_lowrank(lab):
                upd, m, v = _adam_leaf(gf, m, v, count, b1, b2, eps)
                return -lr_t * upd, p, m, v
            r = _rank_for(g.shape, eff_rank)
            if random_proj:
                new_p = _random_projector(jax.random.fold_in(base_key, path_i), g.shape, r)
            else:
                new_p = _svd_projector(gf, r)
            p = jax.lax.cond(refresh, lambda: new_p, lambda: p)
            R = _project(gf, p)
            r_upd, m, v = _adam_leaf(R, m, v, count, b1, b2, eps)
            if mode == "galore":
                full = _project_back(r_upd, p, g.shape) * scale_factor
            elif mode == "fira":
                back = _project_back(r_upd, p, g.shape)
                resid = gf - _project_back(R, p, g.shape)
                phi = jnp.linalg.norm(r_upd) / (jnp.linalg.norm(R) + 1e-12)
                full = (back + phi * resid) * scale_factor
            else:  # apollo / apollo_mini: channel-wise gradient scaling
                if mode == "apollo_mini":
                    s = jnp.linalg.norm(r_upd) / (jnp.linalg.norm(R) + 1e-12)
                    full = gf * s * jnp.sqrt(jnp.asarray(128.0, _f32))  # tensor-wise + heuristic sqrt(rank_ref) boost
                else:
                    # channel = output column when left-projected, row otherwise
                    axis = -2 if _proj_left(g.shape) else -1
                    num = jnp.linalg.norm(r_upd, axis=axis, keepdims=True)
                    den = jnp.linalg.norm(R, axis=axis, keepdims=True) + 1e-12
                    full = gf * (num / den)
                full = full * scale_factor
            return -lr_t * full, p, m, v

        leaves, treedef = jax.tree_util.tree_flatten(grads)
        lab_leaves = jax.tree_util.tree_leaves(labels)
        p_leaves = jax.tree_util.tree_leaves(state.proj)
        m_leaves = jax.tree_util.tree_leaves(state.mu)
        v_leaves = jax.tree_util.tree_leaves(state.nu)
        outs = [leaf(i, l, g, p, m, v) for i, (l, g, p, m, v) in
                enumerate(zip(lab_leaves, leaves, p_leaves, m_leaves, v_leaves))]
        updates = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
        proj = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
        mu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
        nu = jax.tree_util.tree_unflatten(treedef, [o[3] for o in outs])
        return updates, GaloreState(count + 1, proj, mu, nu)

    return GradientTransformation(init, update)


def galore(lr, rank: int = 256, update_proj_gap: int = 200, scale_factor: float = 0.25,
           rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, rank, update_proj_gap, scale_factor, "galore",
                          rules, b1, b2, eps, seed)


def fira(lr, rank: int = 256, update_proj_gap: int = 200, scale_factor: float = 0.25,
         rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, rank, update_proj_gap, scale_factor, "fira",
                          rules, b1, b2, eps, seed)


def apollo(lr, rank: int = 256, update_proj_gap: int = 200, scale_factor: float = 1.0,
           rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, rank, update_proj_gap, scale_factor, "apollo",
                          rules, b1, b2, eps, seed)


def apollo_mini(lr, update_proj_gap: int = 200, scale_factor: float = 1.0,
                rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, 1, update_proj_gap, scale_factor, "apollo_mini",
                          rules, b1, b2, eps, seed)
