"""Projection-based memory-efficient Adam variants: GaLore, Fira, APOLLO(-Mini).

These compress Adam's (m, v) states into a rank-``r`` subspace per matrix:

  * GaLore (Zhao et al., 2024): SVD projection refreshed every ``update_proj_gap``
    steps; Adam runs on the projected gradient; the update is projected back.
  * Fira (Chen et al., 2024): GaLore + the full-rank residual re-scaled by the
    low-rank Adam norm ratio ("norm-based scaling").
  * APOLLO (Zhu et al., 2025): *random* projection; the low-rank Adam update is
    used only to estimate channel-wise gradient scaling factors applied to the
    raw full-rank gradient. APOLLO-Mini is the rank-1 / tensor-wise variant.

The whole family is one pipeline composition: hidden matrices take the
:class:`~repro.core.pipeline.Project` stage (projection + low-rank Adam +
mode-specific back-projection, all owned by the pipeline engine — the
projector tree lives in ``state.extra["proj"]``), while the first
(embedding) and last (LM head) layers and vector params run full Adam —
which dominates their memory at small model sizes (paper §4). Memory
accounting lives in :mod:`repro.core.memory`.
"""
from __future__ import annotations

from typing import Optional

from .labels import LabelRules
from .pipeline import (ADAM_STAGE, PipeState, Project, Stages, _project,
                       build_pipeline)
from .types import GradientTransformation, Schedule

GaloreState = PipeState


def _galore_family(
    lr: Schedule | float,
    rank: int,
    update_proj_gap: int,
    scale_factor: float,
    mode: str,  # "galore" | "fira" | "apollo" | "apollo_mini"
    rules: Optional[LabelRules],
    b1: float,
    b2: float,
    eps: float,
    seed: int,
) -> GradientTransformation:
    spec = Project(mode=mode, rank=rank, update_proj_gap=update_proj_gap,
                   scale_factor=scale_factor, seed=seed)
    # first/last/vector use full Adam (paper §4); only hidden matrices are
    # low-rank
    plans = {"first": ADAM_STAGE, "last": ADAM_STAGE,
             "matrix": Stages(project=spec), "vector": ADAM_STAGE}
    return build_pipeline(plans, lr, b1=b1, b2=b2, eps=eps, rules=rules)


def galore(lr, rank: int = 256, update_proj_gap: int = 200, scale_factor: float = 0.25,
           rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, rank, update_proj_gap, scale_factor, "galore",
                          rules, b1, b2, eps, seed)


def fira(lr, rank: int = 256, update_proj_gap: int = 200, scale_factor: float = 0.25,
         rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, rank, update_proj_gap, scale_factor, "fira",
                          rules, b1, b2, eps, seed)


def apollo(lr, rank: int = 256, update_proj_gap: int = 200, scale_factor: float = 1.0,
           rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, rank, update_proj_gap, scale_factor, "apollo",
                          rules, b1, b2, eps, seed)


def apollo_mini(lr, update_proj_gap: int = 200, scale_factor: float = 1.0,
                rules=None, b1=0.9, b2=0.999, eps=1e-8, seed=0):
    return _galore_family(lr, 1, update_proj_gap, scale_factor, "apollo_mini",
                          rules, b1, b2, eps, seed)
