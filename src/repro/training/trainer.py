"""Training-step factory: loss + grad (with microbatch accumulation and
optional global-norm clipping) + optimizer update, all inside one jitted
function suitable for pjit sharding.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.types import GradientTransformation, apply_updates, global_norm
from repro.models import loss_fn
from repro.models.sharding import Rules


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def init_state(params, tx: GradientTransformation) -> TrainState:
    return TrainState(jnp.zeros((), jnp.int32), params, tx.init(params))


def make_train_step(cfg, tx: GradientTransformation, grad_accum: int = 1,
                    clip_norm: float = 0.0, aux_coef: float = 0.01,
                    rules: Optional[Rules] = None,
                    accum_dtype: str = "float32",
                    norm_metrics: bool = True):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` splits the batch into microbatches along axis 0 and
    accumulates gradients via ``lax.scan`` (bounded activation memory, the
    standard large-scale recipe). ``accum_dtype`` controls the accumulator
    precision — f32 by default; bf16 halves the accumulator HBM footprint
    for the largest models (dry-run default for >300B params).
    """
    rules = rules or Rules(cfg.rule_overrides)
    acc_dt = jnp.float32 if accum_dtype == "float32" else jnp.bfloat16

    def loss_of(params, mb):
        return loss_fn(params, cfg, mb, aux_coef=aux_coef, rules=rules)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def reshape(x):
            return x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.zeros((), jnp.float32)),
                                           micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
        loss = loss_sum / grad_accum
        return loss, {"loss": loss}, grads

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = compute_grads(state.params, batch)
        out_metrics = {"loss": loss}
        if clip_norm > 0 or norm_metrics:
            gnorm = global_norm(grads)
            out_metrics["grad_norm"] = gnorm
        if clip_norm > 0:
            scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        updates, opt_state = tx.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        if norm_metrics:
            out_metrics["update_norm"] = global_norm(updates)
        out_metrics.update({k: v for k, v in metrics.items() if k != "loss"})
        return TrainState(state.step + 1, params, opt_state), out_metrics

    return train_step


def make_eval_step(cfg, rules: Optional[Rules] = None):
    rules = rules or Rules(cfg.rule_overrides)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, rules=rules)
        return {"loss": metrics["loss"], "perplexity": jnp.exp(metrics["loss"])}

    return eval_step
