"""Training-step factory: loss + grad (with microbatch accumulation and
optional global-norm clipping) + optimizer update, all inside one jitted
function suitable for pjit sharding.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.types import GradientTransformation, apply_updates, global_norm
from repro.models import loss_fn
from repro.models.sharding import Rules


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def init_state(params, tx: GradientTransformation) -> TrainState:
    return TrainState(jnp.zeros((), jnp.int32), params, tx.init(params))


def make_train_step(cfg, tx: GradientTransformation, grad_accum: int = 1,
                    clip_norm: float = 0.0, aux_coef: float = 0.01,
                    rules: Optional[Rules] = None,
                    accum_dtype: str = "float32",
                    norm_metrics: bool = True,
                    fused_apply: Optional[bool] = None):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    ``grad_accum > 1`` splits the batch into microbatches along axis 0 and
    accumulates gradients via ``lax.scan`` (bounded activation memory, the
    standard large-scale recipe); per-microbatch auxiliary metrics (MoE
    aux-loss, token weight) are averaged alongside the loss. ``accum_dtype``
    controls the accumulator precision — f32 by default; bf16 halves the
    accumulator HBM footprint for the largest models (dry-run default for
    >300B params).

    ``fused_apply`` selects the optimizer's fused parameter write
    (``tx.update_params``: theta is read and written once, no materialized
    update tree). ``None`` (default) uses it whenever the optimizer provides
    one; ``True`` requires it; ``False`` forces the classic ``update`` +
    ``apply_updates`` sequence. Under the fused path the ``update_norm``
    metric is recovered from the old/new parameter diff, which re-reads
    both param trees — set ``norm_metrics=False`` to hold the fused path
    to its minimal HBM-pass count.
    """
    rules = rules or Rules(cfg.rule_overrides)
    acc_dt = jnp.float32 if accum_dtype == "float32" else jnp.bfloat16
    if fused_apply is None:
        fused_apply = tx.update_params is not None
    elif fused_apply and tx.update_params is None:
        raise ValueError("fused_apply=True but the optimizer has no "
                         "update_params (fused parameter write)")

    def loss_of(params, mb):
        return loss_fn(params, cfg, mb, aux_coef=aux_coef, rules=rules)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def reshape(x):
            return x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), acc, grads)
            # metrics (aux-loss, token weight, ...) are scalars: stack them
            # as scan outputs and average after — dropping them here loses
            # the MoE aux-loss signal whenever grad_accum > 1
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        (gsum, loss_sum), metrics_stack = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
        loss = loss_sum / grad_accum
        metrics = jax.tree_util.tree_map(
            lambda x: jnp.mean(x, axis=0), metrics_stack)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = compute_grads(state.params, batch)
        out_metrics = {"loss": loss}
        if clip_norm > 0 or norm_metrics:
            gnorm = global_norm(grads)
            out_metrics["grad_norm"] = gnorm
        if clip_norm > 0:
            scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        if fused_apply:
            params, opt_state = tx.update_params(grads, state.opt_state,
                                                 state.params)
            if norm_metrics:
                out_metrics["update_norm"] = global_norm(
                    jax.tree_util.tree_map(lambda a, b: a - b,
                                           params, state.params))
        else:
            updates, opt_state = tx.update(grads, state.opt_state, state.params)
            params = apply_updates(state.params, updates)
            if norm_metrics:
                out_metrics["update_norm"] = global_norm(updates)
        out_metrics.update({k: v for k, v in metrics.items() if k != "loss"})
        return TrainState(state.step + 1, params, opt_state), out_metrics

    return train_step


def make_eval_step(cfg, rules: Optional[Rules] = None):
    rules = rules or Rules(cfg.rule_overrides)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, rules=rules)
        return {"loss": metrics["loss"], "perplexity": jnp.exp(metrics["loss"])}

    return eval_step
