"""Training-step factory: loss + grad (with microbatch accumulation and
optional global-norm clipping) + optimizer update, all inside one jitted
function suitable for pjit sharding. An optional in-jit anomaly guard
(:mod:`repro.training.resilience`) vets every update before it is applied.
"""
from __future__ import annotations

import inspect
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.types import GradientTransformation, apply_updates, global_norm
from repro.models import loss_fn
from repro.models.sharding import Rules
from repro.obs.stats import StatsPolicy, make_stats_fn
from repro.training.resilience import (GuardPolicy, guard_step, guard_verdict,
                                       guarded_select, init_guard_state,
                                       inject_grad_faults)


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any
    # None unless the train step was built with a GuardPolicy (a None
    # subtree has no leaves, so guard-less states checkpoint identically
    # to the historical 3-field layout)
    guard: Any = None


def init_state(params, tx: GradientTransformation,
               guard: bool = False) -> TrainState:
    return TrainState(jnp.zeros((), jnp.int32), params, tx.init(params),
                      init_guard_state() if guard else None)


def make_train_step(cfg, tx: GradientTransformation, grad_accum: int = 1,
                    clip_norm: float = 0.0, aux_coef: float = 0.01,
                    rules: Optional[Rules] = None,
                    accum_dtype: str = "float32",
                    norm_metrics: bool = True,
                    fused_apply: Optional[bool] = None,
                    mesh: Optional[Mesh] = None,
                    donate: bool = False,
                    guard: Optional[GuardPolicy] = None,
                    faults=None,
                    stats: Optional[StatsPolicy] = None):
    """Build ``train_step(state, batch) -> (state, metrics)``.

    Batches are passed to the loss whole: packed-document batches
    (``data.pipeline`` with ``pack_documents``) simply carry their extra
    per-token leaves — ``segment_ids``, ``positions``, ``loss_weights`` —
    through the same dict; the microbatch reshape below tree_maps over
    every leaf, so packing and grad accumulation compose.

    ``grad_accum > 1`` splits the batch into microbatches along axis 0 and
    accumulates gradients via ``lax.scan`` (bounded activation memory, the
    standard large-scale recipe); per-microbatch auxiliary metrics (MoE
    aux-loss, token weight) are averaged alongside the loss. ``accum_dtype``
    controls the accumulator precision — f32 by default; bf16 halves the
    accumulator HBM footprint for the largest models (dry-run default for
    >300B params).

    ``fused_apply`` selects the optimizer's fused parameter write
    (``tx.update_params``: theta is read and written once, no materialized
    update tree). ``None`` (default) uses it whenever the optimizer provides
    one; ``True`` requires it; ``False`` forces the classic ``update`` +
    ``apply_updates`` sequence. Under the fused path the ``update_norm``
    metric is recovered from the old/new parameter diff (in f32 — bf16
    params would lose small updates to rounding), which re-reads both param
    trees — set ``norm_metrics=False`` to hold the fused path to its
    minimal HBM-pass count.

    ``mesh``: the pjit mesh the step will run under. Required for
    correctness whenever params are sharded and the optimizer runs custom
    kernels: the per-parameter ``NamedSharding`` tree (from ``rules`` +
    the model's logical axes) is passed to ``tx.update_params`` so the
    fused kernels shard_map over the mesh and psum their norm reductions.
    Optimizers without a ``shardings`` kwarg simply don't receive it.
    The mesh is also handed to the loss (``loss_fn(..., mesh=...)``,
    feature-detected the same way) so the fused LM-head cross-entropy can
    shard_map its kernels over the head's vocab/batch axes.

    When the optimizer's ``update_params`` accepts ``grad_scale``, global-
    norm clipping is folded into the parameter write (the clip factor
    scales the gradient inside the kernels) instead of rescaling the grad
    tree — one full grad read+write less per step, numerically identical
    to clip-then-update.

    ``donate=True`` returns the step already jitted with
    ``donate_argnums=(0,)``: the TrainState buffers are donated, which —
    combined with the apply kernels' ``input_output_aliases`` — makes the
    fused theta/momentum writes truly in-place (no fresh allocation).

    ``guard``: a :class:`repro.training.resilience.GuardPolicy`. The step
    then requires a guard-carrying state (``init_state(..., guard=True)``)
    and vets every update in-jit — non-finite loss/grad-norm or a loss
    spike skips the update (params and optimizer state pass through
    bitwise, via element-select), and the metrics gain ``skipped`` /
    ``bad_step`` / ``rollback`` (the latter trips after
    ``guard.max_bad_steps`` consecutive bad steps, signalling the host to
    restore a checkpoint and cut the LR — see ``launch/train.py``).

    ``faults``: a static :class:`repro.training.faults.FaultPlan` (resolved
    from ``REPRO_FAULTS`` outside jit). Only its gradient faults apply
    here: grads are corrupted with NaN/Inf at the spec'd steps via a
    traced select that is bitwise-inert on every other step.

    ``stats``: a :class:`repro.obs.stats.StatsPolicy`. The step then
    computes per-layer-group gradient/update/momentum statistics (the
    paper's Fig. 4/10 quantities — see :mod:`repro.obs.stats`) under a
    traced ``step % every_k == 0`` ``lax.cond`` and merges them into the
    metrics dict (``stats/<group>/<name>``, zeros plus ``stats/valid`` 0
    off the cadence step). The collector only reads — params and optimizer
    state are bitwise those of a stats-less step.
    """
    rules = rules or Rules(cfg.rule_overrides)
    acc_dt = jnp.float32 if accum_dtype == "float32" else jnp.bfloat16
    if fused_apply is None:
        fused_apply = tx.update_params is not None
    elif fused_apply and tx.update_params is None:
        raise ValueError("fused_apply=True but the optimizer has no "
                         "update_params (fused parameter write)")

    up_kwargs = {}
    if fused_apply:
        accepted = inspect.signature(tx.update_params).parameters
        if mesh is not None and "shardings" in accepted:
            from repro.models import param_logical_axes, param_shapes
            from repro.models.sharding import tree_shardings
            up_kwargs["shardings"] = tree_shardings(
                param_logical_axes(cfg), mesh, rules, param_shapes(cfg))
        fuse_clip = clip_norm > 0 and "grad_scale" in accepted
    else:
        fuse_clip = False

    # the fused-loss analog of the update_params feature-detection: only
    # pass the mesh to losses that know what to do with it
    loss_kwargs = {}
    if mesh is not None and "mesh" in inspect.signature(loss_fn).parameters:
        loss_kwargs["mesh"] = mesh

    stats_fn = make_stats_fn(stats) if stats is not None else None

    def loss_of(params, mb):
        # named scope -> the profiler groups the whole fwd (and, via jad's
        # transpose naming, the bwd) under one label in trace viewers
        with jax.named_scope("fwd"):
            return loss_fn(params, cfg, mb, aux_coef=aux_coef, rules=rules,
                           **loss_kwargs)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def compute_grads(params, batch):
        if grad_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads

        def reshape(x):
            if x.shape[0] % grad_accum:
                raise ValueError(
                    f"grad_accum={grad_accum} must divide the batch axis: "
                    f"got batch size {x.shape[0]} (remainder "
                    f"{x.shape[0] % grad_accum}); pick a batch size that is "
                    f"a multiple of grad_accum or lower grad_accum")
            return x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:])

        micro = jax.tree_util.tree_map(reshape, batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(acc_dt), acc, grads)
            # metrics (aux-loss, token weight, ...) are scalars: stack them
            # as scan outputs and average after — dropping them here loses
            # the MoE aux-loss signal whenever grad_accum > 1
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        (gsum, loss_sum), metrics_stack = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
        loss = loss_sum / grad_accum
        metrics = jax.tree_util.tree_map(
            lambda x: jnp.mean(x, axis=0), metrics_stack)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: dict):
        if guard is not None and state.guard is None:
            raise ValueError(
                "make_train_step(guard=...) needs a guard-carrying state: "
                "build it with init_state(params, tx, guard=True)")
        loss, metrics, grads = compute_grads(state.params, batch)
        grads = inject_grad_faults(faults, state.step, grads)
        raw_grads = grads   # pre-clip: what the Fig. 4/10 stats measure
        out_metrics = {"loss": loss}
        step_kwargs = dict(up_kwargs)
        if clip_norm > 0 or norm_metrics or guard is not None:
            gnorm = global_norm(grads)
            out_metrics["grad_norm"] = gnorm
        if clip_norm > 0:
            scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
            if fuse_clip:
                # folded into the optimizer's gradient read (in-kernel for
                # fused leaves): no materialized g*scale tree
                step_kwargs["grad_scale"] = scale
            else:
                grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        with jax.named_scope("optimizer_update"):
            if fused_apply:
                params, opt_state = tx.update_params(
                    grads, state.opt_state, state.params, **step_kwargs)
                updates = None
            else:
                updates, opt_state = tx.update(grads, state.opt_state,
                                               state.params)
                params = apply_updates(state.params, updates)
        gstate = state.guard
        ok = None
        if guard is not None:
            # the candidate update is computed unconditionally (NaNs and
            # all) and element-selected against the old buffers: a select
            # never propagates values from the discarded branch, so a
            # skipped step passes params and optimizer state through
            # bitwise — the exact state a clean run minus this step has
            with jax.named_scope("guard"):
                ok = guard_verdict(guard, state.guard, loss, gnorm)
                gstate, rollback = guard_step(guard, state.guard, ok, loss)
                params = guarded_select(ok, params, state.params)
                opt_state = guarded_select(ok, opt_state, state.opt_state)
            out_metrics["skipped"] = gstate.skipped
            out_metrics["bad_step"] = (~ok).astype(jnp.int32)
            out_metrics["rollback"] = rollback
        if norm_metrics:
            if fused_apply:
                # diff in f32: bf16 params round small per-element updates
                # away when differenced in the param dtype (post-guard, so
                # a skipped step truthfully reports 0)
                out_metrics["update_norm"] = global_norm(
                    jax.tree_util.tree_map(
                        lambda a, b: (a.astype(jnp.float32)
                                      - b.astype(jnp.float32)),
                        params, state.params))
            else:
                unorm = global_norm(updates)
                out_metrics["update_norm"] = (
                    jnp.where(ok, unorm, 0.0) if guard is not None else unorm)
        if stats_fn is not None:
            # post-guard tensors: a skipped step truthfully reports a zero
            # update; the collector is read-only, so params/opt_state are
            # bitwise those of a stats-less build
            # cadence keys off the *completed-step* index (state.step + 1),
            # the same 1-based numbering the console lines, checkpoint
            # steps and the driver's --metrics-every cadence use — so a
            # --stats-every multiple of --metrics-every lands stats on
            # emitted records
            with jax.named_scope("obs_stats"):
                out_metrics.update(stats_fn(state.step + 1, raw_grads,
                                            state.params, params, opt_state))
        out_metrics.update({k: v for k, v in metrics.items() if k != "loss"})
        return TrainState(state.step + 1, params, opt_state,
                          gstate), out_metrics

    if donate:
        # TrainState donation + the apply kernels' input_output_aliases =
        # in-place theta/momentum writes (no fresh param-sized buffers)
        return jax.jit(train_step, donate_argnums=(0,))
    return train_step


def make_eval_step(cfg, rules: Optional[Rules] = None,
                   mesh: Optional[Mesh] = None):
    rules = rules or Rules(cfg.rule_overrides)
    loss_kwargs = {}
    if mesh is not None and "mesh" in inspect.signature(loss_fn).parameters:
        loss_kwargs["mesh"] = mesh

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, rules=rules,
                                **loss_kwargs)
        return {"loss": metrics["loss"], "perplexity": jnp.exp(metrics["loss"])}

    return eval_step
