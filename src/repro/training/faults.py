"""Deterministic fault injection for the training resilience subsystem.

Production pretraining runs die in a small number of well-known ways:
gradient/loss blow-ups, IO errors under a flaky filesystem, hosts killed
mid-checkpoint-commit, and kernel paths that fail on one backend. This
module turns each of those into a *reproducible* event driven by the
``REPRO_FAULTS`` environment variable, so the guard/recovery machinery in
:mod:`repro.training.resilience` and :mod:`repro.checkpoint` can be
exercised by the chaos tests (and by hand against a real run) without
patching internals.

Spec grammar (read **outside** jit — the plan is resolved host-side and
threaded into traced code as static configuration, never via an env read
at trace time)::

    REPRO_FAULTS ::= clause (";" clause)*
    clause       ::= kind "@" arg (":" arg)*

    nan_grad@K        NaN gradients at global step K (repeatable)
    inf_grad@K        Inf gradients at global step K (repeatable)
    io_error@SITE:N   the first N IO ops at SITE raise OSError
                      (SITE in {save, commit}; exercises retry-with-backoff)
    kill@SITE:N       the N-th operation at SITE raises SimulatedKill —
                      a BaseException, so generic recovery code cannot
                      swallow it (SITE in {save, commit}: "save" fires
                      after the shard lands but before this host's
                      manifest; "commit" fires mid-commit, after the
                      merged manifest but before the COMMITTED marker)
    dispatch_fail@OP  the kernel route of dispatch op OP (or "*" for all)
                      raises at trace time — the dispatch layer must
                      degrade to the jnp reference and log the fallback

Examples::

    REPRO_FAULTS="nan_grad@3"
    REPRO_FAULTS="io_error@save:2;kill@commit:1"
    REPRO_FAULTS="nan_grad@5;inf_grad@9;dispatch_fail@norm_update"

Injection is deterministic: step-indexed faults key off the trainer's
step counter; counted faults (``io_error``, ``kill``) consume from
process-local counters that :func:`reset` rewinds (tests reset between
cases). An unset/empty ``REPRO_FAULTS`` makes every gate a cheap no-op.
"""
from __future__ import annotations

import functools
import os
import threading
from typing import NamedTuple

ENV_VAR = "REPRO_FAULTS"

_SITES = ("save", "commit")
_GRAD_KINDS = ("nan_grad", "inf_grad")


class FaultError(RuntimeError):
    """Raised by the dispatch gate to force the kernel-route failure."""


class SimulatedKill(BaseException):
    """A simulated hard kill (SIGKILL-at-the-worst-moment stand-in).

    Deliberately a ``BaseException``: recovery code that catches
    ``Exception`` (retry loops, graceful-degradation wrappers) must *not*
    be able to absorb a kill — the process is gone. Only the chaos tests
    catch it.
    """


class FaultPlan(NamedTuple):
    """Parsed, immutable ``REPRO_FAULTS`` spec.

    ``grad_steps``: ((step, kind), ...) sorted — kind in {nan, inf}.
    ``io_errors``: ((site, n), ...) — first n IO ops at site fail.
    ``kills``: ((site, n), ...) — the n-th op at site raises SimulatedKill.
    ``dispatch_ops``: op names (or "*") whose kernel route must fail.
    """
    grad_steps: tuple = ()
    io_errors: tuple = ()
    kills: tuple = ()
    dispatch_ops: tuple = ()

    def grad_fault_steps(self, kind: str) -> tuple:
        """Sorted global steps at which ``kind`` gradients are injected."""
        return tuple(s for s, k in self.grad_steps if k == kind)

    @property
    def any_grad_faults(self) -> bool:
        return bool(self.grad_steps)


def _int_arg(clause: str, arg: str) -> int:
    try:
        v = int(arg)
    except ValueError:
        raise ValueError(
            f"REPRO_FAULTS clause {clause!r}: {arg!r} is not an integer")
    if v < 0:
        raise ValueError(f"REPRO_FAULTS clause {clause!r}: {arg!r} < 0")
    return v


@functools.lru_cache(maxsize=None)
def parse_faults(spec: str) -> FaultPlan:
    """Parse a ``REPRO_FAULTS`` spec string (see module docstring).

    Raises ``ValueError`` naming the offending clause for anything outside
    the grammar — a silently ignored typo in a chaos spec would make the
    matrix vacuously green.
    """
    grad, io, kills, ops = [], [], [], []
    for clause in filter(None, (c.strip() for c in spec.split(";"))):
        kind, sep, rest = clause.partition("@")
        if not sep or not rest:
            raise ValueError(
                f"REPRO_FAULTS clause {clause!r}: expected kind@arg[:arg]")
        args = rest.split(":")
        if kind in _GRAD_KINDS:
            if len(args) != 1:
                raise ValueError(
                    f"REPRO_FAULTS clause {clause!r}: expected {kind}@step")
            grad.append((_int_arg(clause, args[0]), kind.split("_")[0]))
        elif kind in ("io_error", "kill"):
            if len(args) != 2 or args[0] not in _SITES:
                raise ValueError(
                    f"REPRO_FAULTS clause {clause!r}: expected "
                    f"{kind}@site:n with site in {_SITES}")
            (io if kind == "io_error" else kills).append(
                (args[0], _int_arg(clause, args[1])))
        elif kind == "dispatch_fail":
            if len(args) != 1 or not args[0]:
                raise ValueError(
                    f"REPRO_FAULTS clause {clause!r}: expected "
                    "dispatch_fail@op (op name or *)")
            ops.append(args[0])
        else:
            raise ValueError(
                f"REPRO_FAULTS clause {clause!r}: unknown fault kind "
                f"{kind!r} (known: nan_grad, inf_grad, io_error, kill, "
                "dispatch_fail)")
    return FaultPlan(tuple(sorted(grad)), tuple(io), tuple(kills),
                     tuple(ops))


def resolve_plan() -> FaultPlan | None:
    """Read ``REPRO_FAULTS`` *now* and parse it (None when unset/empty).

    Like ``dispatch.resolve_mode`` this re-reads the environment on every
    call — callers resolve it host-side (outside jit) and pass the plan
    into traced code as static configuration.
    """
    spec = os.environ.get(ENV_VAR, "").strip()
    return parse_faults(spec) if spec else None


# --------------------------------------------------------------------------
# Counted gates (IO errors, kills). Process-local, thread-safe (AsyncSave
# runs the checkpoint IO on a worker thread), rewound by reset().
# --------------------------------------------------------------------------

_lock = threading.Lock()
_counts: dict = {}


def reset() -> None:
    """Rewind all fault counters (chaos tests call this between cases)."""
    with _lock:
        _counts.clear()


def _bump(key: str) -> int:
    """1-based occurrence number of this event at ``key``."""
    with _lock:
        _counts[key] = _counts.get(key, 0) + 1
        return _counts[key]


def io_gate(site: str, plan: FaultPlan | None = None) -> None:
    """Raise OSError for the first N IO ops at ``site`` (per the plan).

    The checkpointer calls this inside its retried IO sections, so
    ``io_error@save:2`` with 3 retries exercises recovery end-to-end and
    ``io_error@save:9`` with 3 retries exercises the bounded give-up.
    """
    plan = resolve_plan() if plan is None else plan
    if plan is None:
        return
    budget = sum(n for s, n in plan.io_errors if s == site)
    if budget and _bump(f"io:{site}") <= budget:
        raise OSError(f"injected IO error at {site!r} (REPRO_FAULTS)")


def kill_gate(site: str, plan: FaultPlan | None = None) -> None:
    """Raise SimulatedKill on the configured occurrence at ``site``."""
    plan = resolve_plan() if plan is None else plan
    if plan is None:
        return
    hits = {n for s, n in plan.kills if s == site}
    if hits and _bump(f"kill:{site}") in hits:
        raise SimulatedKill(f"injected kill at {site!r} (REPRO_FAULTS)")


def dispatch_gate(op: str, plan: FaultPlan | None = None) -> None:
    """Raise FaultError when ``op``'s kernel route is spec'd to fail.

    Called by ``kernels.dispatch`` at the top of every kernel route (at
    trace time, host-side); the dispatch layer catches it — like any other
    kernel-path failure — and degrades to the jnp reference.
    """
    plan = resolve_plan() if plan is None else plan
    if plan is None:
        return
    if "*" in plan.dispatch_ops or op in plan.dispatch_ops:
        raise FaultError(
            f"injected kernel-dispatch failure for {op!r} (REPRO_FAULTS)")
