"""In-jit anomaly guard: step-level skip + host-side rollback signalling.

Low-state optimizers (SCALE keeps one momentum group and two Adam vectors)
have *less* state to absorb a bad step than Adam — a single NaN/Inf or
loss-spike update lands on the parameters almost directly, so the guard
sits inside the jitted train step and decides **per step** whether the
freshly computed update may be applied:

  * **finite checks** on the loss and the global gradient norm;
  * a **running loss-spike statistic**: an EMA of the (accepted) losses —
    a step whose loss exceeds ``spike_factor * ema`` after ``spike_warmup``
    accepted steps is anomalous even if finite (the stable_spam AdaClip
    idea at step granularity);
  * a bad step is **skipped**: params and optimizer state pass through
    bitwise (element-select against the old trees — no Python branching on
    traced values, the policy is pure ``jnp.where``), a ``skipped``
    counter increments and the bad loss never poisons the EMA;
  * after ``max_bad_steps`` *consecutive* bad steps the guard raises the
    ``rollback`` flag in the step metrics — the host (``launch/train.py``)
    reacts by restoring the last verifiable checkpoint and cutting the
    learning rate, which is exactly the action in-jit code cannot take.

Everything here is shape-polymorphic scalar arithmetic: the guard adds no
HBM traffic beyond the elementwise select of the two parameter trees.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class GuardPolicy(NamedTuple):
    """Static guard configuration (Python values, resolved outside jit).

    ``spike_factor``: accepted-loss-EMA multiple above which a finite loss
    is still anomalous; ``0`` disables the spike check (finite checks stay
    on). ``spike_warmup``: accepted steps before the spike check arms —
    the first losses of a fresh run are legitimately huge. ``ema_beta``:
    decay of the accepted-loss EMA. ``max_bad_steps``: consecutive bad
    steps before the ``rollback`` flag trips; ``0`` means never (skip
    forever).
    """
    spike_factor: float = 0.0
    spike_warmup: int = 20
    ema_beta: float = 0.99
    max_bad_steps: int = 0


class GuardState(NamedTuple):
    """Traced guard state, carried in ``TrainState.guard``.

    ``loss_ema`` is a debiased-by-count EMA over accepted losses only
    (``ema_count`` accepted steps so far); ``skipped`` counts skipped
    steps over the run; ``consecutive_bad`` the current bad streak.
    """
    loss_ema: jnp.ndarray
    ema_count: jnp.ndarray
    skipped: jnp.ndarray
    consecutive_bad: jnp.ndarray


def init_guard_state() -> GuardState:
    return GuardState(jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
                      jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))


def guard_verdict(policy: GuardPolicy, gstate: GuardState, loss, grad_norm):
    """-> boolean scalar: may this step's update be applied?

    Pure traced arithmetic (no Python branches on traced values — the only
    ``if`` is on the static ``spike_factor``). The spike check compares
    against the *debiased* EMA and only arms once ``spike_warmup`` steps
    have been accepted.
    """
    ok = jnp.isfinite(loss) & jnp.isfinite(grad_norm)
    if policy.spike_factor > 0:
        armed = gstate.ema_count >= policy.spike_warmup
        # debias by the accumulated weight so early EMAs are not biased
        # toward the zero init (standard Adam-style correction)
        beta = jnp.float32(policy.ema_beta)
        weight = 1.0 - beta ** gstate.ema_count.astype(jnp.float32)
        mean = gstate.loss_ema / jnp.maximum(weight, 1e-12)
        calm = loss <= policy.spike_factor * mean
        ok = ok & (calm | ~armed)
    return ok


def guard_step(policy: GuardPolicy, gstate: GuardState, ok, loss):
    """Advance the guard state given this step's verdict.

    Returns ``(new_state, rollback)``. The EMA ingests accepted losses
    only; the bad streak resets on any accepted step. ``rollback`` trips
    when the streak reaches ``max_bad_steps`` (statically never when the
    policy disables it).
    """
    beta = jnp.float32(policy.ema_beta)
    loss = jnp.asarray(loss, jnp.float32)
    ema = jnp.where(ok, beta * gstate.loss_ema + (1.0 - beta) * loss,
                    gstate.loss_ema)
    count = gstate.ema_count + ok.astype(jnp.int32)
    streak = jnp.where(ok, 0, gstate.consecutive_bad + 1)
    skipped = gstate.skipped + (~ok).astype(jnp.int32)
    if policy.max_bad_steps > 0:
        rollback = streak >= policy.max_bad_steps
    else:
        rollback = jnp.zeros((), bool)
    return GuardState(ema, count, skipped, streak), rollback


def guarded_select(ok, new_tree: Any, old_tree: Any) -> Any:
    """Elementwise select: ``new`` where ok, else ``old`` — bitwise.

    ``jnp.where`` selects per element, so a skipped step returns the old
    buffers bit-for-bit (NaN/Inf in the discarded candidate never
    propagates through a select, unlike arithmetic masking).
    """
    return jax.tree_util.tree_map(
        lambda n, o: jnp.where(ok, n, o), new_tree, old_tree)


def inject_grad_faults(plan, step, grads):
    """Corrupt ``grads`` at the plan's nan/inf steps (traced, bitwise-inert).

    ``plan`` is a static :class:`repro.training.faults.FaultPlan` resolved
    outside jit; ``step`` the traced global step counter. At a non-fault
    step the select leaves every leaf bitwise untouched, so a faulted
    build of the train step is exactly the clean build everywhere else.
    Only inexact (float) leaves are corrupted — integer leaves have no NaN.
    """
    if plan is None or not plan.any_grad_faults:
        return grads

    def hit(steps):
        return functools.reduce(
            jnp.logical_or,
            [step == k for k in steps],
            jnp.zeros((), bool))

    bad_nan = hit(plan.grad_fault_steps("nan"))
    bad_inf = hit(plan.grad_fault_steps("inf"))

    def corrupt(g):
        if not jnp.issubdtype(g.dtype, jnp.inexact):
            return g
        g = jnp.where(bad_nan, jnp.asarray(jnp.nan, g.dtype), g)
        return jnp.where(bad_inf, jnp.asarray(jnp.inf, g.dtype), g)

    return jax.tree_util.tree_map(corrupt, grads)
