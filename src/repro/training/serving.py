"""Serving-step factories: batched prefill + single-token decode with a
persistent sharded KV/SSM cache. These are the functions the inference
dry-run cells lower (``prefill_32k`` / ``decode_32k`` / ``long_500k``).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import (forward, init_cache, logits_from_hidden)
from repro.models.sharding import Rules


class ServeState(NamedTuple):
    cache: Any
    index: jnp.ndarray  # current cache fill (next write position)


def make_prefill_step(cfg, max_seq: int, rules: Optional[Rules] = None,
                      mesh=None):
    """prefill(params, tokens[, image_embeds]) -> (ServeState, last_logits).

    The returned cache is sized ``max_seq`` so decode can continue in
    place. ``mesh`` reaches the attention layers for the mesh-aware fused
    flash kernels (feature-detected plumbing, like the trainer's loss).
    """
    rules = rules or Rules(cfg.rule_overrides)

    def prefill_step(params, tokens, image_embeds=None):
        B = tokens.shape[0]
        S = tokens.shape[-1]
        cache = init_cache(cfg, B, max_seq)
        hidden, pre_cache, _ = forward(params, cfg, tokens,
                                       image_embeds=image_embeds,
                                       mode="prefill", cache=cache,
                                       rules=rules, mesh=mesh)

        def merge(full, pre):
            if full.shape == pre.shape:
                return pre.astype(full.dtype)
            return jax.lax.dynamic_update_slice(
                full, pre.astype(full.dtype), (0,) * full.ndim)

        cache = jax.tree_util.tree_map(merge, cache, pre_cache)
        logits = logits_from_hidden(params, cfg, hidden[:, -1:], rules=rules)
        return ServeState(cache, jnp.asarray(S, jnp.int32)), logits

    return prefill_step


def make_decode_step(cfg, rules: Optional[Rules] = None, mesh=None):
    """decode(params, state, tokens) -> (state, logits). tokens (B, 1).

    Single-device decode routes attention over the cache through the
    fused flash kernels (the ``kv_len`` bound skips unfilled cache
    tiles); under a mesh the sequence-sharded cache falls back to the
    GSPMD-partitioned chunked path (see ``layers.decode_attention``).
    """
    rules = rules or Rules(cfg.rule_overrides)

    def decode_step(params, state: ServeState, tokens, image_embeds=None):
        hidden, cache, _ = forward(params, cfg, tokens,
                                   image_embeds=image_embeds, mode="decode",
                                   cache=state.cache, cache_index=state.index,
                                   rules=rules, mesh=mesh)
        logits = logits_from_hidden(params, cfg, hidden, rules=rules)
        return ServeState(cache, state.index + tokens.shape[-1]), logits

    return decode_step


def greedy_generate(cfg, params, prompt, n_steps: int, max_seq: int,
                    rules: Optional[Rules] = None, mesh=None):
    """Greedy generation loop (prefill + jitted decode steps)."""
    prefill = jax.jit(make_prefill_step(cfg, max_seq, rules, mesh=mesh))
    decode = jax.jit(make_decode_step(cfg, rules, mesh=mesh))
    state, logits = prefill(params, prompt)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(n_steps - 1):
        state, logits = decode(params, state, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)
