"""Serving-step factories: batched prefill + single-token decode with a
persistent sharded KV/SSM cache. These are the functions the inference
dry-run cells lower (``prefill_32k`` / ``decode_32k`` / ``long_500k``).
"""
from __future__ import annotations

import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import (forward, init_cache, logits_from_hidden)
from repro.models.sharding import Rules


class ServeState(NamedTuple):
    cache: Any
    index: jnp.ndarray  # current cache fill (next write position)


def make_prefill_step(cfg, max_seq: int, rules: Optional[Rules] = None,
                      mesh=None):
    """prefill(params, tokens[, image_embeds]) -> (ServeState, last_logits).

    The returned cache is sized ``max_seq`` so decode can continue in
    place. ``mesh`` reaches the attention layers for the mesh-aware fused
    flash kernels (feature-detected plumbing, like the trainer's loss).
    """
    rules = rules or Rules(cfg.rule_overrides)

    def prefill_step(params, tokens, image_embeds=None):
        B = tokens.shape[0]
        S = tokens.shape[-1]
        cache = init_cache(cfg, B, max_seq)
        hidden, pre_cache, _ = forward(params, cfg, tokens,
                                       image_embeds=image_embeds,
                                       mode="prefill", cache=cache,
                                       rules=rules, mesh=mesh)

        def merge(full, pre):
            if full.shape == pre.shape:
                return pre.astype(full.dtype)
            return jax.lax.dynamic_update_slice(
                full, pre.astype(full.dtype), (0,) * full.ndim)

        cache = jax.tree_util.tree_map(merge, cache, pre_cache)
        logits = logits_from_hidden(params, cfg, hidden[:, -1:], rules=rules)
        return ServeState(cache, jnp.asarray(S, jnp.int32)), logits

    return prefill_step


def make_decode_step(cfg, rules: Optional[Rules] = None, mesh=None):
    """decode(params, state, tokens) -> (state, logits). tokens (B, 1).

    Single-device decode routes attention over the cache through the
    fused flash kernels (the ``kv_len`` bound skips unfilled cache
    tiles); under a mesh the sequence-sharded cache falls back to the
    GSPMD-partitioned chunked path (see ``layers.decode_attention``).
    """
    rules = rules or Rules(cfg.rule_overrides)

    def decode_step(params, state: ServeState, tokens, image_embeds=None):
        hidden, cache, _ = forward(params, cfg, tokens,
                                   image_embeds=image_embeds, mode="decode",
                                   cache=state.cache, cache_index=state.index,
                                   rules=rules, mesh=mesh)
        logits = logits_from_hidden(params, cfg, hidden, rules=rules)
        return ServeState(cache, state.index + tokens.shape[-1]), logits

    return decode_step


def _quantile(sorted_vals: list, q: float) -> float:
    if not sorted_vals:
        return 0.0
    i = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[i]


def greedy_generate(cfg, params, prompt, n_steps: int, max_seq: int,
                    rules: Optional[Rules] = None, mesh=None, logger=None):
    """Greedy generation loop (prefill + jitted decode steps).

    ``logger``: an optional :class:`repro.obs.MetricsLogger`. Prefill and
    decode latencies then flow through the same metrics plane as training:
    one ``kind="serve"`` record per phase — prefill wall time + prompt
    tokens/s, and the decode latency distribution (mean/p50/p99 per token,
    tokens/s) over the generated steps. Timings block on device results
    (``block_until_ready``), so they measure real step latency, not
    dispatch time; the first decode step includes compile and is also
    reported separately (``compile_ms``).
    """
    prefill = jax.jit(make_prefill_step(cfg, max_seq, rules, mesh=mesh))
    decode = jax.jit(make_decode_step(cfg, rules, mesh=mesh))
    t0 = time.perf_counter()
    state, logits = prefill(params, prompt)
    if logger is not None:
        jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        n_prompt = int(prompt.shape[0]) * int(prompt.shape[-1])
        logger.log("serve", 0, phase="prefill", batch=int(prompt.shape[0]),
                   prompt_tokens=n_prompt, latency_ms=1e3 * dt,
                   tokens_per_s=n_prompt / max(dt, 1e-9))
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    lat: list = []
    for _ in range(n_steps - 1):
        t0 = time.perf_counter()
        state, logits = decode(params, state, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        if logger is not None:
            jax.block_until_ready(tok)
            lat.append(time.perf_counter() - t0)
        out.append(tok)
    if logger is not None and lat:
        # first decode step pays compile; report it apart from the steady
        # distribution so p50/p99 describe serving, not tracing
        steady = sorted(lat[1:]) if len(lat) > 1 else sorted(lat)
        logger.log("serve", 0, phase="decode", batch=int(prompt.shape[0]),
                   decode_steps=len(lat), compile_ms=1e3 * lat[0],
                   mean_ms=1e3 * sum(steady) / len(steady),
                   p50_ms=1e3 * _quantile(steady, 0.50),
                   p99_ms=1e3 * _quantile(steady, 0.99),
                   tokens_per_s=int(prompt.shape[0]) * len(steady)
                   / max(sum(steady), 1e-9))
    return jnp.concatenate(out, axis=1)
