from .trainer import TrainState, init_state, make_eval_step, make_train_step
from .serving import ServeState, greedy_generate, make_decode_step, make_prefill_step
from .resilience import (GuardPolicy, GuardState, guard_step, guard_verdict,
                         guarded_select, init_guard_state, inject_grad_faults)
from .faults import FaultPlan, SimulatedKill, parse_faults, resolve_plan
__all__ = ["TrainState", "init_state", "make_eval_step", "make_train_step",
           "ServeState", "greedy_generate", "make_decode_step", "make_prefill_step",
           "GuardPolicy", "GuardState", "guard_step", "guard_verdict",
           "guarded_select", "init_guard_state", "inject_grad_faults",
           "FaultPlan", "SimulatedKill", "parse_faults", "resolve_plan"]
