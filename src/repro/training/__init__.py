from .trainer import TrainState, init_state, make_eval_step, make_train_step
from .serving import ServeState, greedy_generate, make_decode_step, make_prefill_step
__all__ = ["TrainState", "init_state", "make_eval_step", "make_train_step",
           "ServeState", "greedy_generate", "make_decode_step", "make_prefill_step"]
