"""Super-block assembly: every architecture is a sequence of scanned
segments of homogeneous super-blocks (see config.ModelConfig.segments).

Super-block kinds:
  dense : [self-attn + SwiGLU]
  moe   : [self-attn + MoE]
  ssm   : [mamba2]                      (attention-free; no FFN, as mamba2)
  vlm   : [cross-attn + MLP] + (N-1) x [self-attn + MLP]
  hybrid: [attn + MLP] + 7 x [mamba + (MoE | MLP alternating)]   (jamba 1:7)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .sharding import Rules, shard


# --------------------------------------------------------------- sub-layers

def _sub_spec(cfg: ModelConfig, sub: str) -> dict:
    if sub == "attn":
        spec = (L.mla_spec(cfg) if cfg.attention_kind == "mla"
                else L.attn_spec(cfg))
        return {"norm": L.Spec((cfg.d_model,), ("norm",), "ones"), **spec}
    if sub == "cross":
        return {"norm": L.Spec((cfg.d_model,), ("norm",), "ones"),
                **L.attn_spec(cfg, cross=True)}
    if sub == "mlp":
        return {"norm": L.Spec((cfg.d_model,), ("norm",), "ones"),
                **L.mlp_spec(cfg)}
    if sub == "moe":
        return {"norm": L.Spec((cfg.d_model,), ("norm",), "ones"),
                **L.moe_spec(cfg)}
    if sub == "mamba":
        return {"norm": L.Spec((cfg.d_model,), ("norm",), "ones"),
                **L.mamba_spec(cfg)}
    raise ValueError(sub)


def _apply_sub(sub: str, p: dict, cfg: ModelConfig, x, positions, rules: Rules,
               mode: str, cache, cache_index, image_embeds, mesh=None,
               segment_ids=None):
    """Pre-norm residual sub-layer. Returns (x, new_cache, aux).

    ``mesh`` rides along to the attention layers so the fused flash
    kernels can shard_map over the activation batch/head axes (the same
    feature-detected plumbing the fused LM-head loss uses).
    ``segment_ids`` (B, S) int32 — packed-document ids, consumed by the
    *self*-attention subs only (cross-attention keys are not packed and
    mamba's sequence mixing has no segment mask — packed batches are an
    attention-family format).
    """
    h = L.rmsnorm(x, p["norm"], cfg.rms_eps)
    aux = jnp.zeros((), jnp.float32)
    if sub == "attn":
        if cfg.attention_kind == "mla":
            y, cache = L.apply_mla_attention(p, cfg, h, positions, rules,
                                             mode, cache, cache_index,
                                             mesh=mesh,
                                             segment_ids=segment_ids)
        else:
            y, cache = L.apply_attention(p, cfg, h, positions, rules,
                                         mode, cache, cache_index,
                                         mesh=mesh,
                                         segment_ids=segment_ids)
    elif sub == "cross":
        y, _ = L.apply_attention(p, cfg, h, positions, rules, mode="train",
                                 kv_source=image_embeds, causal=False,
                                 mesh=mesh)
    elif sub == "mlp":
        y = L.apply_mlp(p, cfg, h, rules)
    elif sub == "moe":
        y, aux = L.apply_moe(p, cfg, h, rules)
    elif sub == "mamba":
        y, cache = L.apply_mamba(p, cfg, h, rules, mode, cache)
    else:
        raise ValueError(sub)
    return x + y.astype(x.dtype), cache, aux


# ------------------------------------------------------------- super-blocks

def superblock_layout(cfg: ModelConfig, kind: str) -> tuple:
    """Ordered (name, sub_kind) pairs of one super-block."""
    if kind == "dense":
        return (("attn", "attn"), ("ffn", "mlp"))
    if kind == "moe":
        out = [("attn", "attn"), ("ffn", "moe")]
        return tuple(out)
    if kind == "ssm":
        return (("mamba", "mamba"),)
    if kind == "vlm":
        out = [("cross", "cross"), ("cross_ffn", "mlp")]
        for i in range(1, cfg.cross_attn_every):
            out += [(f"attn{i}", "attn"), (f"ffn{i}", "mlp")]
        return tuple(out)
    if kind == "hybrid":
        out = [("attn", "attn"), ("ffn0", "mlp")]
        for i in range(1, cfg.hybrid_period):
            out.append((f"mamba{i}", "mamba"))
            out.append((f"ffn{i}", "moe" if i % 2 == 1 else "mlp"))
        return tuple(out)
    raise ValueError(kind)


def superblock_spec(cfg: ModelConfig, kind: str) -> dict:
    return {name: _sub_spec(cfg, sub) for name, sub in superblock_layout(cfg, kind)}


def _needs_cache(sub: str) -> bool:
    return sub in ("attn", "mamba")


def superblock_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype) -> dict:
    """Zero cache for one super-block (decode/prefill)."""
    out = {}
    for name, sub in superblock_layout(cfg, kind):
        if sub == "attn":
            if cfg.attention_kind == "mla":
                out[name] = {
                    "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
                    "krope": jnp.zeros((batch, max_seq, 1, cfg.qk_rope_dim), dtype),
                }
            else:
                kshape = (batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
                out[name] = {"k": jnp.zeros(kshape, dtype),
                             "v": jnp.zeros(kshape, dtype)}
        elif sub == "mamba":
            out[name] = {
                "conv": jnp.zeros((batch, cfg.ssm_dconv - 1, cfg.conv_dim), dtype),
                "ssm": jnp.zeros((batch, cfg.ssm_nheads, cfg.ssm_state,
                                  cfg.ssm_headdim), jnp.float32),
            }
    return out


def cache_axes(cfg: ModelConfig, kind: str) -> dict:
    out = {}
    for name, sub in superblock_layout(cfg, kind):
        if sub == "attn":
            if cfg.attention_kind == "mla":
                out[name] = {"ckv": (None, "cache_batch", "cache_seq", "cache_kv"),
                             "krope": (None, "cache_batch", "cache_seq", None, None)}
            else:
                ax = (None, "cache_batch", "cache_seq", None, "cache_kv")
                out[name] = {"k": ax, "v": ax}
        elif sub == "mamba":
            out[name] = {"conv": (None, "cache_batch", None, "ssm_inner"),
                         "ssm": (None, "cache_batch", None, None, None)}
    return out


def apply_superblock(kind: str, cfg: ModelConfig, params: dict, x, positions,
                     rules: Rules, mode: str, cache: Optional[dict],
                     cache_index, image_embeds, mesh=None, segment_ids=None):
    new_cache = dict(cache) if cache is not None else None
    aux_total = jnp.zeros((), jnp.float32)
    for name, sub in superblock_layout(cfg, kind):
        sub_cache = cache.get(name) if (cache is not None and _needs_cache(sub)) else None
        x, sub_cache, aux = _apply_sub(sub, params[name], cfg, x, positions,
                                       rules, mode, sub_cache, cache_index,
                                       image_embeds, mesh=mesh,
                                       segment_ids=segment_ids)
        if new_cache is not None and _needs_cache(sub) and sub_cache is not None:
            new_cache[name] = sub_cache
        aux_total = aux_total + aux
    # scan-carry sharding: lets the dry-run store saved residuals TP-sharded
    x = shard(x, ("act_batch", "act_seq", "act_residual"), rules)
    return x, new_cache, aux_total


# ------------------------------------------------------------ scanned stack

def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def apply_segment(kind: str, n_blocks: int, cfg: ModelConfig, stacked: dict,
                  x, positions, rules: Rules, mode: str, cache, cache_index,
                  image_embeds, mesh=None, segment_ids=None):
    """Scan ``n_blocks`` super-blocks with stacked params (+ stacked cache).

    ``segment_ids`` (packed-document masking) is closed over, not scanned:
    it is the same (B, S) operand for every block. (Not to be confused
    with the layer-group "segments" this function scans over.)
    """

    def block(x, inputs):
        p, c = inputs
        x, c, aux = apply_superblock(kind, cfg, p, x, positions, rules, mode,
                                     c, cache_index, image_embeds, mesh=mesh,
                                     segment_ids=segment_ids)
        return x, (c, aux)

    policy = _remat_policy(cfg)
    if policy is not None:
        block = jax.checkpoint(block, policy=policy, prevent_cse=False)

    if cache is None:
        xs = (stacked, None)
        # scan needs a pytree of equal-length leading axes; replace None cache
        # with per-block empty dicts
        xs = (stacked, jnp.zeros((n_blocks, 0)))

        def block_nc(x, inputs):
            p, _ = inputs
            x, _, aux = apply_superblock(kind, cfg, p, x, positions, rules,
                                         mode, None, cache_index,
                                         image_embeds, mesh=mesh,
                                         segment_ids=segment_ids)
            return x, aux

        body = jax.checkpoint(block_nc, policy=policy, prevent_cse=False) \
            if policy is not None else block_nc
        x, auxs = jax.lax.scan(body, x, xs)
        return x, None, jnp.sum(auxs)

    x, (new_cache, auxs) = jax.lax.scan(block, x, (stacked, cache))
    return x, new_cache, jnp.sum(auxs)
