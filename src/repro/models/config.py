"""Model configuration: one dataclass covering all assigned families.

Layer layout is expressed as *segments* of homogeneous super-blocks so every
architecture lowers through ``jax.lax.scan`` (compile-time O(1) in depth):

  * dense/moe/vlm/audio: one segment, super-block = 1 layer (optionally with
    cross-attention or MoE sub-modules at fixed positions inside the block).
  * deepseek-v3: segment of ``first_dense_layers`` dense + segment of MoE.
  * jamba hybrid: super-block of 8 (1 attention + 7 mamba, MoE every 2nd).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio

    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1024
    vocab_pad_multiple: int = 128
    qkv_bias: bool = False

    attention_kind: str = "gqa"  # gqa | mla
    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 0        # 0 -> head_dim

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0          # 0 -> d_ff
    moe_every: int = 1         # layer i is MoE iff i % moe_every == moe_every-1
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048  # tokens per group in group-local MoE dispatch

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_dconv: int = 4
    ssm_chunk: int = 256

    # hybrid (jamba): super-block of `hybrid_period`, attention at position 0
    hybrid_period: int = 0

    # vlm: cross-attention replaces self-attention every N layers (position 0
    # of each super-block of N); image tokens arrive pre-embedded (stub).
    cross_attn_every: int = 0
    n_image_tokens: int = 0

    # audio: input tokens (B, n_codebooks, S); one output head per codebook.
    n_codebooks: int = 0

    rope_theta: float = 10000.0
    rms_eps: float = 1e-5
    # Weight tying: the param tree has no lm_head; tok_embed.w ((V, D), or
    # (C, V, D) audio) doubles as the head read transposed. Optimizers that
    # special-case the head must use LabelRules.tied() (see models.model).
    tie_embeddings: bool = False
    pos_embed: str = "rope"    # rope | learned  (gpt2-style)
    max_position: int = 4096   # learned-pos table size
    mlp_kind: str = "swiglu"   # swiglu | gelu   (gpt2-style 2-matrix MLP)

    dtype: str = "bfloat16"
    remat: str = "full"        # none | dots | full
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    loss_chunk: int = 2048     # vocab-logit chunking along tokens

    # sharding rule overrides, e.g. (("act_seq", ("data",)), ("act_batch", ()))
    rule_overrides: Tuple = ()

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            self.head_dim = self.d_model // self.n_heads
        if self.v_head_dim == 0:
            self.v_head_dim = self.head_dim
        if self.moe_d_ff == 0:
            self.moe_d_ff = self.d_ff

    # ---- derived ----
    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.ssm_ngroups * self.ssm_state

    @property
    def segments(self) -> Tuple[Tuple[str, int], ...]:
        """(super_block_kind, n_superblocks) pairs, scanned in order."""
        if self.family == "hybrid":
            assert self.n_layers % self.hybrid_period == 0
            return (("hybrid", self.n_layers // self.hybrid_period),)
        if self.family == "ssm":
            return (("ssm", self.n_layers),)
        if self.family == "vlm":
            assert self.n_layers % self.cross_attn_every == 0
            return (("vlm", self.n_layers // self.cross_attn_every),)
        if self.family == "moe" and self.first_dense_layers:
            return (("dense", self.first_dense_layers),
                    ("moe", self.n_layers - self.first_dense_layers))
        if self.family == "moe":
            return (("moe", self.n_layers),)
        return (("dense", self.n_layers),)

    def num_params(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs)."""
        from . import model  # lazy, avoids cycle
        return model.count_params(model.param_shapes(self))

    def active_params(self) -> int:
        from . import model
        return model.count_params(model.param_shapes(self), cfg=self, active_only=True)
