"""Logical-axis sharding (MaxText-style rules table).

Every parameter and annotated activation carries a tuple of logical axis
names; ``RULES`` maps each name to zero or more mesh axes. Mapping is
mesh-aware: rules referencing axes absent from the current mesh are dropped,
and a dim is only sharded if its size is divisible by the product of the
mapped mesh axis sizes (otherwise it is left replicated) — this is what makes
the same model lower on (data, model), (pod, data, model) and single-device
CPU meshes without per-mesh configs.

Tied LM heads: a ``tie_embeddings`` model stores the head as the embedding,
logical axes ("vocab", "embed"), where the untied head is ("embed", "vocab").
Under the default rules both map to the same physical pair — vocab -> TP
("model"), embed -> FSDP ("data") — just with the dims swapped, so the fused
xent/optimizer shard plans swap their psum/gather axes accordingly (the
vocab-axis psum of the loss reduces dim 0 of the tied matrix, and its FSDP
embed gather is dim 1; see ``repro.kernels.dispatch``).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical -> mesh-axis rules. Weights: 2-D sharded (FSDP over "data"
# x TP over "model"). Activations: batch over (pod, data), model-parallel
# features over "model".
DEFAULT_RULES: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
    # --- weight axes ---
    ("embed", ("data",)),          # contracting/model dim of weights -> FSDP
    ("mlp", ("model",)),           # ffn hidden -> TP
    ("heads", ("model",)),         # flattened q heads*head_dim -> TP
    ("kv", ("model",)),            # flattened kv heads*head_dim -> TP
    ("vocab", ("model",)),         # vocab dim of embed/head -> TP
    ("experts", ("model",)),       # expert dim -> EP over model axis
    ("expert_mlp", ()),            # per-expert ffn dim (already EP-sharded)
    ("lora", ()),                  # MLA low-rank dims: replicated
    ("conv", ()),
    ("ssm_inner", ("model",)),     # mamba d_inner -> TP
    ("ssm_state", ()),
    ("norm", ()),
    # --- activation axes ---
    ("act_batch", ("pod", "data")),
    ("act_seq", ()),
    ("act_embed", ()),
    # scan-carry residual between layers; mapping this to ("model",) stores
    # the per-layer saved activations TP-sharded (sequence-parallel style)
    ("act_residual", ()),
    ("act_mlp", ("model",)),
    ("act_heads", ("model",)),
    ("act_kv", ("model",)),
    ("act_vocab", ("model",)),
    ("act_experts", ("model",)),
    # expert capacity rows shard over data: EP = experts x model, tokens x
    # data — without this every data-replica computes every expert's rows
    ("act_expert_cap", ("data",)),
    ("act_moe_group", ("pod", "data")),
    ("act_ssm_inner", ("model",)),
    ("act_ssm_heads", ("model",)),
    ("act_ssm_state", ()),
    # --- cache axes ---
    # KV caches shard over (batch x sequence): attention over the sharded T
    # becomes local partial-softmax + small lse all-reduces (no cache gather),
    # and head_dim stays whole so no score-sized partial-sum all-reduces.
    ("cache_batch", ("data",)),
    ("cache_seq", ("model",)),
    ("cache_kv", ()),
)


class Rules:
    def __init__(self, overrides: Sequence[Tuple[str, Tuple[str, ...]]] = ()):
        self._map = dict(DEFAULT_RULES)
        for k, v in overrides:
            self._map[k] = tuple(v) if v is not None else ()

    def spec(self, logical_axes: Sequence[Optional[str]], mesh: Mesh,
             shape: Optional[Sequence[int]] = None) -> P:
        """Build a PartitionSpec for ``logical_axes`` on ``mesh``.

        Divisibility-guarded: a dim whose size does not divide by the mapped
        mesh-axis product is replicated instead (prevents lowering failures
        for e.g. 8 kv heads on a 16-way model axis).
        """
        parts = []
        used = set()
        for i, ax in enumerate(logical_axes):
            if ax is None:
                parts.append(None)
                continue
            mesh_axes = tuple(a for a in self._map.get(ax, ())
                              if a in mesh.axis_names and a not in used)
            if not mesh_axes:
                parts.append(None)
                continue
            if shape is not None:
                k = int(np.prod([mesh.shape[a] for a in mesh_axes]))
                if shape[i] % k != 0:
                    # try a prefix of the mesh axes that divides
                    while mesh_axes:
                        k = int(np.prod([mesh.shape[a] for a in mesh_axes]))
                        if shape[i] % k == 0:
                            break
                        mesh_axes = mesh_axes[:-1]
                    if not mesh_axes:
                        parts.append(None)
                        continue
                    if shape[i] % int(np.prod([mesh.shape[a] for a in mesh_axes])) != 0:
                        parts.append(None)
                        continue
            used.update(mesh_axes)
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        return P(*parts)

    def sharding(self, logical_axes, mesh, shape=None) -> NamedSharding:
        return NamedSharding(mesh, self.spec(logical_axes, mesh, shape))


def spec_mesh_axes(spec: P, ndim: int) -> Tuple[Tuple[str, ...], ...]:
    """Canonicalize a PartitionSpec to per-dim tuples of mesh axis names.

    Pads short specs with replicated dims, normalizes ``None`` -> ``()`` and
    single names -> 1-tuples. This is the form the fused-update dispatch
    consumes to decide which mesh axes a col/row norm must ``psum`` over
    (the axes sharding the reduce dim of the matrix).
    """
    entries = tuple(spec) + (None,) * (ndim - len(tuple(spec)))
    out = []
    for e in entries[:ndim]:
        if e is None:
            out.append(())
        elif isinstance(e, (tuple, list)):
            out.append(tuple(e))
        else:
            out.append((e,))
    return tuple(out)


def shard(x, logical_axes, rules: Rules, mesh: Optional[Mesh] = None):
    """Annotate an activation with its logical sharding (no-op off-mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty or len(mesh.devices.flat) == 1:
        return x
    spec = rules.spec(logical_axes, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return m if m is not None and not m.empty else None
    except Exception:
        return None


def tree_shardings(logical_tree, mesh: Mesh, rules: Rules, shape_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        (a is None or isinstance(a, str)) for a in x)
    if shape_tree is None:
        return jax.tree_util.tree_map(
            lambda ax: rules.sharding(ax, mesh), logical_tree, is_leaf=is_axes)
    return jax.tree_util.tree_map(
        lambda ax, s: rules.sharding(ax, mesh, tuple(s.shape) if hasattr(s, "shape") else tuple(s)),
        logical_tree, shape_tree, is_leaf=is_axes)
