"""Model building blocks, purely functional.

Every module exposes
  * ``<mod>_spec(cfg) -> {name: Spec}``  — single source of truth for shapes,
    logical sharding axes and initializers;
  * ``apply_<mod>(params, cfg, ...)``    — forward.

Attention uses a *triangular blockwise* (flash-style) causal algorithm: a
``lax.scan`` over the lower-triangle (q-block, kv-block) tile pairs with an
online-softmax carry, so peak memory is O(tile) and compiled FLOPs are
~S^2/2 rather than S^2. TPU adaptation: tiles are MXU-aligned multiples of
128 and the softmax statistics stay in f32 VREGs.
"""
from __future__ import annotations

import functools
import math
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.attention.mask import MaskSpec, mask_array, mask_spec

from .config import ModelConfig
from .sharding import Rules, shard


class Spec(NamedTuple):
    shape: tuple
    axes: tuple           # logical axis names (len == ndim)
    init: str = "normal"  # normal | zeros | ones | alog | dtbias | small


def init_param(key, spec: Spec, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "alog":  # mamba A in [1, 16): store log
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dtbias":  # inverse softplus of dt ~ U[1e-3, 1e-1]
        dt = jnp.exp(jax.random.uniform(key, spec.shape, jnp.float32,
                                        math.log(1e-3), math.log(1e-1)))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    scale = 0.006 if spec.init == "small" else 0.02
    return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dtype)


def init_from_spec(key, spec_tree: dict, dtype) -> dict:
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [init_param(k, s, dtype) for k, s in zip(keys, leaves)])


def axes_from_spec(spec_tree: dict) -> dict:
    return jax.tree_util.tree_map(
        lambda s: s.axes, spec_tree, is_leaf=lambda x: isinstance(x, Spec))


def shapes_from_spec(spec_tree: dict) -> dict:
    return jax.tree_util.tree_map(
        lambda s: tuple(s.shape), spec_tree, is_leaf=lambda x: isinstance(x, Spec))


# ---------------------------------------------------------------- norms/rope

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def rope_tables(positions: jnp.ndarray, dim: int, theta: float):
    """cos/sin tables for ``positions`` (any shape), last dim ``dim // 2``."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, n_heads, dim); cos/sin (..., S, dim/2)."""
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


# ------------------------------------------------------- blockwise attention
#
# Flash-style blockwise attention with a custom VJP: the backward pass
# recomputes score tiles from (q, k, v, out, lse) instead of saving O(S^2)
# intermediates through the scan's autodiff (which would otherwise stack
# per-tile scores for every pair — the dominant HBM term at 4k+ contexts).
# All inputs are full-head (B, S, H, hd): GQA repeats kv before the call so
# the head axis shards cleanly over the TP mesh axis. This scan is the
# **bitwise jnp reference** for the fused Pallas kernels behind
# ``repro.kernels.dispatch.flash_attention`` (``REPRO_FUSED=off`` or
# uncovered shapes route back here); the public wrappers below
# (``causal_blockwise_attention`` / ``cross_blockwise_attention`` /
# ``decode_attention``) own that routing.

def largest_divisor(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is <= ``target`` (>= 1; O(sqrt n)).

    The shared divisor search behind every blockwise fallback
    (``_pick_block`` here, ``model._pick_chunk`` for the loss scan):
    computed directly over the divisor pairs instead of decrementing from
    ``target``, which silently walked prime sizes down to 1.
    """
    target = min(target, n)
    best, d = 1, 1
    while d * d <= n:
        if n % d == 0:
            for c in (d, n // d):
                if best < c <= target:
                    best = c
        d += 1
    return best


def _pick_block(S: int, T: int, block: int) -> int:
    """Largest common divisor block of (S, T) that is <= ``block`` — and
    *audibly*: a prime or awkward length used to silently degrade to
    block=1 (the same failure mode as the pre-PR-3 ``chunk -= 1``),
    turning the tile scan into a per-position loop. Warns whenever the
    usable block falls below half the requested size.
    """
    target = max(min(block, S, T), 1)
    best = largest_divisor(math.gcd(S, T), target)
    if best * 2 < target:
        warnings.warn(
            f"blockwise attention: (S={S}, T={T}) share no divisor in "
            f"({target // 2}, {target}]; the tile shrinks to {best} "
            f"({(S // best) * (T // best)} candidate tile pairs). Pick "
            f"lengths with a common divisor near block={target} to keep "
            f"the scan short.", stacklevel=3)
    return best


def _tile_pairs(nq: int, nk: int, causal: bool, block: int = 1,
                offset: int = 0) -> np.ndarray:
    """(q tile, kv tile) index pairs; causal drops fully-masked pairs.

    Causal is *rectangular*: with ``offset = T - S >= 0`` query ``i``
    attends keys ``j <= offset + i`` (a cached-prefill continuation whose
    query block sits at the end of the key range; ``offset = 0`` is
    ordinary causal, where this reduces to the lower triangle). A pair
    survives iff its last query position reaches its first key position.
    """
    if causal:
        return np.array(
            [(qi, ki) for qi in range(nq) for ki in range(nk)
             if ki * block <= offset + (qi + 1) * block - 1],
            dtype=np.int32)
    return np.array([(qi, ki) for qi in range(nq) for ki in range(nk)],
                    dtype=np.int32)


_FLASH_RULES = Rules()


def _shard_flash(x, axes):
    """Head-shard the f32 flash-attention carries (they would otherwise sit
    replicated over the TP axis: 1-2 GB per layer for 128-head models)."""
    return shard(x, axes, _FLASH_RULES)


_NEG = -1e30  # finite -inf stand-in, same value as the fused kernels':
#               masked score entries underflow exp() to exactly +0.0, and a
#               row with no valid position keeps a NaN-free running max
#               (with -inf masking a fully-masked first tile made
#               ``exp(m_old - m_new)`` = exp(-inf - -inf) = NaN — reachable
#               once segment masking can blank a tile below the causal
#               diagonal)


def _tile_valid(spec: MaskSpec, qs, ks, block: int, q_seg, kv_seg):
    """Validity mask for one (q, kv) tile pair of the scan.

    Returns None when the spec masks nothing here (the non-causal
    no-segment path stays mask-free), a (block, block) bool for pure
    causal, or (B, 1, block, block) once segment ids participate —
    broadcastable against the (B, H, block, block) score tile either way.
    """
    valid = None
    if spec.causal:
        qpos = spec.offset + qs + jnp.arange(block)
        kpos = ks + jnp.arange(block)
        valid = qpos[:, None] >= kpos[None, :]
    if spec.has_segments:
        qsegb = jax.lax.dynamic_slice_in_dim(q_seg, qs, block, 1)
        ksegb = jax.lax.dynamic_slice_in_dim(kv_seg, ks, block, 1)
        seg = (qsegb[:, :, None] == ksegb[:, None, :])[:, None]
        valid = seg if valid is None else valid & seg
    return valid


def _flash_forward(q, k, v, q_seg, kv_seg, block: int, scale: float,
                   spec: MaskSpec):
    B, S, H, hd = q.shape
    T = k.shape[1]
    hdv = v.shape[-1]
    offset = spec.offset
    block = _pick_block(S, T, block)
    pairs = _tile_pairs(S // block, T // block, spec.causal, block, offset)

    acc0 = _shard_flash(jnp.zeros((B, S, H, hdv), jnp.float32),
                        ("act_batch", None, "act_heads", None))
    m0 = _shard_flash(jnp.full((B, S, H), -jnp.inf, jnp.float32),
                      ("act_batch", None, "act_heads"))
    l0 = _shard_flash(jnp.zeros((B, S, H), jnp.float32),
                      ("act_batch", None, "act_heads"))

    def body(carry, pair):
        acc, m, l = carry
        qs, ks = pair[0] * block, pair[1] * block
        qb = jax.lax.dynamic_slice_in_dim(q, qs, block, 1)
        kb = jax.lax.dynamic_slice_in_dim(k, ks, block, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, ks, block, 1)
        s = jnp.einsum("bqhd,bshd->bhqs", qb, kb).astype(jnp.float32) * scale
        valid = _tile_valid(spec, qs, ks, block, q_seg, kv_seg)
        if valid is not None:
            s = jnp.where(valid, s, _NEG)
        accb = jnp.swapaxes(jax.lax.dynamic_slice_in_dim(acc, qs, block, 1), 1, 2)
        mb = jnp.swapaxes(jax.lax.dynamic_slice_in_dim(m, qs, block, 1), 1, 2)
        lb = jnp.swapaxes(jax.lax.dynamic_slice_in_dim(l, qs, block, 1), 1, 2)
        m_new = jnp.maximum(mb, jnp.max(s, axis=-1))
        alpha = jnp.exp(mb - m_new)
        # explicit mask on the exp (bitwise = the old -inf masking where
        # any position is valid: masked entries underflow to +0.0 either
        # way, and the running max only ever sees real scores)
        if valid is not None:
            p = jnp.where(valid, jnp.exp(s - m_new[..., None]), 0.0)
        else:
            p = jnp.exp(s - m_new[..., None])
        lb = lb * alpha + jnp.sum(p, axis=-1)
        accb = accb * alpha[..., None] + jnp.einsum(
            "bhqs,bshd->bhqd", p.astype(v.dtype),
            vb).astype(jnp.float32)
        acc = jax.lax.dynamic_update_slice_in_dim(
            acc, jnp.swapaxes(accb, 1, 2), qs, 1)
        m = jax.lax.dynamic_update_slice_in_dim(
            m, jnp.swapaxes(m_new, 1, 2), qs, 1)
        l = jax.lax.dynamic_update_slice_in_dim(
            l, jnp.swapaxes(lb, 1, 2), qs, 1)
        return (acc, m, l), None

    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.asarray(pairs))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l[..., None]).astype(q.dtype)
    lse = m + jnp.log(l)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def masked_flash_attention(q, k, v, q_seg, kv_seg, block: int, scale: float,
                           spec: MaskSpec):
    """Memory-O(S*d) blockwise attention under a :class:`MaskSpec`.

    q,k,v (B,S,H,hd) / (B,T,H,hd); ``q_seg``/``kv_seg`` are (B, S)/(B, T)
    int32 segment ids, read only when ``spec.has_segments`` (pass
    zero-size (B, 0) arrays otherwise — :func:`flash_attention` does).
    The spec is a nondiff hashable; segment ids are traced operands whose
    cotangents are float0. This jnp scan is the bitwise reference path for
    the fused kernels (see the section comment above).
    """
    return _flash_forward(q, k, v, q_seg, kv_seg, block, scale, spec)[0]


def flash_attention(q, k, v, block: int, scale: float, causal: bool):
    """Blockwise attention with only the causal clause (pre-packing API).

    ``causal`` masks rectangularly when T > S (query ``i`` sees keys
    ``j <= (T - S) + i`` — a cached-prefill continuation); T == S is
    ordinary causal. Thin wrapper: builds the equivalent
    :class:`MaskSpec` and runs :func:`masked_flash_attention` with no
    segment operands — bitwise the pre-MaskSpec scan.
    """
    spec = mask_spec(q.shape[1], k.shape[1], causal=causal)
    z = jnp.zeros((q.shape[0], 0), jnp.int32)
    return masked_flash_attention(q, k, v, z, z, block, scale, spec)


def _flash_fwd_rule(q, k, v, q_seg, kv_seg, block, scale, spec):
    out, lse = _flash_forward(q, k, v, q_seg, kv_seg, block, scale, spec)
    return out, (q, k, v, q_seg, kv_seg, out, lse)


def _flash_bwd_rule(block, scale, spec, res, dout):
    q, k, v, q_seg, kv_seg, out, lse = res
    B, S, H, hd = q.shape
    T = k.shape[1]
    offset = spec.offset
    block_ = _pick_block(S, T, block)
    pairs = _tile_pairs(S // block_, T // block_, spec.causal, block_,
                        offset)
    # D_i = sum_d dout_i * out_i  (B,S,H)
    Dsum = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1)

    dq0 = _shard_flash(jnp.zeros(q.shape, jnp.float32),
                       ("act_batch", None, "act_heads", None))
    dk0 = _shard_flash(jnp.zeros(k.shape, jnp.float32),
                       ("act_batch", None, "act_heads", None))
    dv0 = _shard_flash(jnp.zeros(v.shape, jnp.float32),
                       ("act_batch", None, "act_heads", None))

    def body(carry, pair):
        dq, dk, dv = carry
        qs, ks = pair[0] * block_, pair[1] * block_
        qb = jax.lax.dynamic_slice_in_dim(q, qs, block_, 1)
        kb = jax.lax.dynamic_slice_in_dim(k, ks, block_, 1)
        vb = jax.lax.dynamic_slice_in_dim(v, ks, block_, 1)
        dob = jax.lax.dynamic_slice_in_dim(dout, qs, block_, 1)
        lseb = jnp.swapaxes(
            jax.lax.dynamic_slice_in_dim(lse, qs, block_, 1), 1, 2)
        Db = jnp.swapaxes(
            jax.lax.dynamic_slice_in_dim(Dsum, qs, block_, 1), 1, 2)
        s = jnp.einsum("bqhd,bshd->bhqs", qb, kb).astype(jnp.float32) * scale
        valid = _tile_valid(spec, qs, ks, block_, q_seg, kv_seg)
        if valid is not None:
            # explicit zero (not exp of a masked score): a fully-masked
            # row's lse is ~_NEG and exp(_NEG - lse) would be exp(~0) = 1
            p = jnp.where(valid, jnp.exp(s - lseb[..., None]), 0.0)
        else:
            p = jnp.exp(s - lseb[..., None])                 # (B,H,q,s)
        pb = p.astype(v.dtype)
        dvb = jnp.einsum("bhqs,bqhd->bshd", pb, dob)
        dp = jnp.einsum("bqhd,bshd->bhqs", dob, vb).astype(jnp.float32)
        ds = p * (dp - Db[..., None]) * scale
        dsb = ds.astype(q.dtype)
        dqb = jnp.einsum("bhqs,bshd->bqhd", dsb, kb)
        dkb = jnp.einsum("bhqs,bqhd->bshd", dsb, qb)
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, qs, block_, 1)
            + dqb.astype(jnp.float32), qs, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, ks, block_, 1)
            + dkb.astype(jnp.float32), ks, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, ks, block_, 1)
            + dvb.astype(jnp.float32), ks, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), jnp.asarray(pairs))
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            _int_cotangent(q_seg), _int_cotangent(kv_seg))


def _int_cotangent(x):
    """float0 cotangent for an integer operand (segment ids)."""
    return np.zeros(x.shape, jax.dtypes.float0)


masked_flash_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _route_attention(q, k, v, scale: float, *, causal: bool, kv_len=None,
                     segments=None, rules: Optional[Rules] = None, mesh=None,
                     kv_axes=("act_batch", None, "act_heads", None)):
    """Fused-kernel route for one attention call (None -> caller's jnp path).

    Mirrors ``model.lm_loss``'s xent routing: resolve REPRO_FUSED once,
    derive the q/kv NamedShardings from the logical rules when a mesh is
    given (``kv_axes`` lets the decode path describe its cache layout),
    and only call the dispatch entry point when it will actually run the
    kernels — the callers keep their own scan/chunked reference paths.
    """
    from repro.kernels import dispatch as _kd  # lazy: optional kernel layer
    q_sh = kv_sh = None
    if mesh is not None and rules is not None:
        q_sh = rules.sharding(("act_batch", None, "act_heads", None), mesh,
                              q.shape)
        kv_sh = rules.sharding(kv_axes, mesh, k.shape)
    mode = _kd.resolve_mode()
    route, _ = _kd.attn_route(q.shape, k.shape, causal, mode, q_sh, kv_sh)
    if route != "kernel" or v.shape[:3] != k.shape[:3]:
        return None
    return _kd.flash_attention(q, k, v, scale=scale, causal=causal,
                               kv_len=kv_len, segments=segments,
                               q_sharding=q_sh, kv_sharding=kv_sh, mode=mode)


def causal_blockwise_attention(q, k, v, block: int, scale: float, *,
                               rules: Optional[Rules] = None, mesh=None,
                               segments=None) -> jnp.ndarray:
    """Causal flash attention; kv may have fewer heads (GQA).

    Fused route (default where covered): the Pallas kernels behind
    ``dispatch.flash_attention`` index the kv block by ``q_head // group``
    natively — the H/K repeat is never materialized, and under ``mesh``
    the kernels shard_map over the activation batch/head axes. Reference
    route (``REPRO_FUSED=off`` / uncovered): repeat kv to full heads (so
    the head axis TP-shards cleanly) and run the jnp scan — the bitwise
    pre-kernel path. ``segments`` — a ((B, S), (B, T)) int32 pair —
    additionally forbids attention across packed-document boundaries.
    """
    return _blockwise_attention(q, k, v, block, scale, causal=True,
                                rules=rules, mesh=mesh, segments=segments)


def cross_blockwise_attention(q, k, v, block: int, scale: float, *,
                              rules: Optional[Rules] = None, mesh=None,
                              segments=None) -> jnp.ndarray:
    """Non-causal flash attention (cross-attention over image tokens).

    Routed like :func:`causal_blockwise_attention` (kernels where
    covered, repeated-kv jnp scan otherwise).
    """
    return _blockwise_attention(q, k, v, block, scale, causal=False,
                                rules=rules, mesh=mesh, segments=segments)


def _blockwise_attention(q, k, v, block, scale, *, causal, rules, mesh,
                         segments):
    out = _route_attention(q, k, v, scale, causal=causal, segments=segments,
                           rules=rules, mesh=mesh)
    if out is not None:
        return out
    H, K = q.shape[2], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
        if rules is not None:
            k = shard(k, ("act_batch", None, "act_heads", None), rules)
            v = shard(v, ("act_batch", None, "act_heads", None), rules)
    if segments is not None:
        spec = mask_spec(q.shape[1], k.shape[1], causal=causal,
                         segments=segments)
        return masked_flash_attention(q, k, v, segments[0], segments[1],
                                      block, scale, spec)
    return flash_attention(q, k, v, block, scale, causal)


def decode_attention(q, k, v, q_block: int, scale: float, kv_len=None, *,
                     rules: Optional[Rules] = None, mesh=None,
                     kv_axes=("cache_batch", "cache_seq", None,
                              "cache_kv")) -> jnp.ndarray:
    """Attention over a T-length cache (decode / single-query cross-attn).

    Kernel route: the flash kernels run the rectangular (S=1..block, T)
    problem with the traced ``kv_len`` bound folded into the tile masks —
    tiles past the cache fill skip their compute entirely. The
    sequence-sharded decode cache (``cache_seq -> "model"``) is not
    expressible as a batch/head shard_map plan, so under such a mesh this
    falls back to :func:`chunked_q_attention`, which GSPMD partitions
    over the sharded T with small lse all-reduces.
    """
    out = _route_attention(q, k, v, scale, causal=False, kv_len=kv_len,
                           rules=rules, mesh=mesh, kv_axes=kv_axes)
    if out is not None:
        return out
    return chunked_q_attention(q, k, v, q_block, scale, kv_len=kv_len)


def chunked_q_attention(q, k, v, q_block: int, scale: float,
                        kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Non-causal attention chunked over q (cross-attn / decode-over-cache).

    q (B,S,H,hd); k,v (B,T,K,hd). ``kv_len`` masks positions >= kv_len —
    densified through the shared :func:`~repro.kernels.attention.mask
    .mask_array` so decode consumes the same MaskSpec clause as the
    kernels (decode serves one document per row, so the segment clause is
    never live here — ``mask_spec`` rejects segments + kv_len outright).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    q_block = min(q_block, S)
    assert S % q_block == 0
    nq = S // q_block
    qg = q.reshape(B, nq, q_block, K, G, hd)

    spec = mask_spec(S, T, causal=False, kv_len=kv_len)
    kmask = None
    if spec.has_kv_len:
        # (T,) row of the dense (1, S, T) mask: non-causal + kv_len is
        # query-invariant, bitwise what `arange(T) < kv_len` produced
        kmask = mask_array(spec, 1, T, kv_len=kv_len)[0, 0]

    def one(qb):  # (B,b,K,G,hd)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, k).astype(jnp.float32) * scale
        if kmask is not None:
            s = jnp.where(kmask[None, None, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
        return o

    out = jax.lax.map(lambda i: one(qg[:, i]), jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, H, v.shape[-1])
    return out.astype(q.dtype)


# ------------------------------------------------------------- GQA attention

def attn_spec(cfg: ModelConfig, cross: bool = False) -> dict:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": Spec((D, H * hd), ("embed", "heads")),
        "wk": Spec((D, K * hd), ("embed", "kv")),
        "wv": Spec((D, K * hd), ("embed", "kv")),
        "wo": Spec((H * hd, D), ("heads", "embed")),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = Spec((H * hd,), ("heads",), "zeros")
        s["bk"] = Spec((K * hd,), ("kv",), "zeros")
        s["bv"] = Spec((K * hd,), ("kv",), "zeros")
    return s


def apply_attention(p, cfg: ModelConfig, x, positions, rules: Rules,
                    mode: str = "train", cache: Optional[dict] = None,
                    cache_index=None, kv_source: Optional[jnp.ndarray] = None,
                    causal: bool = True, mesh=None, segment_ids=None):
    """GQA self-attention (or cross-attention when ``kv_source`` is given).

    mode: train | prefill | decode. Returns (y, new_cache). ``mesh``
    (threaded from the trainer/serving factories, feature-detected like
    the loss's) lets the fused attention kernels shard_map over the
    activation batch/head axes. ``segment_ids`` (B, S) int32 masks
    attention to within-document positions for packed batches (self-
    attention only: cross-attention keys are not packed, and decode
    serves one document per row).
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_in = kv_source if kv_source is not None else x

    q = x @ p["wq"]
    k = kv_in @ p["wk"]
    v = kv_in @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = shard(q, ("act_batch", "act_seq", "act_heads"), rules)
    k = shard(k, ("act_batch", "act_seq", "act_kv"), rules)
    v = shard(v, ("act_batch", "act_seq", "act_kv"), rules)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, kv_in.shape[1], K, hd)
    v = v.reshape(B, kv_in.shape[1], K, hd)

    if kv_source is None and cfg.pos_embed == "rope":
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    scale = 1.0 / math.sqrt(hd)
    new_cache = cache
    if mode == "decode" and kv_source is None:
        # insert this step's k/v at cache_index, attend over the cache.
        # The cache is sequence-sharded (cache_seq -> model axis): attention
        # reduces over the sharded T with small lse/partial all-reduces
        # instead of gathering the cache.
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), cache_index, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), cache_index, 1)
        ck = shard(ck, ("cache_batch", "cache_seq", None, "cache_kv"), rules)
        cv = shard(cv, ("cache_batch", "cache_seq", None, "cache_kv"), rules)
        new_cache = {"k": ck, "v": cv}
        out = decode_attention(q, ck, cv, cfg.attn_q_block, scale,
                               kv_len=cache_index + S, rules=rules,
                               mesh=mesh)
    elif kv_source is not None and S == 1:
        out = decode_attention(q, k, v, cfg.attn_q_block, scale, rules=rules,
                               mesh=mesh,
                               kv_axes=("act_batch", None, "act_heads", None))
    else:
        if mode == "prefill":
            new_cache = {"k": k, "v": v}
        q = shard(q, ("act_batch", "act_seq", "act_heads", None), rules)
        k = shard(k, ("act_batch", None, "act_heads", None), rules)
        v = shard(v, ("act_batch", None, "act_heads", None), rules)
        # GQA expansion (kernel route: never; ref route: repeat so the
        # head axis TP-shards cleanly) lives inside the wrappers
        fn = (causal_blockwise_attention if kv_source is None
              else cross_blockwise_attention)
        seg = None
        if segment_ids is not None and kv_source is None:
            seg = (segment_ids, segment_ids)
        out = fn(q, k, v, cfg.attn_kv_block, scale, rules=rules, mesh=mesh,
                 segments=seg)

    out = out.reshape(B, S, H * hd)
    y = out @ p["wo"]
    return shard(y, ("act_batch", "act_seq", "act_embed"), rules), new_cache


# ------------------------------------------------------------- MLA attention

def mla_spec(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    qn, qr, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    qlr, kvlr = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wq_a": Spec((D, qlr), ("embed", "lora")),
        "q_norm": Spec((qlr,), ("norm",), "ones"),
        "wq_b": Spec((qlr, H * (qn + qr)), ("lora", "heads")),
        "wkv_a": Spec((D, kvlr + qr), ("embed", "lora")),
        "kv_norm": Spec((kvlr,), ("norm",), "ones"),
        "wkv_b": Spec((kvlr, H * (qn + vd)), ("lora", "heads")),
        "wo": Spec((H * vd, D), ("heads", "embed")),
    }


def apply_mla_attention(p, cfg: ModelConfig, x, positions, rules: Rules,
                        mode: str = "train", cache=None, cache_index=None,
                        mesh=None, segment_ids=None):
    """Multi-head Latent Attention (DeepSeek-V2/V3).

    Caches only the compressed kv latent (kv_lora_rank) + shared rope key —
    the architecture's memory win, visible directly in the dry-run bytes.
    """
    B, S, D = x.shape
    H = cfg.n_heads
    qn, qr, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvlr = cfg.kv_lora_rank

    cq = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.rms_eps)
    q = (cq @ p["wq_b"]).reshape(B, S, H, qn + qr)
    q_nope, q_rope = q[..., :qn], q[..., qn:]

    kv_a = x @ p["wkv_a"]
    c_kv, k_rope = kv_a[..., :kvlr], kv_a[..., kvlr:]
    c_kv = rmsnorm(c_kv, p["kv_norm"], cfg.rms_eps)

    cos, sin = rope_tables(positions, qr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)  # single shared head

    scale = 1.0 / math.sqrt(qn + qr)
    new_cache = cache
    if mode == "decode":
        # Absorbed decode (DeepSeek's production trick): fold wkv_b into the
        # query/output so attention runs directly against the cached latent —
        # no T-sized key/value expansion per step. The latent cache is
        # sequence-sharded; softmax reduces over the sharded T with small
        # all-reduces.
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), cache_index, 1)
        krope = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), cache_index, 1)
        ckv = shard(ckv, ("cache_batch", "cache_seq", "cache_kv"), rules)
        new_cache = {"ckv": ckv, "krope": krope}
        T = ckv.shape[1]
        wkv = p["wkv_b"].reshape(kvlr, H, qn + vd)
        wk, wv = wkv[..., :qn], wkv[..., qn:]
        q_lat = jnp.einsum("bqhn,khn->bqhk", q_nope, wk)       # (B,S,H,kvlr)
        s_lat = jnp.einsum("bqhk,btk->bhqt", q_lat, ckv)
        s_rope = jnp.einsum("bqhr,btr->bhqt", q_rope, krope[:, :, 0])
        s = (s_lat + s_rope).astype(jnp.float32) * scale
        mask = jnp.arange(T) < (cache_index + S)
        s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
        pattn = jax.nn.softmax(s, axis=-1)
        out_lat = jnp.einsum("bhqt,btk->bqhk", pattn.astype(x.dtype), ckv)
        out = jnp.einsum("bqhk,khv->bqhv", out_lat, wv)
    else:
        if mode == "prefill":
            new_cache = {"ckv": c_kv, "krope": k_rope}
        # expand latents to per-head keys/values (train/prefill)
        kv = (c_kv @ p["wkv_b"]).reshape(B, c_kv.shape[1], H, qn + vd)
        k_nope, vv = kv[..., :qn], kv[..., qn:]
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (B, c_kv.shape[1], H, qr))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        q_full = shard(q_full, ("act_batch", "act_seq", "act_heads", None), rules)
        k_full = shard(k_full, ("act_batch", None, "act_heads", None), rules)
        vv = shard(vv, ("act_batch", None, "act_heads", None), rules)
        # full-head (H == K) causal attention; the kernel route also
        # covers MLA's asymmetric head dims (qk qn+qr vs value vd)
        seg = None if segment_ids is None else (segment_ids, segment_ids)
        out = causal_blockwise_attention(q_full, k_full, vv,
                                         cfg.attn_kv_block, scale,
                                         rules=rules, mesh=mesh,
                                         segments=seg)
    y = out.reshape(B, S, H * vd) @ p["wo"]
    return shard(y, ("act_batch", "act_seq", "act_embed"), rules), new_cache


# --------------------------------------------------------------------- MLPs

def mlp_spec(cfg: ModelConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    if cfg.mlp_kind == "gelu":  # gpt2-style 2-matrix MLP
        return {
            "w_up": Spec((D, F), ("embed", "mlp")),
            "w_down": Spec((F, D), ("mlp", "embed")),
        }
    return {
        "w_gate": Spec((D, F), ("embed", "mlp")),
        "w_up": Spec((D, F), ("embed", "mlp")),
        "w_down": Spec((F, D), ("mlp", "embed")),
    }


def apply_mlp(p, cfg: ModelConfig, x, rules: Rules):
    if cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(x @ p["w_up"])
    else:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, ("act_batch", "act_seq", "act_mlp"), rules)
    y = h @ p["w_down"]
    return shard(y, ("act_batch", "act_seq", "act_embed"), rules)


def moe_spec(cfg: ModelConfig) -> dict:
    D, F, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    s = {
        "router": Spec((D, E), ("embed", None), "small"),
        "w_gate": Spec((E, D, F), ("experts", "embed", "expert_mlp")),
        "w_up": Spec((E, D, F), ("experts", "embed", "expert_mlp")),
        "w_down": Spec((E, F, D), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        s["shared_gate"] = Spec((D, Fs), ("embed", "mlp"))
        s["shared_up"] = Spec((D, Fs), ("embed", "mlp"))
        s["shared_down"] = Spec((Fs, D), ("mlp", "embed"))
    return s


def apply_moe(p, cfg: ModelConfig, x, rules: Rules):
    """Capacity-based token-dropping MoE, group-local dispatch (GShard-style).

    Tokens are partitioned into ``G`` groups that shard over the ``data``
    axis; each group scatters into its own (E, C_g, D) buffer. Because
    activations are already replicated over ``model`` and experts over
    ``data``, the dispatch scatter is device-local — the only collectives
    are the expert-dim ones XLA inserts for the combine (activation-sized,
    not dispatch-buffer-sized). Expert FLOPs ~ active-FLOPs * capacity.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = max(1, T // cfg.moe_group_size)
    while T % G:
        G -= 1
    Tg = T // G
    C = max(1, int(cfg.capacity_factor * Tg * k / E))
    C = min(C, Tg)
    xg = x.reshape(G, Tg, D)
    xg = shard(xg, ("act_moe_group", None, "act_embed"), rules)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    top_w, top_i = jax.lax.top_k(probs, k)                    # (G, Tg, k)
    top_w = top_w / jnp.sum(top_w, -1, keepdims=True)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(jax.nn.one_hot(top_i[..., 0], E, dtype=jnp.float32), (0, 1))
    pe = jnp.mean(probs, (0, 1))
    aux = E * jnp.sum(me * pe)

    # slot of each (token, choice) inside its expert's capacity, per group
    oh = jax.nn.one_hot(top_i, E, dtype=jnp.int32).reshape(G, Tg * k, E)
    pos = jnp.cumsum(oh, 1) - 1                               # (G, Tg*k, E)
    pos = jnp.sum(pos * oh, -1).reshape(G, Tg, k)
    keep = pos < C

    # GShard-style one-hot dispatch/combine einsums: everything downstream of
    # the mask is E-sharded (EP over 'model'), so the only collectives are
    # (a) small (G,Tg,D) partial-sum all-reduces for combine/dispatch-grad
    # and (b) FSDP weight gathers — no scatter/gather buffer movement.
    # The k axis is contracted INSIDE the einsum (a flattened (G,Tg*k,E,C)
    # intermediate would be ~5 GB for deepseek-v3).
    keep_f = keep.astype(x.dtype)
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=x.dtype)  # (G,t,k,C)
    oh_e = jax.nn.one_hot(top_i, E, dtype=x.dtype)                    # (G,t,k,E)
    oh_e = shard(oh_e, ("act_moe_group", None, None, "act_experts"), rules)
    mask_c = jnp.einsum("gtke,gtkc->gtec",
                        oh_e * (top_w.astype(x.dtype) * keep_f)[..., None],
                        oh_c)                                  # weighted combine
    mask_d = jnp.einsum("gtke,gtkc->gtec", oh_e * keep_f[..., None], oh_c)
    mask_c = shard(mask_c, ("act_moe_group", None, "act_experts", None), rules)
    mask_d = shard(mask_d, ("act_moe_group", None, "act_experts", None), rules)

    xe = jnp.einsum("gtec,gtd->gecd", mask_d, xg)             # (G,E,C,D)
    xe = shard(xe, ("act_moe_group", "act_experts", None, "act_embed"), rules)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = shard(ye, ("act_moe_group", "act_experts", None, "act_embed"), rules)

    y = jnp.einsum("gtec,gecd->gtd", mask_c, ye)
    y = shard(y, ("act_moe_group", None, "act_embed"), rules)
    y = y.reshape(T, D)

    if cfg.n_shared_experts:
        xf = x.reshape(T, D)
        hs = jax.nn.silu(xf @ p["shared_gate"]) * (xf @ p["shared_up"])
        y = y + hs @ p["shared_down"]
    y = y.reshape(B, S, D)
    return shard(y, ("act_batch", "act_seq", "act_embed"), rules), aux


# ------------------------------------------------------------------- Mamba2

def mamba_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    din, nh = cfg.d_inner, cfg.ssm_nheads
    gn = cfg.ssm_ngroups * cfg.ssm_state
    d_in_proj = 2 * din + 2 * gn + nh
    return {
        "in_proj": Spec((D, d_in_proj), ("embed", "ssm_inner")),
        "conv_w": Spec((cfg.ssm_dconv, cfg.conv_dim), ("conv", "ssm_inner")),
        "conv_b": Spec((cfg.conv_dim,), ("ssm_inner",), "zeros"),
        "A_log": Spec((nh,), (None,), "alog"),
        "D": Spec((nh,), (None,), "ones"),
        "dt_bias": Spec((nh,), (None,), "dtbias"),
        "gate_norm": Spec((din,), ("ssm_inner",), "ones"),
        "out_proj": Spec((din, D), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv1d, window ``dconv``. x (B,S,C), w (dconv,C).

    ``state`` (B, dconv-1, C) prepends history (decode); returns (y, new_state).
    """
    dconv = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dconv - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], 1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(dconv)) + b
    new_state = xp[:, -(dconv - 1):] if dconv > 1 else pad
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, rules: Optional[Rules] = None):
    """Chunked SSD (Mamba2 'state-space duality' algorithm, matmul form).

    xh (B,S,nh,hd); dt (B,S,nh) (post-softplus); A (nh,) negative;
    Bm, Cm (B,S,G,N). Returns y (B,S,nh,hd).
    """
    B_, S, nh, hd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    chunk = _pick_block(S, S, chunk)
    nc = S // chunk
    rep = nh // G

    xc = xh.reshape(B_, nc, chunk, nh, hd)
    dtc = dt.reshape(B_, nc, chunk, nh)
    Bc = jnp.repeat(Bm.reshape(B_, nc, chunk, G, N), rep, axis=3)   # (B,nc,c,nh,N)
    Cc = jnp.repeat(Cm.reshape(B_, nc, chunk, G, N), rep, axis=3)
    if rules is not None:
        # head-shard the intra-chunk tensors: the (B,nc,c,c,nh) decay/score
        # blocks are O(17 GB) per jamba layer if the head dim replicates
        hax = ("act_batch", None, None, "act_ssm_heads", None)
        xc = shard(xc, hax, rules)
        Bc = shard(Bc, hax, rules)
        Cc = shard(Cc, hax, rules)
        dtc = shard(dtc, ("act_batch", None, None, "act_ssm_heads"), rules)

    dA = dtc * A  # (B,nc,c,nh), negative
    cum = jnp.cumsum(dA, axis=2)

    # intra-chunk: y_i += sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
    li = cum[:, :, :, None, :]   # i index at axis 2
    lj = cum[:, :, None, :, :]
    decay = jnp.exp(li - lj)     # (B,nc,i,j,nh)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc).astype(jnp.float32)
    w = cb * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(xh.dtype), xc)

    # chunk states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j x_j^T  (B,nc,nh,N,hd)
    dec_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,c,nh)
    sb = (dec_end * dtc)[..., None] * Bc        # (B,nc,c,nh,N)
    states = jnp.einsum("bcjhn,bcjhp->bchnp", sb.astype(xh.dtype), xc)

    # inter-chunk recurrence over nc (small): h_c = h_{c-1} * exp(sum dA_c) + S_c
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))  # (B,nc,nh)

    def scan_fn(h, inp):
        s_c, d_c = inp
        h_new = h * d_c[..., None, None].astype(h.dtype) + s_c
        return h_new, h  # emit state *before* this chunk

    h0 = jnp.zeros((B_, nh, N, hd), jnp.float32)
    _, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)  # (B,nc,nh,N,hd)

    dec_in = jnp.exp(cum)  # (B,nc,c,nh)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         (Cc * dec_in[..., None]).astype(xh.dtype),
                         h_prev.astype(xh.dtype))
    y = (y_intra + y_inter).reshape(B_, S, nh, hd)
    return y


def apply_mamba(p, cfg: ModelConfig, x, rules: Rules, mode: str = "train",
                cache: Optional[dict] = None):
    """Mamba2 block. cache = {"conv": (B,dconv-1,conv_dim), "ssm": (B,nh,N,hd)}."""
    B, S, D = x.shape
    din, nh, hd = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state

    zxbcdt = x @ p["in_proj"]
    zxbcdt = shard(zxbcdt, ("act_batch", "act_seq", "act_ssm_inner"), rules)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:din + cfg.conv_dim]
    dt_raw = zxbcdt[..., din + cfg.conv_dim:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    new_cache = cache
    if mode == "decode":
        xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], cache["conv"])
    else:
        xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        # conv_state holds the last (dconv-1) pre-activation inputs — exactly
        # what decode needs if this pass is a prefill.

    xs = xbc[..., :din].reshape(B, S, nh, hd)
    Bm = xbc[..., din:din + G * N].reshape(B, S, G, N)
    Cm = xbc[..., din + G * N:].reshape(B, S, G, N)

    if mode == "decode":
        # single-step recurrence: h = h*exp(dt*A) + dt * x B^T ; y = C.h + D x
        h = cache["ssm"].astype(jnp.float32)           # (B,nh,N,hd)
        dt1 = dt[:, 0]                                  # (B,nh)
        dA = jnp.exp(dt1 * A)                           # (B,nh)
        Bm1 = jnp.repeat(Bm[:, 0], nh // G, axis=1)     # (B,nh,N)
        Cm1 = jnp.repeat(Cm[:, 0], nh // G, axis=1)
        x1 = xs[:, 0].astype(jnp.float32)               # (B,nh,hd)
        h = h * dA[..., None, None] + (dt1[..., None, None]
                                       * Bm1[..., :, None] * x1[..., None, :])
        y = jnp.einsum("bhn,bhnp->bhp", Cm1, h)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * x1
        y = y[:, None].astype(x.dtype)                  # (B,1,nh,hd)
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssm": h.astype(cache["ssm"].dtype)}
    else:
        y = _ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk, rules)
        y = y + p["D"].astype(x.dtype)[None, None, :, None] * xs
        if mode == "prefill":
            new_cache = {"conv": conv_state,
                         "ssm": _final_ssm_state(xs, dt, A, Bm, Cm)}

    y = y.reshape(B, S, din)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["gate_norm"], cfg.rms_eps)
    out = y @ p["out_proj"]
    return shard(out, ("act_batch", "act_seq", "act_embed"), rules), new_cache


def _final_ssm_state(xh, dt, A, Bm, Cm):
    """Final SSM state h_S = sum_j exp(cum_S - cum_j) dt_j B_j x_j^T."""
    B_, S, nh, hd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    Bf = jnp.repeat(Bm, rep, axis=2)
    dA = dt * A
    cum = jnp.cumsum(dA, axis=1)
    dec = jnp.exp(cum[:, -1:, :] - cum)  # (B,S,nh)
    sb = (dec * dt)[..., None] * Bf      # (B,S,nh,N)
    return jnp.einsum("bjhn,bjhp->bhnp", sb.astype(jnp.float32),
                      xh.astype(jnp.float32))
