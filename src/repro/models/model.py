"""Top-level model API: init / forward / loss / caches / logical axes.

Parameter tree layout (labels drive the SCALE optimizer branches):

    {"tok_embed": {"w"},                  # 'first' group
     "segments": {"seg<i>_<kind>": {...stacked super-block params...}},
     "final_norm": {"s"},
     "lm_head": {"w"}}                    # 'last' group (momentum)

With ``cfg.tie_embeddings`` the ``lm_head`` entry does not exist: the head
is ``tok_embed.w`` read transposed ((V, D) storage, (D, V) use; audio:
(C, V, D) vs (C, D, V)). :func:`head_weight` is the single accessor — the
serving/loss paths either fold the transpose into their contraction or
dispatch the transposed-w fused kernels, and the optimizer must label the
tied matrix ``last`` (``LabelRules.tied()``) so it keeps head momentum.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as T
from .config import ModelConfig
from .sharding import Rules, shard

_is_spec = lambda x: isinstance(x, L.Spec)


# ----------------------------------------------------------------- spec tree

def _embed_spec(cfg: ModelConfig) -> dict:
    V, D = cfg.padded_vocab, cfg.d_model
    if cfg.family == "audio":
        return {"w": L.Spec((cfg.n_codebooks, V, D), (None, "vocab", "embed"))}
    return {"w": L.Spec((V, D), ("vocab", "embed"))}


def _head_spec(cfg: ModelConfig) -> dict:
    V, D = cfg.padded_vocab, cfg.d_model
    if cfg.family == "audio":
        return {"w": L.Spec((cfg.n_codebooks, D, V), (None, "embed", "vocab"))}
    return {"w": L.Spec((D, V), ("embed", "vocab"))}


def _stacked(spec_tree: dict, n: int) -> dict:
    return jax.tree_util.tree_map(
        lambda s: L.Spec((n,) + tuple(s.shape), (None,) + tuple(s.axes), s.init),
        spec_tree, is_leaf=_is_spec)


def model_spec(cfg: ModelConfig) -> dict:
    segs = {}
    for i, (kind, n) in enumerate(cfg.segments):
        segs[f"seg{i}_{kind}"] = _stacked(T.superblock_spec(cfg, kind), n)
    out = {
        "tok_embed": _embed_spec(cfg),
        "segments": segs,
        "final_norm": {"s": L.Spec((cfg.d_model,), ("norm",), "ones")},
    }
    if not cfg.tie_embeddings:
        # tied models have no separate head: tok_embed.w is read transposed
        out["lm_head"] = _head_spec(cfg)
    if cfg.pos_embed == "learned":
        out["pos_embed"] = {"w": L.Spec((cfg.max_position, cfg.d_model),
                                        (None, "embed"))}
    return out


def param_shapes(cfg: ModelConfig) -> dict:
    return L.shapes_from_spec(model_spec(cfg))


def param_logical_axes(cfg: ModelConfig) -> dict:
    return L.axes_from_spec(model_spec(cfg))


def count_params(shapes, cfg: Optional[ModelConfig] = None,
                 active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count from a shapes tree."""
    import numpy as np
    if not active_only or cfg is None or not cfg.n_experts:
        return int(sum(np.prod(s) for s in jax.tree_util.tree_leaves(
            shapes, is_leaf=lambda x: isinstance(x, tuple))))
    spec = model_spec(cfg)
    total = 0
    for s in jax.tree_util.tree_leaves(spec, is_leaf=_is_spec):
        n = int(np.prod(s.shape))
        if "experts" in s.axes:
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return int(total)


def init_params(key, cfg: ModelConfig) -> dict:
    spec = model_spec(cfg)
    dtype = cfg.jdtype
    flat = {}
    keys = jax.random.split(key, 3 + len(cfg.segments))
    flat["tok_embed"] = L.init_from_spec(keys[0], spec["tok_embed"], dtype)
    flat["final_norm"] = L.init_from_spec(keys[1], spec["final_norm"], dtype)
    if "lm_head" in spec:  # untied only; keys[2] stays reserved so the
        # tied/untied trees share every other leaf's init stream
        flat["lm_head"] = L.init_from_spec(keys[2], spec["lm_head"], dtype)
    if "pos_embed" in spec:
        flat["pos_embed"] = L.init_from_spec(
            jax.random.fold_in(key, 99), spec["pos_embed"], dtype)
    segs = {}
    for i, (kind, n) in enumerate(cfg.segments):
        sb_spec = T.superblock_spec(cfg, kind)
        ks = jax.random.split(keys[3 + i], n)
        segs[f"seg{i}_{kind}"] = jax.vmap(
            lambda k: L.init_from_spec(k, sb_spec, dtype))(ks)
    flat["segments"] = segs
    return flat


# -------------------------------------------------------------------- cache

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or cfg.jdtype
    out = {}
    for i, (kind, n) in enumerate(cfg.segments):
        one = T.superblock_cache(cfg, kind, batch, max_seq, dtype)
        out[f"seg{i}_{kind}"] = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n,) + x.shape).copy(), one)
    return out


def cache_logical_axes(cfg: ModelConfig) -> dict:
    return {f"seg{i}_{kind}": T.cache_axes(cfg, kind)
            for i, (kind, n) in enumerate(cfg.segments)}


# ------------------------------------------------------------------ forward

def forward(params, cfg: ModelConfig, tokens, *, image_embeds=None,
            mode: str = "train", cache=None, cache_index=None,
            rules: Optional[Rules] = None, mesh=None, positions=None,
            segment_ids=None):
    """Run the backbone. Returns (hidden, new_cache, aux_loss).

    ``mesh`` (optional, threaded from the trainer/serving factories the
    same way ``loss_fn`` receives it) reaches the attention layers so the
    fused flash kernels can shard_map over the batch/head mesh axes.
    ``positions``/``segment_ids`` (both (B, S) int32, optional) are the
    packed-document operands: within-document positions (RoPE/learned
    positions restart at every document boundary) and the per-token
    document ids the attention mask keeps separated (pad id 0). When
    ``positions`` is None the usual 0..S-1 (or cache-offset) ramp is used.
    """
    rules = rules or Rules(cfg.rule_overrides)
    ew = params["tok_embed"]["w"]
    if cfg.family == "audio":
        # tokens (B, n_codebooks, S): sum codebook embeddings
        x = sum(jnp.take(ew[c], tokens[:, c], axis=0)
                for c in range(cfg.n_codebooks))
    else:
        x = jnp.take(ew, tokens, axis=0)
    x = shard(x, ("act_batch", "act_seq", "act_embed"), rules)

    S = x.shape[1]
    if positions is None:
        if mode == "decode":
            positions = cache_index + jnp.arange(S)
        else:
            positions = jnp.arange(S)
    if cfg.pos_embed == "learned":
        x = x + jnp.take(params["pos_embed"]["w"], positions, axis=0)

    new_cache = {} if cache is not None else None
    aux = jnp.zeros((), jnp.float32)
    for i, (kind, n) in enumerate(cfg.segments):
        name = f"seg{i}_{kind}"
        seg_cache = cache[name] if cache is not None else None
        x, seg_cache, seg_aux = T.apply_segment(
            kind, n, cfg, params["segments"][name], x, positions, rules,
            mode, seg_cache, cache_index, image_embeds, mesh=mesh,
            segment_ids=segment_ids)
        if new_cache is not None:
            new_cache[name] = seg_cache
        aux = aux + seg_aux
    x = L.rmsnorm(x, params["final_norm"]["s"], cfg.rms_eps)
    return x, new_cache, aux


def head_weight(params, cfg: ModelConfig):
    """(w, transposed): the logit-producing matrix and its storage layout.

    Untied: ``params["lm_head"]["w"]`` in (D, V) use layout ((C, D, V)
    audio), ``transposed=False``. Tied: ``params["tok_embed"]["w"]`` in
    (V, D) storage ((C, V, D) audio), ``transposed=True`` — consumers fold
    the transpose into their contraction (reference paths) or dispatch the
    transposed-w kernels; the gradient then lands directly on the embedding
    in its storage layout.
    """
    if cfg.tie_embeddings:
        return params["tok_embed"]["w"], True
    return params["lm_head"]["w"], False


def logits_from_hidden(params, cfg: ModelConfig, hidden,
                       rules: Optional[Rules] = None):
    """Full-vocab logits (serving). hidden (B,S,D) -> (B,S,V[,per codebook])."""
    rules = rules or Rules(cfg.rule_overrides)
    w, tied = head_weight(params, cfg)
    if cfg.family == "audio":
        out = jnp.einsum("bsd,cvd->bcsv" if tied else "bsd,cdv->bcsv",
                         hidden, w)
    elif tied:
        # XLA folds the transpose into the dot (no materialized w.T)
        out = jnp.einsum("bsd,vd->bsv", hidden, w)
    else:
        out = hidden @ w
    out = _mask_pad_vocab(out, cfg)
    return shard(out, ("act_batch", "act_seq", "act_vocab"), rules)


def _mask_pad_vocab(logits, cfg: ModelConfig):
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    neg = jnp.asarray(-1e9, logits.dtype)
    idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(idx < cfg.vocab_size, logits, neg)


def _pick_chunk(S: int, target: int) -> int:
    """Largest divisor of S that is <= min(target, S).

    Delegates the divisor search to ``layers.largest_divisor`` (shared
    with the attention tile fallback ``layers._pick_block``) — and stays
    *audible*: a prime or awkward S used to silently degrade to chunk=1,
    turning the loss scan into a per-token loop with an (S,)-step trace.
    """
    target = min(target, S)
    best = L.largest_divisor(S, target)
    if best * 2 < target:
        warnings.warn(
            f"lm_loss: seq_len={S} has no divisor in ({target // 2}, "
            f"{target}]; loss chunk shrinks to {best} ({S // best} scan "
            f"steps). Pick a seq_len with a divisor near loss_chunk="
            f"{target} to keep the loss scan short.", stacklevel=3)
    return best


def _xent_chunk(h_chunk, w, labels_chunk, cfg: ModelConfig, rules: Rules,
                weights_chunk=None):
    """h (B,c,D), w (D,V), labels (B,c) -> (sum_loss, sum_weight).

    ``weights_chunk`` (optional, (B,c) f32) scales each token's loss; the
    effective weight is 0 wherever the label is masked (-1) *or* the
    weight is 0 — the returned sum_weight counts exactly the tokens that
    contributed, so the caller's mean divides by the right denominator.
    """
    logits = (h_chunk @ w).astype(jnp.float32)
    logits = _mask_pad_vocab(logits, cfg)
    logits = shard(logits, ("act_batch", "act_seq", "act_vocab"), rules)
    lse = jax.nn.logsumexp(logits, axis=-1)
    lab = jnp.clip(labels_chunk, 0)
    ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    weight = (labels_chunk >= 0).astype(jnp.float32)
    if weights_chunk is not None:
        weight = weight * weights_chunk.astype(jnp.float32)
    return jnp.sum((lse - ll) * weight), jnp.sum(weight)


def lm_loss(params, cfg: ModelConfig, hidden, labels,
            rules: Optional[Rules] = None, mesh=None, weights=None):
    """Cross-entropy over the LM head without full-sequence logits.

    Two implementations, selected by ``repro.kernels.dispatch.xent_route``:

    * **fused** (default where covered): the Pallas blockwise kernels
      behind ``dispatch.xent_loss`` — logits live only as a
      (token-tile, vocab-tile) VMEM block, the backward emits dH/dW from
      the same tiles (custom_vjp). ``mesh`` (passed by the trainer, which
      feature-detects this kwarg) lets the dispatch shard_map the kernels
      using the head's ("embed", "vocab") and the activations'
      ("act_batch", ...) logical axes.
    * **chunked jnp scan** (``REPRO_FUSED=off`` or uncovered
      shape/sharding): the original reference path — (chunk, V) f32
      logit blocks per scan step, bitwise-stable across PRs.

    labels: (B,S) int32, -1 = masked; audio: (B, n_codebooks, S).
    ``weights`` (optional, (B,S) f32 — packed-document loss weights)
    scales each token's loss; the mean divides by the summed *effective*
    weight, counting only tokens with label >= 0 AND weight > 0 (an
    all-masked batch returns loss 0, not a division by a clamped fake
    denominator — see the weight handling below). Audio heads do not take
    weights (packing is a text-family format).
    Returns (mean_loss, total_weight).

    Tied heads (``cfg.tie_embeddings``): ``w`` is the (V, D) embedding; the
    fused route dispatches the transposed-w kernel variants (dW lands in
    (V, D), directly on the embedding) and the scan fallback contracts
    ``tok_embed.w.T`` chunk by chunk. The head's sharding is derived from
    the storage layout's ("vocab", "embed") logical axes — the same
    physical axes as the untied head's ("embed", "vocab"), swapped.
    """
    rules = rules or Rules(cfg.rule_overrides)
    if weights is not None and cfg.family == "audio":
        raise ValueError("lm_loss: per-token weights are not supported for "
                         "the audio multi-codebook head")
    w, tied = head_weight(params, cfg)
    B, S = hidden.shape[0], hidden.shape[1]

    from repro.kernels import dispatch as _kd  # lazy: optional kernel layer
    head_shape = tuple(w.shape[-2:])
    h_sh = w_sh = None
    if mesh is not None:
        h_sh = rules.sharding(("act_batch", "act_seq", "act_embed"), mesh,
                              hidden.shape)
        w_sh = rules.sharding(("vocab", "embed") if tied
                              else ("embed", "vocab"), mesh, head_shape)
    # resolve REPRO_FUSED once and thread it through: the branch taken
    # here and the route inside xent_loss must come from the same read
    mode = _kd.resolve_mode()
    route, _ = _kd.xent_route(hidden.shape, head_shape, mode,
                              h_sharding=h_sh, w_sharding=w_sh,
                              transposed=tied)
    # mean = sum / effective weight; a zero effective weight (all tokens
    # masked) yields loss 0 via a neutral denominator — NOT max(ws, 1),
    # which silently deflated fractional-weight sums in (0, 1)
    _mean = lambda ls, ws: ls / jnp.where(ws > 0, ws, 1.0)

    if route == "kernel":
        def head_loss_sums(wh, labs):
            losses = _kd.xent_loss(hidden, wh, labs,
                                   vocab_size=cfg.vocab_size, mode=mode,
                                   weights=weights,
                                   h_sharding=h_sh, w_sharding=w_sh,
                                   transposed=tied)
            if weights is not None:
                ws = jnp.sum(jnp.where(labs >= 0, weights, 0.0))
            else:
                ws = jnp.sum((labs >= 0).astype(jnp.float32))
            return jnp.sum(losses), ws

        if cfg.family == "audio":
            tot_l = tot_w = 0.0
            for c in range(cfg.n_codebooks):
                ls, ws = head_loss_sums(w[c], labels[:, c])
                tot_l, tot_w = tot_l + ls, tot_w + ws
            return _mean(tot_l, tot_w), tot_w
        ls, ws = head_loss_sums(w, labels)
        return _mean(ls, ws), ws

    chunk = _pick_chunk(S, cfg.loss_chunk)
    nch = S // chunk

    def per_head(wh, labs):
        if tied:
            # chunked scan over tok_embed.w.T: the transpose is lazy and
            # fuses into each chunk's dot; grads land on the (V, D) storage
            wh = jnp.swapaxes(wh, -1, -2)

        def body(carry, i):
            s0 = i * chunk
            h_c = jax.lax.dynamic_slice_in_dim(hidden, s0, chunk, 1)
            l_c = jax.lax.dynamic_slice_in_dim(labs, s0, chunk, 1)
            w_c = None if weights is None else \
                jax.lax.dynamic_slice_in_dim(weights, s0, chunk, 1)
            ls, ws = _xent_chunk(h_c, wh, l_c, cfg, rules, weights_chunk=w_c)
            return (carry[0] + ls, carry[1] + ws), None

        (ls, ws), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(nch))
        return ls, ws

    if cfg.family == "audio":
        tot_l = tot_w = 0.0
        for c in range(cfg.n_codebooks):
            ls, ws = per_head(w[c], labels[:, c])
            tot_l, tot_w = tot_l + ls, tot_w + ws
        return _mean(tot_l, tot_w), tot_w
    ls, ws = per_head(w, labels)
    return _mean(ls, ws), ws


def loss_fn(params, cfg: ModelConfig, batch: dict, aux_coef: float = 0.01,
            rules: Optional[Rules] = None, mesh=None):
    """Full training loss. batch: tokens, labels, [image_embeds],
    [positions, segment_ids, loss_weights] (packed-document batches).

    ``mesh`` is forwarded to :func:`lm_loss` for the mesh-aware fused
    cross-entropy AND to :func:`forward` for the mesh-aware fused
    attention; callers (the trainer) feature-detect this kwarg. Packed
    batches (``data.pipeline`` with ``pack_documents``) carry
    within-document positions, the segment ids the attention mask keeps
    separated, and per-token loss weights — all picked up here by key.
    """
    hidden, _, aux = forward(params, cfg, batch["tokens"],
                             image_embeds=batch.get("image_embeds"),
                             mode="train", rules=rules, mesh=mesh,
                             positions=batch.get("positions"),
                             segment_ids=batch.get("segment_ids"))
    loss, weight = lm_loss(params, cfg, hidden, batch["labels"], rules=rules,
                           mesh=mesh, weights=batch.get("loss_weights"))
    total = loss + aux_coef * aux
    return total, {"loss": loss, "aux": aux, "weight": weight}
