"""repro.models — the architecture zoo (dense / MoE / SSM / hybrid / VLM / audio)."""
from .config import ModelConfig
from .model import (cache_logical_axes, count_params, forward, head_weight,
                    init_cache, init_params, lm_loss, logits_from_hidden,
                    loss_fn, model_spec, param_logical_axes, param_shapes)
from .sharding import DEFAULT_RULES, Rules, shard, tree_shardings

__all__ = [
    "ModelConfig", "cache_logical_axes", "count_params", "forward",
    "head_weight", "init_cache", "init_params", "lm_loss",
    "logits_from_hidden", "loss_fn", "model_spec", "param_logical_axes",
    "param_shapes", "DEFAULT_RULES", "Rules", "shard", "tree_shardings",
]
