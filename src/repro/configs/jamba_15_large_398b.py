"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 with MoE every 2nd
layer, 16 experts top-2 [arXiv:2403.19887; hf]. Super-block of 8: attention
at position 0, Mamba at 1..7; MoE FFN at odd positions.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    n_experts=16, top_k=2, moe_d_ff=24576, hybrid_period=8,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid",
    n_layers=8, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, n_experts=4, top_k=2, moe_d_ff=128,
    hybrid_period=4, ssm_state=16, ssm_headdim=32, ssm_chunk=16,
    dtype="float32", attn_kv_block=32, attn_q_block=32, loss_chunk=32,
    capacity_factor=2.0,
)
