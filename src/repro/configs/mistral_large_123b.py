"""mistral-large-123b — dense GQA [hf:mistralai/Mistral-Large-2407]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=32768,
)

SMOKE = ModelConfig(
    name="mistral-large-123b-smoke", family="dense",
    n_layers=2, d_model=192, n_heads=6, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, dtype="float32",
    attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
