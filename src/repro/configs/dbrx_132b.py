"""dbrx-132b — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    n_experts=16, top_k=4, moe_d_ff=10752,
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, n_experts=4, top_k=2, moe_d_ff=128,
    dtype="float32", attn_kv_block=32, attn_q_block=32, loss_chunk=32,
    capacity_factor=2.0,
)
