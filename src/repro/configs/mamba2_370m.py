"""mamba2-370m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_ngroups=1, ssm_dconv=4, ssm_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=512, ssm_state=16, ssm_headdim=32, ssm_chunk=16,
    dtype="float32", loss_chunk=32,
)
