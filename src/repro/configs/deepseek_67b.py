"""deepseek-67b — dense llama-arch GQA [arXiv:2401.02954; hf]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, dtype="float32",
    attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
