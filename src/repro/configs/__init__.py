from .base import LM_SHAPES, ShapeSpec, cell_config, supports_long_context
from .registry import (ARCH_IDS, LLAMA_PAPER, get_arch, get_cell, get_shapes,
                       iter_cells)
__all__ = ["LM_SHAPES", "ShapeSpec", "cell_config", "supports_long_context",
           "ARCH_IDS", "LLAMA_PAPER", "get_arch", "get_cell", "get_shapes",
           "iter_cells"]
