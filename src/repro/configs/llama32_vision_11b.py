"""llama-3.2-vision-11b — VLM backbone, cross-attn every 5th layer
[hf:meta-llama/Llama-3.2-11B-Vision]. The vision tower is a STUB:
input_specs supplies precomputed patch embeddings (B, n_image_tokens, D).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    cross_attn_every=5, n_image_tokens=4096,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke", family="vlm",
    n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=512, cross_attn_every=2, n_image_tokens=16,
    dtype="float32", attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
