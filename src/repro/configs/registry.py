"""Architecture registry: ``--arch <id>`` selection + paper LLaMA sizes."""
from __future__ import annotations

import dataclasses
import importlib

from repro.models import ModelConfig

from .base import LM_SHAPES, ShapeSpec, cell_config, supports_long_context

ARCH_IDS = (
    "deepseek-67b",
    "qwen2-7b",
    "granite-3-8b",
    "mistral-large-123b",
    "mamba2-370m",
    "llama-3.2-vision-11b",
    "dbrx-132b",
    "deepseek-v3-671b",
    "jamba-1.5-large-398b",
    "musicgen-medium",
)

_MODULES = {
    "deepseek-67b": "deepseek_67b",
    "qwen2-7b": "qwen2_7b",
    "granite-3-8b": "granite_3_8b",
    "mistral-large-123b": "mistral_large_123b",
    "mamba2-370m": "mamba2_370m",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "dbrx-132b": "dbrx_132b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "jamba-1.5-large-398b": "jamba_15_large_398b",
    "musicgen-medium": "musicgen_medium",
}

# The paper's own LLaMA family (Zhao et al. 2024 GaLore configs), used by the
# pretraining-proxy benchmarks and examples.
# Appendix F extra architectures (paper Table 9/10): GPT2-Medium (learned
# positions + GELU MLP), Qwen2-500M (GQA + QKV bias), Gemma-2B (wide-ff GQA).
PAPER_EXTRA = {
    "gpt2-medium": dict(n_layers=24, d_model=1024, n_heads=16,
                        n_kv_heads=16, d_ff=4096, vocab_size=50257,
                        pos_embed="learned", max_position=1024,
                        mlp_kind="gelu"),
    "qwen2-500m": dict(n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
                       head_dim=64, d_ff=4864, vocab_size=151936,
                       qkv_bias=True),
    "gemma-2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                     head_dim=256, d_ff=16384, vocab_size=256000),
}

LLAMA_PAPER = {
    "llama-60m": dict(n_layers=8, d_model=512, n_heads=8, d_ff=1376),
    "llama-130m": dict(n_layers=12, d_model=768, n_heads=12, d_ff=2048),
    "llama-350m": dict(n_layers=24, d_model=1024, n_heads=16, d_ff=2736),
    "llama-1b": dict(n_layers=24, d_model=2048, n_heads=32, d_ff=5461),
    "llama-7b": dict(n_layers=32, d_model=4096, n_heads=32, d_ff=11008),
}


def get_arch(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id in _MODULES:
        mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
        cfg = mod.SMOKE if smoke else mod.CONFIG
        return dataclasses.replace(cfg)
    if arch_id in LLAMA_PAPER:
        kw = LLAMA_PAPER[arch_id]
        return ModelConfig(name=arch_id, family="dense", vocab_size=32000,
                           n_kv_heads=kw["n_heads"], **kw)
    if arch_id in PAPER_EXTRA:
        return ModelConfig(name=arch_id, family="dense", **PAPER_EXTRA[arch_id])
    raise KeyError(f"unknown arch {arch_id!r}; options: "
                   f"{ARCH_IDS + tuple(LLAMA_PAPER) + tuple(PAPER_EXTRA)}")


def get_shapes(arch_id: str) -> tuple:
    return LM_SHAPES


def iter_cells(include_skipped: bool = False):
    """All (arch_id, ShapeSpec, runnable) dry-run cells."""
    for arch_id in ARCH_IDS:
        cfg = get_arch(arch_id)
        for shape in LM_SHAPES:
            runnable = not (shape.subquadratic_only
                            and not supports_long_context(cfg))
            if runnable or include_skipped:
                yield arch_id, shape, runnable


def get_cell(arch_id: str, shape_name: str):
    """(adapted ModelConfig, ShapeSpec) for one dry-run cell."""
    cfg = get_arch(arch_id)
    shape = {s.name: s for s in LM_SHAPES}[shape_name]
    return cell_config(cfg, shape), shape
