"""Shared config machinery: input-shape cells + per-cell config adaptation."""
from __future__ import annotations

import dataclasses

from repro.models import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int
    subquadratic_only: bool = False


# The LM-family shape set assigned to every architecture in this task.
LM_SHAPES = (
    ShapeSpec("train_4k", "train", 4_096, 256),
    ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    ShapeSpec("decode_32k", "decode", 32_768, 128),
    ShapeSpec("long_500k", "decode", 524_288, 1, subquadratic_only=True),
)


def supports_long_context(cfg: ModelConfig) -> bool:
    """Sub-quadratic prefill: SSM state or hybrid (per DESIGN.md §5)."""
    return cfg.family in ("ssm", "hybrid")


def _carry_bytes(cfg: ModelConfig, shape, local_mb: int = 4) -> float:
    """Scan-carry (saved residuals) estimate at local microbatch 4, bf16."""
    n_superblocks = sum(n for _, n in cfg.segments)
    return n_superblocks * local_mb * shape.seq_len * cfg.d_model * 2.0


def cell_config(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Adapt a model config to one input-shape cell.

    long_500k (batch=1) re-maps sharding: the batch axis cannot shard, so the
    sequence/cache-seq axes take the ``data`` axis (sequence parallelism).
    """
    cfg = dataclasses.replace(cfg)
    if shape.kind == "train" and _carry_bytes(cfg, shape) > 5e9:
        # store the scan-carry residual TP-sharded (sequence-parallel style)
        # ONLY where the saved activations wouldn't fit: the resharding costs
        # one residual-sized all-gather fwd + all-reduce bwd per layer, which
        # regressed the dense cells when applied blanket (§Perf iteration 8)
        cfg.rule_overrides = tuple(cfg.rule_overrides) + (
            ("act_residual", ("model",)),)
    if shape.global_batch == 1:
        cfg.rule_overrides = tuple(cfg.rule_overrides) + (
            ("act_batch", ()),
            ("cache_batch", ()),
            ("act_seq", ("data",)),
            ("cache_seq", ("data", "model")),
        )
    return cfg
