"""qwen2-7b — dense GQA with QKV bias [arXiv:2407.10671; hf]."""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, qkv_bias=True, dtype="float32",
    attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
