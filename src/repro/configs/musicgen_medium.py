"""musicgen-medium — decoder-only over EnCodec tokens, 4 codebooks
[arXiv:2306.05284; hf]. The EnCodec frontend is a STUB: tokens are
(B, n_codebooks, S) int32; input embeddings sum across codebooks and the
model carries one output head per codebook (all in the SCALE last-layer
momentum group).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048, n_codebooks=4,
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=128, n_codebooks=4,
    dtype="float32", attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
