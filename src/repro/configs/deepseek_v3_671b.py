"""deepseek-v3-671b — MLA + 1 shared / 256 routed top-8 MoE
[arXiv:2412.19437; hf]. First 3 layers dense (d_ff 18432), rest MoE with
per-expert d_ff 2048. The MTP head is folded into the lm_head group (the
SCALE momentum group), per DESIGN.md.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=18432, vocab_size=129280,
    attention_kind="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_rope_dim=64, qk_nope_dim=128, v_head_dim=128,
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    first_dense_layers=3, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=256, vocab_size=512,
    attention_kind="mla", q_lora_rank=48, kv_lora_rank=32,
    qk_rope_dim=16, qk_nope_dim=32, v_head_dim=32,
    n_experts=8, top_k=2, n_shared_experts=1, moe_d_ff=64,
    first_dense_layers=1, capacity_factor=2.0,
    dtype="float32", attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
