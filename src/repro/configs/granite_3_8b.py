"""granite-3-8b — dense GQA [hf:ibm-granite/granite-3.0; hf].

vocab 49155 is not 128-aligned; the embedding/head pad to 49280 and the
loss masks padded logits (production vocab-padding, Megatron-style).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155,
)

SMOKE = ModelConfig(
    name="granite-3-8b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
    d_ff=256, vocab_size=387,  # deliberately unaligned -> exercises padding
    dtype="float32", attn_kv_block=32, attn_q_block=32, loss_chunk=32,
)
